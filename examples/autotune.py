"""Autotuner walkthrough: let the system pick its own strategy.

    PYTHONPATH=src python examples/autotune.py
    PYTHONPATH=src python examples/autotune.py --fast   # CI smoke subset

Searches the strategy space (PP schedule x microbatches x ZeRO x EP)
for two of the paper's configs on a pp=4, dp=2 mesh, under a per-device
memory budget, then shows the winning plan as a declarative Strategy
(its canonical JSON is what the plan cache stores), its lowered
directive list, and the plan-cache hit on a repeated call.  Everything
runs on the timeline simulator — no accelerator needed.
"""
import sys
import tempfile
import time

from repro import Strategy
from repro import tune
from repro.configs import get_config

TOKENS = 32768
BUDGET = 64 * 2**30          # 64 GiB/device keeps the big configs honest


def show(name: str, cache_dir: str,
         mesh: tune.MeshSpec = tune.MeshSpec(pp=4, dp=2),
         budget: int = BUDGET, tokens: int = TOKENS,
         space=None) -> None:
    cfg = get_config(name)
    kw = dict(tokens=tokens, cache_dir=cache_dir, space=space)
    t0 = time.time()
    try:
        plan = tune.search(cfg, mesh, budget, **kw)
    except tune.NoFeasiblePlanError as e:
        # the error names the smallest-footprint candidate, so the fix
        # (more HBM, more devices, or a smaller model) is actionable
        print(f"=== {name}: over budget " + "=" * 26)
        print(f"  {e}")
        budget *= 2
        print(f"  retrying with {budget/2**30:.0f} GiB/device")
        plan = tune.search(cfg, mesh, budget, **kw)
    dt = time.time() - t0
    print(f"=== {name} ({dt:.1f}s) " + "=" * 30)
    print(plan.summary())
    print("  leaderboard:")
    for s in plan.leaderboard:
        print(f"    {s.candidate.label():<34} "
              f"{s.step_seconds*1e3:8.2f} ms  "
              f"{s.peak_bytes/2**30:6.2f} GiB")
    # the winner is a declarative Strategy: serializable, replayable
    strat = plan.strategy()
    doc = strat.to_json()
    assert Strategy.from_json(doc) == strat     # byte-stable round trip
    print(f"  strategy  : {strat.label()}")
    print(f"  json      : {doc[:72]}...")
    d = plan.directives()
    kinds = {}
    for x in d:
        kinds[type(x).__name__] = kinds.get(type(x).__name__, 0) + 1
    print(f"  directives: {len(d)} total {kinds}")
    # second call: served from the JSON plan cache
    t0 = time.time()
    again = tune.search(cfg, mesh, budget, **kw)
    print(f"  re-search: from_cache={again.from_cache} "
          f"({(time.time()-t0)*1e3:.0f} ms)\n")


def main(argv=None) -> None:
    fast = "--fast" in (argv if argv is not None else sys.argv[1:])
    with tempfile.TemporaryDirectory() as cache_dir:
        if fast:
            # CI examples-smoke subset: one dense config, pp=2, and a
            # pruned space so the sweep stays well under a minute
            show("qwen3-1b", cache_dir, mesh=tune.MeshSpec(pp=2, dp=2),
                 tokens=8192,
                 space=tune.SearchSpace(kinds=("1f1b", "dualpipev"),
                                        mb_multipliers=(2,)))
            return
        show("qwen3-1b", cache_dir)           # dense, pp=4 x dp=2
        # MoE opens the EP axis; pp=2 keeps the candidate programs small
        # enough that the 40-point sweep finishes in ~10 s
        show("deepseek-moe-16b", cache_dir, mesh=tune.MeshSpec(pp=2, dp=2))


if __name__ == "__main__":
    main()
