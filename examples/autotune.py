"""Autotuner walkthrough: let the system pick its own strategy.

    PYTHONPATH=src python examples/autotune.py

Searches the strategy space (PP schedule x microbatches x ZeRO x EP)
for two of the paper's configs on a pp=4, dp=2 mesh, under a per-device
memory budget, then shows the winning plan's directive list and the
plan-cache hit on a repeated call.  Everything runs on the timeline
simulator — no accelerator needed.
"""
import tempfile
import time

from repro import tune
from repro.configs import get_config

TOKENS = 32768
BUDGET = 64 * 2**30          # 64 GiB/device keeps the big configs honest


def show(name: str, cache_dir: str,
         mesh: tune.MeshSpec = tune.MeshSpec(pp=4, dp=2),
         budget: int = BUDGET) -> None:
    cfg = get_config(name)
    t0 = time.time()
    try:
        plan = tune.search(cfg, mesh, budget, tokens=TOKENS,
                           cache_dir=cache_dir)
    except tune.NoFeasiblePlanError as e:
        # the error names the smallest-footprint candidate, so the fix
        # (more HBM, more devices, or a smaller model) is actionable
        print(f"=== {name}: over budget " + "=" * 26)
        print(f"  {e}")
        budget *= 2
        print(f"  retrying with {budget/2**30:.0f} GiB/device")
        plan = tune.search(cfg, mesh, budget, tokens=TOKENS,
                           cache_dir=cache_dir)
    dt = time.time() - t0
    print(f"=== {name} ({dt:.1f}s) " + "=" * 30)
    print(plan.summary())
    print("  leaderboard:")
    for s in plan.leaderboard:
        print(f"    {s.candidate.label():<34} "
              f"{s.step_seconds*1e3:8.2f} ms  "
              f"{s.peak_bytes/2**30:6.2f} GiB")
    d = plan.directives()
    kinds = {}
    for x in d:
        kinds[type(x).__name__] = kinds.get(type(x).__name__, 0) + 1
    print(f"  directives: {len(d)} total {kinds}")
    # second call: served from the JSON plan cache
    t0 = time.time()
    again = tune.search(cfg, mesh, budget, tokens=TOKENS,
                        cache_dir=cache_dir)
    print(f"  re-search: from_cache={again.from_cache} "
          f"({(time.time()-t0)*1e3:.0f} ms)\n")


def main() -> None:
    with tempfile.TemporaryDirectory() as cache_dir:
        show("qwen3-1b", cache_dir)           # dense, pp=4 x dp=2
        # MoE opens the EP axis; pp=2 keeps the candidate programs small
        # enough that the 40-point sweep finishes in ~10 s
        show("deepseek-moe-16b", cache_dir, mesh=tune.MeshSpec(pp=2, dp=2))


if __name__ == "__main__":
    main()
