"""Paper §6.2 (Table 1 / Fig 8): composing PP with every ZeRO level —
each cell is a ``Strategy(Mesh(pp, dp), Pipeline(...) | ZeRO(stage))``
compiled through the Strategy front door (see benchmarks/common.py).
Frameworks that don't reshard between microbatches keep full param/grad
buffers alive; Piper's IR frees them after the last consumer, so peak
memory tracks the shard size and much larger batches fit.

  PYTHONPATH=src python examples/zero_pp_memory.py
"""
import sys

sys.path.insert(0, "benchmarks") if "benchmarks" not in sys.path else None

import jax

from benchmarks.bench_pp_zero import peak_for

jax.config.update("jax_platform_name", "cpu")


def main():
    print(f"{'batch':>6} | {'ZeRO-2 piper':>13} {'ZeRO-2 no-reshard':>18} "
          f"| {'ZeRO-3 piper':>13} {'ZeRO-3 no-reshard':>18}")
    for batch in (32, 128, 512):
        row = [batch]
        for zero in (2, 3):
            row.append(peak_for(zero, batch, hold=False))
            row.append(peak_for(zero, batch, hold=True))
        print(f"{row[0]:>6} | {row[1]:>13,} {row[2]:>18,} "
              f"| {row[3]:>13,} {row[4]:>18,}")
    print("\n(no-reshard emulates the TorchTitan behaviour the paper "
          "measured: full buffers never released between microbatches)")


if __name__ == "__main__":
    main()
