"""Batched serving: prefill a batch of prompts, then greedy-decode with
the per-architecture KV/SSM caches.  Runs any assigned arch at reduced
scale on CPU.

  PYTHONPATH=src python examples/serve.py --arch zamba2-2.7b --tokens 16
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import decode_step, init, prefill

jax.config.update("jax_platform_name", "cpu")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    params = init(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab)
    batch = {"tokens": prompts}
    if cfg.n_enc_layers:
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.enc_seq, cfg.d_model))

    max_seq = args.prompt_len + args.tokens
    t0 = time.time()
    logits, cache = prefill(cfg, params, batch, max_seq=max_seq)
    t_prefill = time.time() - t0
    step = jax.jit(lambda p, tok, c: decode_step(cfg, p, tok, c))

    tok = jnp.argmax(logits[:, -1], axis=-1).reshape(args.batch, 1)
    out = [tok]
    t0 = time.time()
    for _ in range(args.tokens - 1):
        logits, cache = step(params, tok, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1).reshape(args.batch, 1)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} ({cfg.family})  batch={args.batch}")
    print(f"prefill {args.prompt_len} tokens: {t_prefill*1e3:.0f} ms")
    print(f"decode {args.tokens} tokens: {t_decode*1e3:.0f} ms "
          f"({args.batch*(args.tokens-1)/max(t_decode,1e-9):.0f} tok/s)")
    print("generated ids[0]:", gen[0].tolist())


if __name__ == "__main__":
    main()
