"""The paper's headline demo (Listings 1-2, Figures 1-4): an annotated
MoE model scheduled with DualPipeV — PP x DP x EP with overlapped
forward/backward microbatch pairs — compiled through the Piper IR,
validated bit-for-bit against the unscheduled model, and timed on the
TPU-constant simulator against interleaved-1F1B.

  PYTHONPATH=src python examples/dualpipe_moe.py
"""
import jax
import jax.numpy as jnp

from repro.core import (ExpertParallel, Mesh, Pipeline, Strategy, ZeRO,
                        compile_training)
from repro.runtime import Interpreter
from repro.runtime.costmodel import CostModel
from repro.runtime.simulator import TimelineSimulator

jax.config.update("jax_platform_name", "cpu")

D, BATCH, N_MB, R = 32, 32, 8, 2
S = 2 * R  # DualPipeV V-placement: rank r hosts stages r and 2R-1-r


# --- Listing 1: the annotated model -----------------------------------------
def stage_fn(p, x):
    return jnp.tanh(jnp.tanh(x @ p["w1"]) @ p["w2"])


def loss_fn(p, x, y):
    return jnp.mean((stage_fn(p, x) - y) ** 2)


def forward(rec, tvs):
    h = tvs["x"]
    for i in range(S - 1):
        with rec.annotate("pp"):                 # pipeline stage
            h = rec.region(stage_fn, f"stage{i}", name=f"s{i}")(h)
            if i % 2 == 1:
                with rec.annotate("ep"):         # expert component
                    h = rec.region(stage_fn, f"exp{i}", name=f"e{i}")(h)
    with rec.annotate("pp"):
        return rec.region(loss_fn, f"stage{S-1}", name="head")(
            h, tvs["y"])


def make_params(seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4 * S)
    p = {}
    for i in range(S):
        p[f"stage{i}"] = {"w1": jax.random.normal(ks[4*i], (D, D)) * .1,
                          "w2": jax.random.normal(ks[4*i+1], (D, D)) * .1}
        if i % 2 == 1 and i < S - 1:
            p[f"exp{i}"] = {"w1": jax.random.normal(ks[4*i+2], (D, D)) * .1,
                            "w2": jax.random.normal(ks[4*i+3], (D, D)) * .1}
    return p


# --- Listing 2: the strategy -------------------------------------------------
def strategy(kind):
    """PP(kind) x DP-2 x EP, declared over a named-axis mesh — the
    fragments lower to the paper's Place/Replicate/Shard/Split/Order
    directive list in canonical order."""
    return Strategy(Mesh(pp=R, dp=2),
                    Pipeline(kind, n_mb=N_MB)     # stage placement + order
                    | ZeRO(stage=1)               # DP for attn (all-reduce)
                    | ExpertParallel())           # EP for experts (a2a)


def main():
    params = make_params()
    inputs = {"x": ((BATCH, D), "float32"), "y": ((BATCH, D), "float32")}
    x = jax.random.normal(jax.random.PRNGKey(1), (BATCH, D))
    y = jax.random.normal(jax.random.PRNGKey(2), (BATCH, D))

    # oracle: the unscheduled model
    def full(p):
        h = x
        for i in range(S - 1):
            h = stage_fn(p[f"stage{i}"], h)
            if i % 2 == 1:
                h = stage_fn(p[f"exp{i}"], h)
        return loss_fn(p[f"stage{S-1}"], h, y)
    l_ref = float(full(params))

    results = {}
    for kind in ("1f1b", "interleaved_1f1b", "dualpipev"):
        # split_backward (ZeroBubble Bi/Bw) derives from the Pipeline
        # fragment's kind; the Strategy is also JSON-serializable:
        # strategy(kind).to_json() round-trips byte-stably
        prog = compile_training(forward, params, inputs,
                                strategy=strategy(kind))
        res = Interpreter(prog).run({"x": x, "y": y})
        assert abs(res.loss - l_ref) < 1e-6, (kind, res.loss, l_ref)
        sim = TimelineSimulator(
            prog, CostModel(ici_bw=2.5e4, comm_latency=0.0),
            chunk_seconds_override=lambda n: (
                5e-3 if n.dims.get("PASS") in ("Bi", "Bw") else 1e-2))
        t = sim.run()
        results[kind] = t.makespan
        print(f"{kind:<18} loss={res.loss:.6f} (oracle {l_ref:.6f})  "
              f"makespan={t.makespan*1e3:.1f} ms  "
              f"peak_mem(dev0)={res.ledgers[0].peak/1024:.0f} KiB "
              f"[{prog.stats['chunks']} chunks, {prog.stats['comms']} comms]")
    gain = 1 - results["dualpipev"] / results["interleaved_1f1b"]
    print(f"\nDualPipeV vs interleaved-1F1B: {gain*100:+.1f}% "
          f"(paper: +10-13% with EP comm on the critical path)")


if __name__ == "__main__":
    main()
