"""Quickstart: declare a distributed strategy with the Strategy API,
save it as JSON, then train a tiny reduced-config model end-to-end on
CPU with the full substrate (data pipeline, AdamW+cosine,
checkpoint/restart) — the saved strategy is validated and scored on the
timeline simulator before training starts (``--strategy``).

  PYTHONPATH=src python examples/quickstart.py
"""
import pathlib
import sys
import tempfile

from repro import Mesh, Overlap, Pipeline, Strategy, ZeRO
from repro.launch.train import main

# the whole distributed plan in one declarative, serializable object:
# 1F1B over a pp=2 x dp=2 named-axis mesh, ZeRO-3 on the DP groups,
# and the overlap engine prefetching param gathers 4 chunks ahead
STRATEGY = Strategy(
    Mesh(pp=2, dp=2),
    Pipeline("1f1b", n_mb=4)
    | ZeRO(stage=3)
    | Overlap(prefetch=4, bucket_mb=32),
)

if __name__ == "__main__":
    doc = STRATEGY.to_json()
    assert Strategy.from_json(doc) == STRATEGY   # byte-stable round trip
    with tempfile.TemporaryDirectory() as tmp:
        path = pathlib.Path(tmp) / "strategy.json"
        path.write_text(doc)
        print(f"strategy {STRATEGY.label()} -> {path}")
        sys.exit(main([
            "--arch", "qwen1.5-0.5b",
            "--strategy", str(path),
            "--tune-tokens", "16384",
            "--steps", "100",
            "--batch", "8", "--seq", "64",
            "--d-model", "128", "--layers", "2", "--vocab", "512",
            "--ckpt-dir", "/tmp/repro_quickstart",
        ]))
