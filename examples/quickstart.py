"""Quickstart: train a tiny reduced-config model end-to-end on CPU with
the full substrate (data pipeline, AdamW+cosine, checkpoint/restart).

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    sys.exit(main([
        "--arch", "qwen1.5-0.5b",
        "--steps", "100",
        "--batch", "8", "--seq", "64",
        "--d-model", "128", "--layers", "2", "--vocab", "512",
        "--ckpt-dir", "/tmp/repro_quickstart",
    ]))
