"""World regrowth: the inverse of ``elastic.shrink_for_survivors``
(DESIGN.md §14).

When replacement devices arrive, the supervisor does not restart: it
*regrows the world* through the same Strategy/IR path a shrink uses —
derive the largest valid ``Mesh`` that fits survivors + replacements by
growing exactly ONE axis, re-target the fragments with
``Strategy.for_mesh`` (the compiler's own validation gates every
candidate), recompile through the plan cache, and remap ZeRO shards UP
in DP degree with the same bit-exact ``checkpoint.reshard`` codec that
mapped them down.

Symmetry is the point: a regrowth after a shrink that reuses the
original world size reproduces the original mesh shape exactly, and the
shrink-era plan cache already holds the original program — regrowth at
a checkpoint boundary costs zero compiles and zero lost steps.
"""
from __future__ import annotations

from dataclasses import dataclass

from ..core.strategy import Mesh, Strategy, StrategyError


class RegrowthError(RuntimeError):
    """No valid grown mesh exists for the available ranks (every
    single-axis increase is rejected by the strategy's fragments, or
    there is nothing to grow)."""


@dataclass(frozen=True)
class GrowthPlan:
    """The growth planner's output: where the world grew and the
    re-targeted strategy to recompile."""
    old_mesh: Mesh
    new_mesh: Mesh
    strategy: Strategy
    grown_axis: str


def grow_for_arrivals(strategy: Strategy, n_ranks: int) -> GrowthPlan:
    """Derive the best grown mesh for ``n_ranks`` available ranks
    (survivors + replacements), mirroring ``shrink_for_survivors``.

    Policy: grow exactly one axis.  Candidates are every
    ``axis -> size`` increase whose world fits ``n_ranks`` and whose
    re-targeted strategy validates (``Strategy.for_mesh`` — stage
    divisibility, dualpipev's S == 2*pp pin, fragment axis checks).
    Preference order: largest world first, then non-pipeline axes
    before the pipeline axis (growing DP adds replicas without moving
    any stage; growing PP remaps stages and regroups every collective),
    then the rightmost (fastest-varying) axis.

    Ranks are logical: the grown mesh numbers them densely and the
    caller maps them onto physical devices (survivors keep their slots,
    replacements fill the new ones)."""
    mesh = strategy.mesh
    if mesh is None:
        raise RegrowthError(
            "cannot grow a mesh-less strategy (legacy RawDirectives "
            "shim) — elastic regrowth needs structured fragments")
    n_ranks = int(n_ranks)
    if n_ranks <= mesh.n_devices:
        raise RegrowthError(
            f"nothing to grow: {n_ranks} ranks <= world "
            f"{mesh.n_devices}")
    pipe = strategy.pipeline
    pp_axis = pipe.axis if pipe is not None else None
    names = list(mesh.axis_names)
    candidates = []
    for pos, name in enumerate(names):
        old = mesh[name]
        pref = 1 if name == pp_axis else 0
        tie = len(names) - 1 - pos
        # largest growth first; stop at the size where the world no
        # longer fits the available ranks
        for size in range(old + 1, n_ranks + 1):
            m = mesh.resized(name, size)
            if m.n_devices > n_ranks:
                break
            try:
                strat = strategy.for_mesh(m)
            except StrategyError:
                continue
            candidates.append(
                ((-m.n_devices, pref, -tie), name, m, strat))
    if not candidates:
        raise RegrowthError(
            f"no valid grown mesh for {n_ranks} ranks over {mesh!r} — "
            f"no single-axis increase satisfies the strategy's "
            f"fragments")
    candidates.sort(key=lambda c: c[0])
    _, axis, new_mesh, strat = candidates[0]
    return GrowthPlan(old_mesh=mesh, new_mesh=new_mesh, strategy=strat,
                      grown_axis=axis)


@dataclass
class GrowthReport:
    """One regrowth's accounting — the mirror of
    ``elastic.RecoveryReport``.  ``steps_lost`` is 0 when the regrowth
    lands on a checkpoint boundary with live params (the normal case:
    nothing is redone, the world just widens)."""
    step: int
    old_world: int
    new_world: int
    grown_axis: str
    arrivals: tuple
    steps_lost: int
    recovery_seconds: float
    compile_seconds: float
    cache_hit: bool

    def to_dict(self) -> dict:
        d = dict(self.__dict__)
        d["arrivals"] = list(self.arrivals)
        return d


__all__ = ["GrowthPlan", "GrowthReport", "RegrowthError",
           "grow_for_arrivals"]
