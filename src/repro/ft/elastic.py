"""Elastic fault tolerance for the strategy compiler (DESIGN.md §13).

When a rank dies mid-run, the supervisor does not wait for a
replacement: it *shrinks the world*.  The pieces, in order:

  1. ``shrink_for_survivors`` — derive the largest valid ``Mesh`` that
     fits the surviving ranks by shrinking exactly ONE axis of the old
     mesh (data-parallel axes preferred; the pipeline axis only when
     the pinned stage count still divides the new degree).  Candidate
     validity is decided by ``Strategy.for_mesh`` — the same fragment
     validation the compiler runs, so the planner can never propose a
     mesh the compiler would reject.
  2. ``CompiledProgram.recompile`` — re-lower the SAME traced model
     under the re-targeted strategy (plan compilation as a runtime
     event), warmed by a plan cache keyed on the strategy document so a
     repeat failure at the same world size costs zero compiles.
  3. restore — params/optimizer state from the last async checkpoint
     (run through the ZeRO shard remap codec when the DP degree
     changed), data-stream position from the same checkpoint, asserted
     against the checkpoint step (``check_stream_position``).
  4. resume — a fresh runner over the surviving *physical* devices,
     reporting steps-lost-per-failure and recovery wall time
     (``RecoveryReport``).

The parity contract (tests/test_elastic.py): a run that fails and
elastically resumes produces, from the resume step onward, bit-exact
fp64 losses and final params versus an uninterrupted run that restores
the same checkpoint directly onto the shrunk mesh.  Shrinking DP
changes gradient summation order, so parity is defined from the shared
checkpoint — not across the mesh change.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Optional, Sequence

import jax

from ..checkpoint import (CheckpointManager, CorruptCheckpointError,
                          reshard_tree)
from ..core.compiler import CompiledProgram
from ..core.strategy import Mesh, Strategy, StrategyError
# the exception root + unified injectors live in ft.chaos (PR 7);
# RankFailure / RankFailureInjector are re-exported here so existing
# `from repro.ft.elastic import RankFailure` callers keep working
from .chaos import (ChaosInjector, ChaosReport, FaultSchedule,
                    NumericalFailure, RankFailure, RankFailureInjector,
                    WorkerFailure, check_numerics, corrupt_latest)
from .regrow import GrowthPlan, GrowthReport, RegrowthError, \
    grow_for_arrivals
from .supervisor import StragglerWatchdog, check_stream_position


class ElasticError(RuntimeError):
    """Elastic recovery could not proceed (no valid shrunk mesh, failure
    budget exhausted, or an inconsistent checkpoint)."""


# ---------------------------------------------------------------------------
# Mesh-shrink planning
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ElasticPlan:
    """The planner's output: where the world shrank and the re-targeted
    strategy to recompile."""
    old_mesh: Mesh
    new_mesh: Mesh
    strategy: Strategy
    survivors: tuple[int, ...]
    shrunk_axis: str


def shrink_for_survivors(strategy: Strategy,
                         survivors: Sequence[int]) -> ElasticPlan:
    """Derive the best shrunk mesh for ``survivors`` (logical rank ids
    of the old mesh that are still alive).

    Policy: shrink exactly one axis.  Candidates are every
    ``axis -> size`` reduction whose world fits the survivor count and
    whose re-targeted strategy validates (``Strategy.for_mesh`` — stage
    divisibility, dualpipev's S == 2*pp pin, fragment axis checks).
    Preference order: largest surviving world first, then non-pipeline
    axes before the pipeline axis (shrinking DP keeps the per-rank
    stage placement intact; shrinking PP remaps stages and regroups
    every collective), then the rightmost (fastest-varying) axis.

    The plan depends only on ``len(survivors)``: ranks are logical, the
    shrunk mesh renumbers them densely, and the caller maps logical
    ranks onto surviving *physical* devices.
    """
    mesh = strategy.mesh
    if mesh is None:
        raise ElasticError(
            "cannot shrink a mesh-less strategy (legacy RawDirectives "
            "shim) — elastic recovery needs structured fragments")
    n_survive = len(set(int(r) for r in survivors))
    if n_survive < 1:
        raise ElasticError("no surviving ranks")
    if n_survive >= mesh.n_devices:
        raise ElasticError(
            f"nothing to shrink: {n_survive} survivors >= world "
            f"{mesh.n_devices}")
    pipe = strategy.pipeline
    pp_axis = pipe.axis if pipe is not None else None
    names = list(mesh.axis_names)
    candidates = []
    for pos, name in enumerate(names):
        old = mesh[name]
        pref = 1 if name == pp_axis else 0
        # rightmost axis wins ties: its groups are contiguous ranks, the
        # least disruptive renumbering
        tie = len(names) - 1 - pos
        for size in range(old - 1, 0, -1):
            m = mesh.resized(name, size)
            if m.n_devices > n_survive:
                continue
            try:
                strat = strategy.for_mesh(m)
            except StrategyError:
                continue
            candidates.append(
                ((-m.n_devices, pref, -tie), name, m, strat))
    if not candidates:
        raise ElasticError(
            f"no valid shrunk mesh for {n_survive} survivors of "
            f"{mesh!r} — no single-axis reduction satisfies the "
            f"strategy's fragments")
    candidates.sort(key=lambda c: c[0])
    _, axis, new_mesh, strat = candidates[0]
    return ElasticPlan(old_mesh=mesh, new_mesh=new_mesh, strategy=strat,
                       survivors=tuple(sorted(set(int(r)
                                                  for r in survivors))),
                       shrunk_axis=axis)


def zero_shard_degree(strategy: Strategy) -> int:
    """The ZeRO shard degree a checkpoint written under ``strategy``
    implies: the DP width when params/grads are sharded (stage >= 2),
    else 1 (full replicas; nothing to remap)."""
    z = strategy.zero
    if z is None or z.stage < 2 or strategy.mesh is None:
        return 1
    return strategy.mesh[z.axis]


def sgd_update(lr: float = 0.05) -> Callable:
    """A tiny deterministic optimizer for the supervision loop/tests:
    ``update(params, grads, step) -> params`` doing per-bucket SGD.
    fp64-reproducible by construction (pure tree_map, no RNG)."""
    def update(params: dict[str, Any], grads: dict[str, Any],
               step: int) -> dict[str, Any]:
        out = dict(params)
        for bucket, g in grads.items():
            out[bucket] = jax.tree_util.tree_map(
                lambda p, gg: p - lr * gg, params[bucket], g)
        return out
    return update


# ---------------------------------------------------------------------------
# Elastic supervisor
# ---------------------------------------------------------------------------

@dataclass
class RecoveryReport:
    """One failure's accounting, appended to
    ``ElasticSupervisor.reports``.  ``steps_lost`` is the work redone:
    steps completed after the restored checkpoint and before the
    failure (bounded by the checkpoint interval)."""
    step_failed: int
    resume_step: int
    steps_lost: int
    recovery_seconds: float
    compile_seconds: float
    cache_hit: bool
    old_world: int
    new_world: int
    failed_rank: int
    shrunk_axis: str

    def to_dict(self) -> dict:
        return dict(self.__dict__)


@dataclass
class RebalanceReport:
    """One mid-run microbatch rebalance: the supervisor consumed its own
    ``rebalance_proposal()`` as a recompile at a checkpoint boundary.
    Numerics-neutral by construction (``Pipeline.mb_split`` is
    scheduling metadata), so no steps are lost."""
    step: int
    split: dict
    slowdowns: dict
    compile_seconds: float
    cache_hit: bool

    def to_dict(self) -> dict:
        return {"step": self.step,
                "split": {int(k): int(v) for k, v in self.split.items()},
                "slowdowns": {int(k): float(v)
                              for k, v in self.slowdowns.items()},
                "compile_seconds": self.compile_seconds,
                "cache_hit": self.cache_hit}


class ElasticSupervisor:
    """GlobalPlan-aware fault-tolerant training loop.

    Unlike ``Supervisor`` (which re-runs a fixed step function), this
    owns the compiled program: on a ``WorkerFailure`` it re-plans the
    mesh for the survivors, recompiles the strategy, remaps checkpoint
    shards across the ZeRO degree change, restores the data stream, and
    rebuilds the runner on the surviving physical devices.

    ``runner_factory(prog, params, physical_devices)`` builds the
    executor.  ``runtime.executor.executor_factory(name)`` produces a
    factory in exactly this shape for any registered backend —
    ``"spmd"``/``"mpmd"`` in real runs, ``"reference"`` in fast tests
    (the interpreter ignores ``physical_devices``).  The runner
    contract is the registry's ``Executor`` protocol: ``run(batch)``
    returns an object with ``.loss`` and ``.grads``, and assigning
    ``runner.params`` swaps weights without retracing.
    """

    def __init__(self, prog: CompiledProgram, ckpt: CheckpointManager,
                 loader, *, runner_factory: Callable,
                 update: Optional[Callable] = None,
                 checkpoint_every: int = 10,
                 injector: Optional[ChaosInjector] = None,
                 watchdog: Optional[StragglerWatchdog] = None,
                 max_failures: int = 4,
                 health_check: bool = True,
                 rebalance: bool = False,
                 rebalance_patience: int = 2,
                 rebalance_cooldown: Optional[int] = None) -> None:
        if prog.strategy is None or prog.strategy.mesh is None:
            raise ElasticError(
                "ElasticSupervisor needs a program compiled from a "
                "meshed Strategy (compile_training(strategy=...))")
        self.prog = prog
        self.strategy = prog.strategy
        self.ckpt = ckpt
        self.loader = loader
        self.runner_factory = runner_factory
        self.update = update or sgd_update()
        self.every = int(checkpoint_every)
        self.injector = injector
        self.watchdog = watchdog or StragglerWatchdog()
        self.max_failures = max_failures
        self.health_check = bool(health_check)
        self.rebalance = bool(rebalance)
        self.rebalance_patience = int(rebalance_patience)
        # default cooldown: one checkpoint interval — at most one
        # recompile per boundary even under a persistently noisy EMA
        self.rebalance_cooldown = (int(rebalance_cooldown)
                                   if rebalance_cooldown is not None
                                   else self.every)
        self.failures = 0
        self.world = self.strategy.mesh.n_devices
        # logical rank -> physical device index; recovery drops the dead
        # physical device and keeps a dense logical numbering
        self.physical: list[int] = list(range(self.world))
        # standby pool: spare physical devices a shrink idled plus any
        # scripted/real arrivals — regrowth draws from here
        self.standby: list[int] = []
        # plan cache: strategy document -> compiled program, so a repeat
        # failure at an already-seen world size skips the compile
        self._compiled: dict[str, CompiledProgram] = {
            self.strategy.to_json(): prog}
        self.history: list[dict] = []
        self.reports: list[RecoveryReport] = []
        self.growths: list[GrowthReport] = []
        self.rebalances: list[RebalanceReport] = []
        self.numeric_rewinds = 0
        self.corrupt_detected = 0
        self.corrupt_skipped_steps: list[int] = []
        # rebalance hysteresis: a proposal must persist this many
        # consecutive checkpoint boundaries before we act on it
        self._rb_streak = 0
        self._rb_pending: Optional[dict] = None
        self._rb_last_step = -10 ** 9

    # -- plan cache ---------------------------------------------------------
    def prewarm(self, n_failures: int = 1) -> int:
        """Pre-compile the plans the next ``n_failures`` single-rank
        losses would need, so recovery pays only restore time.  Returns
        the number of programs compiled."""
        compiled = 0
        strat = self.strategy
        world = strat.mesh.n_devices
        for _ in range(n_failures):
            if world <= 1:
                break
            try:
                plan = shrink_for_survivors(strat, range(world - 1))
            except ElasticError:
                break
            key = plan.strategy.to_json()
            if key not in self._compiled:
                self._compiled[key] = self.prog.recompile(
                    strategy=plan.strategy)
                compiled += 1
            strat = plan.strategy
            world = strat.mesh.n_devices
        return compiled

    def rebalance_proposal(self) -> Optional[dict[int, int]]:
        """Straggler-aware microbatch split for the current pipeline
        n_mb, from the watchdog's per-rank EMAs (None when no Pipeline
        fragment or no observations)."""
        pipe = self.strategy.pipeline
        if pipe is None:
            return None
        slow = self.watchdog.slowdowns()
        if not slow:
            return None
        from ..tune.rebalance import rebalance_microbatches
        return rebalance_microbatches(pipe.n_mb, slow)

    # -- main loop ----------------------------------------------------------
    def run(self, params: dict[str, Any], n_steps: int,
            log_every: int = 0) -> dict[str, Any]:
        """Train ``n_steps``; returns the final params.  Losses land in
        ``self.history`` (one record per completed step; records after a
        rewind shadow the lost ones — last write per step wins)."""
        runner = self.runner_factory(self.prog, params,
                                     tuple(self.physical))
        step = 0
        init_params = params
        init_loader_state = dict(self.loader.state_dict())
        while step < n_steps:
            try:
                if self.injector is not None:
                    self.injector.check(step)
                    arrived = self._injected_arrivals(step)
                    if arrived:
                        params, runner = self._regrow(step, arrived,
                                                      params, runner)
                batch = self.loader.next_batch()
                t0 = time.time()
                res = runner.run(batch)
                dt = time.time() - t0
                grads = res.grads
                if self.injector is not None and \
                        hasattr(self.injector, "poison_grads"):
                    grads, _ = self.injector.poison_grads(step, grads)
                if self.health_check:
                    # sentinel BEFORE the optimizer boundary: a
                    # non-finite loss/grad must never touch the weights
                    check_numerics(step, res.loss, grads)
                params = self.update(params, grads, step)
                runner.params = params
                self.watchdog.observe(step, dt)
                self._observe_ranks(step, dt)
                step += 1
                self.history.append({"step": step,
                                     "loss": float(res.loss),
                                     "dt": dt, "world": self.world})
                if log_every and step % log_every == 0:
                    print(f"  step {step}: loss={float(res.loss):.4f} "
                          f"world={self.world}", flush=True)
                if step % self.every == 0 or step == n_steps:
                    self.ckpt.save(
                        step, {"params": params},
                        extra={"data": self.loader.state_dict(),
                               "strategy": self.strategy.to_json(),
                               "world": self.world,
                               "zero_shards":
                                   zero_shard_degree(self.strategy)})
                    self._injected_corruptions(step)
                    if self.rebalance and step != n_steps:
                        new = self._maybe_rebalance(step, params)
                        if new is not None:
                            runner = new
            except NumericalFailure as e:
                # rewind-only: the world is intact, the weights are not
                params, runner, step = self._rewind(
                    e, step, params, runner, init_params,
                    init_loader_state)
            except WorkerFailure as e:
                params, runner, step = self._recover(
                    e, step, params, init_params, init_loader_state)
        self.ckpt.wait()
        return params

    def _injected_arrivals(self, step: int) -> list:
        if hasattr(self.injector, "arrivals"):
            return list(self.injector.arrivals(step))
        return []

    def _injected_corruptions(self, step: int) -> None:
        """Execute scripted checkpoint bit-rot (the fault itself, not
        its detection — restore's digest check is what must catch it)."""
        if not hasattr(self.injector, "corruptions"):
            return
        for ev in self.injector.corruptions(step):
            self.ckpt.wait()
            corrupted = corrupt_latest(
                self.ckpt, flips=ev.flips,
                seed=getattr(self.injector, "schedule",
                             FaultSchedule()).seed)
            print(f"  [chaos] corrupted checkpoint step_{corrupted} "
                  f"({ev.flips} byte flips)", flush=True)

    def _observe_ranks(self, step: int, dt: float) -> None:
        """Feed per-rank wall-clock into the watchdog; a scripted
        straggle window inflates its rank's observed time (the detection
        path is the watchdog's own median-of-others EMA logic)."""
        delay = getattr(self.injector, "delay_factor", None)
        if delay is None:
            return
        for rank in range(self.world):
            self.watchdog.observe_rank(rank, step,
                                       dt * delay(rank, step))

    # -- recovery -----------------------------------------------------------
    def _recover(self, failure: WorkerFailure, step_failed: int,
                 live_params: dict[str, Any],
                 init_params: dict[str, Any],
                 init_loader_state: dict) -> tuple:
        self.failures += 1
        if self.failures > self.max_failures:
            raise ElasticError(
                f"failure budget exhausted ({self.max_failures}); "
                f"last: {failure}") from failure
        t_start = time.time()
        failed_rank = getattr(failure, "rank", self.world - 1)
        if not 0 <= failed_rank < self.world:
            raise ElasticError(
                f"failed rank {failed_rank} outside world {self.world}")
        old_world = self.world
        old_strategy = self.strategy
        survivors = [r for r in range(old_world) if r != failed_rank]

        # 1. re-plan the mesh for the survivors
        plan = shrink_for_survivors(old_strategy, survivors)
        new_world = plan.new_mesh.n_devices

        # 2. recompile (or hit the plan cache)
        key = plan.strategy.to_json()
        cache_hit = key in self._compiled
        t_c = time.time()
        if not cache_hit:
            self._compiled[key] = self.prog.recompile(
                strategy=plan.strategy)
        compile_seconds = 0.0 if cache_hit else time.time() - t_c
        new_prog = self._compiled[key]

        # surviving physical devices, in rank order; the shrunk world
        # takes the first new_world of them (dense logical renumbering)
        # and the rest join the standby pool for a later regrowth
        alive = [p for i, p in enumerate(self.physical)
                 if i != failed_rank]
        new_phys = alive[:new_world]
        spares = alive[new_world:]

        # 3. restore params + stream position from the newest GOOD
        # checkpoint (corrupt ones are detected by the manifest digest
        # and skipped)
        restored = self._restore_latest(live_params)
        if restored is None:
            params = init_params
            self.loader.load_state_dict(dict(init_loader_state))
            resume = 0
        else:
            state, extra = restored
            resume = check_stream_position(extra)
            self.loader.load_state_dict(extra["data"])
            params = state["params"]
            old_deg = int(extra.get("zero_shards", 1))
            new_deg = zero_shard_degree(plan.strategy)
            if old_deg != new_deg:
                # regather the old ZeRO shards and re-slice for the new
                # DP width — bit-exact by the codec's verify pass
                params = reshard_tree(params, old_deg, new_deg)

        # 4. resume on the shrunk world
        self.strategy = plan.strategy
        self.world = new_world
        self.physical = new_phys
        self.standby.extend(spares)
        self.watchdog.reset_ranks()
        self._rb_streak, self._rb_pending = 0, None
        runner = self.runner_factory(new_prog, params, tuple(new_phys))
        report = RecoveryReport(
            step_failed=step_failed, resume_step=resume,
            steps_lost=step_failed - resume,
            recovery_seconds=time.time() - t_start,
            compile_seconds=compile_seconds, cache_hit=cache_hit,
            old_world=old_world, new_world=new_world,
            failed_rank=failed_rank, shrunk_axis=plan.shrunk_axis)
        self.reports.append(report)
        print(f"  [elastic] {failure} — world {old_world}->{new_world} "
              f"(shrunk {plan.shrunk_axis}), resumed at step {resume} "
              f"({report.steps_lost} steps lost, "
              f"{report.recovery_seconds:.2f}s"
              f"{', plan cache hit' if cache_hit else ''})", flush=True)
        return params, runner, resume

    def _restore_latest(self, live_params: dict[str, Any]):
        """Restore the newest checkpoint that passes integrity
        verification, skipping (and recording) corrupt ones.  Returns
        ``(state, extra)`` or None when no good checkpoint exists."""
        self.ckpt.wait()       # an async write may still be in flight
        for step in reversed(self.ckpt.steps()):
            try:
                # restore against the LIVE params tree: its leaves are
                # the concrete arrays whose dtypes were saved.
                # ``prog.params`` may hold abstract proxy specs (e.g.
                # bfloat16 avals) that numpy cannot cast a loaded array
                # into.
                return self.ckpt.restore({"params": live_params},
                                         step=step)
            except CorruptCheckpointError as e:
                self.corrupt_detected += 1
                self.corrupt_skipped_steps.append(step)
                print(f"  [elastic] checkpoint step_{step} failed "
                      f"integrity check ({e}) — falling back to the "
                      f"previous one", flush=True)
        return None

    # -- regrowth -----------------------------------------------------------
    def _regrow(self, step: int, arrived: Sequence[int],
                params: dict[str, Any], runner) -> tuple:
        """Grow the world onto survivors + standby + ``arrived``
        devices.  Params are LIVE (no restore, no lost steps): the same
        weights are resharded UP across the ZeRO degree change and the
        runner is rebuilt on the wider device set.  When no larger mesh
        validates, the arrivals just join the standby pool."""
        self.standby.extend(int(d) for d in arrived)
        t_start = time.time()
        old_world = self.world
        n_avail = old_world + len(self.standby)
        try:
            plan = grow_for_arrivals(self.strategy, n_avail)
        except RegrowthError:
            print(f"  [elastic] {len(arrived)} arrival(s) at step "
                  f"{step} banked in standby (no larger valid mesh for "
                  f"{n_avail} ranks)", flush=True)
            return params, runner
        new_world = plan.new_mesh.n_devices

        key = plan.strategy.to_json()
        cache_hit = key in self._compiled
        t_c = time.time()
        if not cache_hit:
            self._compiled[key] = self.prog.recompile(
                strategy=plan.strategy)
        compile_seconds = 0.0 if cache_hit else time.time() - t_c
        new_prog = self._compiled[key]

        # survivors keep their slots; replacements fill the new ranks
        needed = new_world - old_world
        new_phys = list(self.physical) + self.standby[:needed]
        self.standby = self.standby[needed:]

        old_deg = zero_shard_degree(self.strategy)
        new_deg = zero_shard_degree(plan.strategy)
        if old_deg != new_deg:
            # remap ZeRO shards UP in DP degree — the same bit-exact
            # codec that mapped them down at shrink time
            params = reshard_tree(params, old_deg, new_deg)

        self.strategy = plan.strategy
        self.world = new_world
        self.physical = new_phys
        self.watchdog.reset_ranks()
        self._rb_streak, self._rb_pending = 0, None
        runner = self.runner_factory(new_prog, params, tuple(new_phys))
        report = GrowthReport(
            step=step, old_world=old_world, new_world=new_world,
            grown_axis=plan.grown_axis,
            arrivals=tuple(int(d) for d in arrived), steps_lost=0,
            recovery_seconds=time.time() - t_start,
            compile_seconds=compile_seconds, cache_hit=cache_hit)
        self.growths.append(report)
        print(f"  [elastic] arrivals {list(arrived)} at step {step} — "
              f"world {old_world}->{new_world} (grew "
              f"{plan.grown_axis}), 0 steps lost"
              f"{', plan cache hit' if cache_hit else ''}", flush=True)
        return params, runner

    # -- numerical rewind ---------------------------------------------------
    def _rewind(self, failure: NumericalFailure, step_failed: int,
                live_params: dict[str, Any], runner,
                init_params: dict[str, Any],
                init_loader_state: dict) -> tuple:
        """Rewind-only recovery for a tripped numerics sentinel: same
        mesh, same program — restore the newest good checkpoint (the
        poisoned update never reached the weights, but the weights that
        PRODUCED the spike are suspect, so we rewind rather than
        retry)."""
        self.failures += 1
        if self.failures > self.max_failures:
            raise ElasticError(
                f"failure budget exhausted ({self.max_failures}); "
                f"last: {failure}") from failure
        self.numeric_rewinds += 1
        t_start = time.time()
        restored = self._restore_latest(live_params)
        if restored is None:
            params = init_params
            self.loader.load_state_dict(dict(init_loader_state))
            resume = 0
        else:
            state, extra = restored
            resume = check_stream_position(extra)
            self.loader.load_state_dict(extra["data"])
            params = state["params"]
        runner.params = params
        report = RecoveryReport(
            step_failed=step_failed, resume_step=resume,
            steps_lost=step_failed - resume,
            recovery_seconds=time.time() - t_start,
            compile_seconds=0.0, cache_hit=True,
            old_world=self.world, new_world=self.world,
            failed_rank=-1, shrunk_axis="")
        self.reports.append(report)
        print(f"  [elastic] {failure} — rewound to step {resume} on "
              f"the same mesh ({report.steps_lost} steps lost)",
              flush=True)
        return params, runner, resume

    # -- mid-run rebalance --------------------------------------------------
    def _maybe_rebalance(self, step: int, params: dict[str, Any]):
        """Consume ``rebalance_proposal()`` at a checkpoint boundary:
        recompile with the proposed per-rank microbatch split
        (``Pipeline.mb_split`` — scheduling metadata, numerics
        bit-identical).

        Hysteresis: act only when a proposal that differs from the
        current split has persisted ``rebalance_patience`` consecutive
        boundaries AND ``rebalance_cooldown`` steps have passed since
        the last rebalance — an oscillating EMA can therefore never
        thrash recompiles.  A proposal equal to the canonical
        healthy-fleet split reverts an applied split (back to
        ``mb_split=None``) under the same hysteresis.  Returns the new
        runner, or None when nothing changed."""
        proposal = self.rebalance_proposal()
        pipe = self.strategy.pipeline
        if proposal is None or pipe is None:
            self._rb_streak, self._rb_pending = 0, None
            return None
        current = pipe.mb_split_dict()
        # the on-pace test compares against the CANONICAL healthy-fleet
        # split, not "all counts equal": with n_mb < world the canonical
        # split necessarily leaves some ranks at 0, and misreading it as
        # a skew would recompile healthy fleets forever.  A proposal
        # equal to the canonical split means revert (mb_split=None) if a
        # split is applied, else nothing.
        from ..tune.rebalance import rebalance_microbatches
        canonical = rebalance_microbatches(
            pipe.n_mb, {r: 1.0 for r in proposal})
        effective = None if proposal == canonical else dict(proposal)
        if effective == current:
            # on-pace (or already applied) — decay the streak
            self._rb_streak, self._rb_pending = 0, None
            return None
        if effective == self._rb_pending:
            self._rb_streak += 1
        else:
            self._rb_pending = effective
            self._rb_streak = 1
        if self._rb_streak < self.rebalance_patience:
            return None
        if step - self._rb_last_step < self.rebalance_cooldown:
            return None

        import dataclasses
        import os
        new_pipe = dataclasses.replace(pipe, mb_split=effective)
        new_strategy = self.strategy.replacing(new_pipe).validate()
        key = new_strategy.to_json()
        cache_hit = key in self._compiled
        t_c = time.time()
        if not cache_hit:
            self._compiled[key] = self.prog.recompile(
                strategy=new_strategy)
            # translation-validate the rebalance recompile: mb_split is
            # scheduling metadata (which rank runs which microbatch), so
            # the recompiled plan must carry the exact same dataflow as
            # the plan it replaces — certified like any compiler pass
            # (PIPER026) when pass checking is on.  Baseline is the
            # program currently running this mesh (after a shrink or
            # regrowth ``self.prog`` is the original-mesh build).
            if os.environ.get("REPRO_CHECK_PASSES", "") not in ("", "0"):
                from ..analysis import AnalysisReport, PlanVerificationError
                from ..analysis.equiv import (certify_equivalent,
                                              dataflow_fingerprint_safe)
                running = self._compiled.get(self.strategy.to_json(),
                                             self.prog)
                diags = certify_equivalent(
                    dataflow_fingerprint_safe(running.dag),
                    dataflow_fingerprint_safe(self._compiled[key].dag),
                    f"Pipeline(mb_split={effective})")
                if diags:
                    del self._compiled[key]
                    raise PlanVerificationError(AnalysisReport(
                        diagnostics=diags,
                        meta={"phase": "rebalance-recompile",
                              "step": step}))
        compile_seconds = 0.0 if cache_hit else time.time() - t_c
        self.strategy = new_strategy
        self._rb_last_step = step
        self._rb_streak, self._rb_pending = 0, None
        runner = self.runner_factory(self._compiled[key], params,
                                     tuple(self.physical))
        # an empty split records a reversion: the fleet returned to pace
        # and the default schedule was recompiled back in
        report = RebalanceReport(
            step=step, split=effective or {},
            slowdowns=self.watchdog.slowdowns(),
            compile_seconds=compile_seconds, cache_hit=cache_hit)
        self.rebalances.append(report)
        what = (f"rebalanced microbatches: {effective}"
                if effective is not None else
                "reverted microbatch split (fleet back on pace)")
        print(f"  [elastic] {what} at step {step} (slowdowns "
              f"{ {k: round(v, 2) for k, v in report.slowdowns.items()} })",
              flush=True)
        return runner

    # -- reporting ----------------------------------------------------------
    def chaos_report(self, steps: int,
                     wall_seconds: float = 0.0) -> ChaosReport:
        """Aggregate this run's fault accounting into a ``ChaosReport``
        (written to benchmarks/results/chaos/ by the soak harness)."""
        sched = getattr(self.injector, "schedule", None)
        return ChaosReport(
            schedule_seed=getattr(sched, "seed", 0),
            n_events=len(getattr(sched, "events", ())),
            kinds=sched.kinds() if sched is not None else {},
            steps=int(steps),
            final_world=self.world,
            final_mesh=repr(self.strategy.mesh),
            recoveries=[r.to_dict() for r in self.reports],
            growths=[g.to_dict() for g in self.growths],
            rebalances=[b.to_dict() for b in self.rebalances],
            numeric_rewinds=self.numeric_rewinds,
            corrupt_detected=self.corrupt_detected,
            corrupt_skipped_steps=list(self.corrupt_skipped_steps),
            steps_lost_total=sum(r.steps_lost for r in self.reports),
            wall_seconds=float(wall_seconds))


__all__ = ["ElasticError", "ElasticPlan", "ElasticSupervisor",
           "GrowthPlan", "GrowthReport", "RankFailure",
           "RankFailureInjector", "RebalanceReport", "RecoveryReport",
           "RegrowthError", "grow_for_arrivals", "shrink_for_survivors",
           "sgd_update", "zero_shard_degree"]
