"""Fault-tolerant training supervision (DESIGN.md §7, §13).

``Supervisor`` wraps a flat (single-plan) step function with:
  - periodic async checkpoints (params/opt state + data-pipeline state,
    so restarts resume the exact sample stream),
  - failure handling: on a (possibly injected) WorkerFailure the loop
    restores the last checkpoint and continues; repeated failures back
    off and eventually surface,
  - elastic restart hook: a callback rebuilds the step for a smaller
    DP degree when survivors < world (simulated on CPU by re-sharding
    the restored state onto the new mesh),
  - straggler watchdog: per-step wall-clock EMA; steps slower than
    ``threshold``x the EMA are recorded, and per-RANK EMAs feed the
    tuner's microbatch rebalancing (``tune.rebalance``).

The data stream position is part of the restart contract: checkpoints
persist the loader state, restores assert that the restored position
matches the checkpoint step, and a failure BEFORE the first checkpoint
rewinds the loader to its pristine state (a from-scratch restart that
silently kept the advanced stream would train on a different sample
order than a true cold start).

``ft.elastic.ElasticSupervisor`` is the GlobalPlan-aware sibling: it
recompiles the strategy for a shrunk mesh instead of merely rebuilding
a DP step function.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import numpy as np

from ..checkpoint import CheckpointManager
# exception root + unified injectors live in ft.chaos (PR 7); re-exported
# here so existing `from repro.ft.supervisor import WorkerFailure,
# FailureInjector` callers keep working
from .chaos import FailureInjector, WorkerFailure


class StreamPositionError(RuntimeError):
    """A restored checkpoint's data-stream position disagrees with its
    step — resuming would silently skip or replay samples."""


@dataclass
class StragglerWatchdog:
    """Wall-clock EMAs over step times.

    ``observe`` keeps the global per-step EMA (events = steps slower
    than ``threshold``x it).  ``observe_rank`` keeps one EMA per rank —
    the signal that, at real scale, drives the tuner's microbatch
    rebalancing: ``slowdowns()`` normalizes the per-rank EMAs by the
    fleet median and ``tune.rebalance.rebalance_microbatches`` turns
    that into a per-replica microbatch share."""
    threshold: float = 2.0
    ema: float = 0.0
    beta: float = 0.9
    events: list = field(default_factory=list)
    rank_ema: dict = field(default_factory=dict)
    rank_events: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = self.ema > 0 and dt > self.threshold * self.ema
        if is_straggler:
            self.events.append((step, dt, self.ema))
        # stragglers don't poison the baseline estimate
        self.ema = (self.beta * self.ema + (1 - self.beta) * dt
                    if self.ema else dt)
        return is_straggler

    def observe_rank(self, rank: int, step: int, dt: float) -> bool:
        """Update rank's EMA; a rank is a straggler when its step time
        exceeds ``threshold``x the median of the OTHER ranks' EMAs (its
        own past cannot normalize away a persistent slowdown)."""
        others = [v for r, v in self.rank_ema.items()
                  if r != rank and v > 0]
        ref = float(np.median(others)) if others else 0.0
        is_straggler = ref > 0 and dt > self.threshold * ref
        if is_straggler:
            self.rank_events.append((step, rank, dt, ref))
        prev = self.rank_ema.get(rank, 0.0)
        self.rank_ema[rank] = (self.beta * prev + (1 - self.beta) * dt
                               if prev else dt)
        return is_straggler

    def reset_ranks(self) -> None:
        """Drop the per-rank EMAs (the global step EMA survives).
        Called on every mesh change — rank ids are renumbered by a
        shrink/regrowth, so stale EMAs would attribute one world's
        slowdowns to another world's ranks."""
        self.rank_ema.clear()

    def slowdowns(self) -> dict[int, float]:
        """Per-rank EMA normalized by the fleet median — 1.0 is on-pace;
        the microbatch-rebalance hook's input."""
        if not self.rank_ema:
            return {}
        med = float(np.median(list(self.rank_ema.values())))
        if med <= 0:
            return {r: 1.0 for r in self.rank_ema}
        return {r: v / med for r, v in self.rank_ema.items()}


def check_stream_position(extra: dict) -> int:
    """Validate a checkpoint's persisted data-stream position against
    its step; returns the step.  Raises ``StreamPositionError`` when the
    loader state is missing or disagrees — both mean a resume would
    consume the wrong samples."""
    step = int(extra["step"])
    data = extra.get("data")
    if not isinstance(data, dict):
        raise StreamPositionError(
            f"checkpoint at step {step} carries no data-stream state; "
            "resuming would restart the sample stream at an arbitrary "
            "position")
    pos = data.get("step")
    if pos is None or int(pos) != step:
        raise StreamPositionError(
            f"checkpoint at step {step} persisted stream position "
            f"{pos!r} — the resumed run would skip or replay samples")
    return step


class Supervisor:
    def __init__(self, ckpt: CheckpointManager, loader,
                 checkpoint_every: int = 50,
                 injector: Optional[FailureInjector] = None,
                 watchdog: Optional[StragglerWatchdog] = None,
                 max_restarts: int = 5):
        self.ckpt = ckpt
        self.loader = loader
        self.every = checkpoint_every
        self.injector = injector
        self.watchdog = watchdog or StragglerWatchdog()
        self.max_restarts = max_restarts
        self.restarts = 0
        self.history: list[dict] = []

    def run(self, state, step_fn: Callable, n_steps: int,
            on_restore: Optional[Callable] = None,
            log_every: int = 10) -> Any:
        """Run ``n_steps`` with checkpoint/restart.  ``step_fn(state,
        batch) -> (state, metrics)``.  Returns the final state."""
        step = int(state["step"]) if "step" in state else 0
        # pristine restart snapshot: a failure BEFORE the first
        # checkpoint must rewind the data stream too (jnp leaves are
        # immutable, so keeping references is a faithful snapshot)
        init_state, init_step = state, step
        init_loader_state = dict(self.loader.state_dict())
        while step < n_steps:
            try:
                if self.injector:
                    self.injector.check(step)
                batch = self.loader.next_batch()
                t0 = time.time()
                state, metrics = step_fn(state, batch)
                dt = time.time() - t0
                self.watchdog.observe(step, dt)
                step += 1
                rec = {"step": step, "dt": dt,
                       **{k: float(v) for k, v in metrics.items()}}
                self.history.append(rec)
                if log_every and step % log_every == 0:
                    print(f"  step {step}: loss={rec.get('loss'):.4f} "
                          f"({dt*1e3:.0f} ms)", flush=True)
                if step % self.every == 0 or step == n_steps:
                    self.ckpt.save(step, state,
                                   extra={"data": self.loader.state_dict()})
            except WorkerFailure as e:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                print(f"  [ft] {e} — restoring last checkpoint "
                      f"(restart {self.restarts}/{self.max_restarts})",
                      flush=True)
                latest = self.ckpt.latest_step()
                if latest is None:
                    # no checkpoint yet: true from-scratch restart —
                    # model state AND stream position back to pristine
                    state = init_state
                    self.loader.load_state_dict(dict(init_loader_state))
                    step = init_step
                    continue
                state, extra = self.ckpt.restore(state)
                step = check_stream_position(extra)
                self.loader.load_state_dict(extra["data"])
                if on_restore is not None:
                    state = on_restore(state)
        self.ckpt.wait()
        return state
