"""Fault-tolerant training supervision (DESIGN.md §7).

``Supervisor`` wraps a step function with:
  - periodic async checkpoints (params/opt state + data-pipeline state,
    so restarts resume the exact sample stream),
  - failure handling: on a (possibly injected) WorkerFailure the loop
    restores the last checkpoint and continues; repeated failures back
    off and eventually surface,
  - elastic restart hook: a callback rebuilds the step for a smaller
    DP degree when survivors < world (simulated on CPU by re-sharding
    the restored state onto the new mesh),
  - straggler watchdog: per-step wall-clock EMA; steps slower than
    ``threshold``x the EMA are recorded (at real scale this signal
    drives microbatch rebalancing — benchmarked in the simulator).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from ..checkpoint import CheckpointManager


class WorkerFailure(RuntimeError):
    """A (simulated) lost worker / preemption."""


@dataclass
class FailureInjector:
    """Deterministically fail at the given global steps (once each)."""
    fail_at: tuple = ()
    _fired: set = field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            raise WorkerFailure(f"injected failure at step {step}")


@dataclass
class StragglerWatchdog:
    threshold: float = 2.0
    ema: float = 0.0
    beta: float = 0.9
    events: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = self.ema > 0 and dt > self.threshold * self.ema
        if is_straggler:
            self.events.append((step, dt, self.ema))
        # stragglers don't poison the baseline estimate
        self.ema = (self.beta * self.ema + (1 - self.beta) * dt
                    if self.ema else dt)
        return is_straggler


class Supervisor:
    def __init__(self, ckpt: CheckpointManager, loader,
                 checkpoint_every: int = 50,
                 injector: Optional[FailureInjector] = None,
                 watchdog: Optional[StragglerWatchdog] = None,
                 max_restarts: int = 5):
        self.ckpt = ckpt
        self.loader = loader
        self.every = checkpoint_every
        self.injector = injector
        self.watchdog = watchdog or StragglerWatchdog()
        self.max_restarts = max_restarts
        self.restarts = 0
        self.history: list[dict] = []

    def run(self, state, step_fn: Callable, n_steps: int,
            on_restore: Optional[Callable] = None,
            log_every: int = 10) -> Any:
        """Run ``n_steps`` with checkpoint/restart.  ``step_fn(state,
        batch) -> (state, metrics)``.  Returns the final state."""
        step = int(state["step"]) if "step" in state else 0
        while step < n_steps:
            try:
                if self.injector:
                    self.injector.check(step)
                batch = self.loader.next_batch()
                t0 = time.time()
                state, metrics = step_fn(state, batch)
                dt = time.time() - t0
                self.watchdog.observe(step, dt)
                step += 1
                rec = {"step": step, "dt": dt,
                       **{k: float(v) for k, v in metrics.items()}}
                self.history.append(rec)
                if log_every and step % log_every == 0:
                    print(f"  step {step}: loss={rec.get('loss'):.4f} "
                          f"({dt*1e3:.0f} ms)", flush=True)
                if step % self.every == 0 or step == n_steps:
                    self.ckpt.save(step, state,
                                   extra={"data": self.loader.state_dict()})
            except WorkerFailure as e:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                print(f"  [ft] {e} — restoring last checkpoint "
                      f"(restart {self.restarts}/{self.max_restarts})",
                      flush=True)
                latest = self.ckpt.latest_step()
                if latest is None:
                    # no checkpoint yet: restart from scratch
                    step = int(state.get("step", 0))
                    continue
                state, extra = self.ckpt.restore(state)
                self.loader.load_state_dict(extra["data"])
                if on_restore is not None:
                    state = on_restore(state)
                step = int(extra["step"])
        self.ckpt.wait()
        return state
