"""Fault tolerance: supervised training loop, programmable chaos
schedules, straggler watchdog, elastic mesh-shrink recovery + world
regrowth + mid-run rebalance (DESIGN.md §13-14)."""
from .chaos import (CHAOS_SCHEMA_VERSION, ChaosInjector, ChaosReport,
                    ChaosScheduleError, FaultEvent, FaultSchedule,
                    NumericalFailure, check_numerics, corrupt_latest)
from .elastic import (ElasticError, ElasticPlan, ElasticSupervisor,
                      RankFailure, RankFailureInjector, RebalanceReport,
                      RecoveryReport, shrink_for_survivors, sgd_update,
                      zero_shard_degree)
from .regrow import (GrowthPlan, GrowthReport, RegrowthError,
                     grow_for_arrivals)
from .supervisor import (FailureInjector, StragglerWatchdog,
                         StreamPositionError, Supervisor, WorkerFailure,
                         check_stream_position)

__all__ = ["CHAOS_SCHEMA_VERSION", "ChaosInjector", "ChaosReport",
           "ChaosScheduleError", "ElasticError", "ElasticPlan",
           "ElasticSupervisor", "FailureInjector", "FaultEvent",
           "FaultSchedule", "GrowthPlan", "GrowthReport",
           "NumericalFailure", "RankFailure", "RankFailureInjector",
           "RebalanceReport", "RecoveryReport", "RegrowthError",
           "StragglerWatchdog", "StreamPositionError", "Supervisor",
           "WorkerFailure", "check_numerics", "check_stream_position",
           "corrupt_latest", "grow_for_arrivals", "shrink_for_survivors",
           "sgd_update", "zero_shard_degree"]
