"""Fault tolerance: supervised training loop, failure injection,
straggler watchdog, elastic mesh-shrink recovery (DESIGN.md §13)."""
from .elastic import (ElasticError, ElasticPlan, ElasticSupervisor,
                      RankFailure, RankFailureInjector, RecoveryReport,
                      shrink_for_survivors, sgd_update, zero_shard_degree)
from .supervisor import (FailureInjector, StragglerWatchdog,
                         StreamPositionError, Supervisor, WorkerFailure,
                         check_stream_position)

__all__ = ["ElasticError", "ElasticPlan", "ElasticSupervisor",
           "FailureInjector", "RankFailure", "RankFailureInjector",
           "RecoveryReport", "StragglerWatchdog", "StreamPositionError",
           "Supervisor", "WorkerFailure", "check_stream_position",
           "shrink_for_survivors", "sgd_update", "zero_shard_degree"]
