"""Fault tolerance: supervised training loop, failure injection,
straggler watchdog, elastic restart."""
from .supervisor import FailureInjector, StragglerWatchdog, Supervisor

__all__ = ["FailureInjector", "StragglerWatchdog", "Supervisor"]
