"""Programmable chaos schedules for the elastic subsystem (DESIGN.md
§14).

A ``FaultSchedule`` is to faults what ``core.strategy.Strategy`` is to
parallelism: a seeded, serializable document (byte-stable JSON round
trip, schema-versioned, unknown kinds/fields rejected) that scripts
every fault the harness can inject:

  - ``kill``      — lose a rank (or an anonymous worker) at a step
  - ``arrive``    — replacement physical devices join the standby pool
  - ``straggle``  — a rank runs ``factor``x slow for ``duration`` steps
                    (the ``StragglerWatchdog`` must detect it and the
                    supervisor must rebalance microbatches)
  - ``corrupt``   — flip bytes in the newest on-disk checkpoint (the
                    manifest digest must catch it on restore)
  - ``nan_spike`` — poison one gradient leaf with NaN (the numerical
                    health sentinel must trip and rewind)

``ChaosInjector`` executes a schedule against the supervisor's step
loop.  Kill/arrive/corrupt/nan events fire once — a post-rewind replay
through the same step must not re-raise them — while straggle windows
are stateless functions of (rank, step), so replayed steps are slowed
consistently.

This module is also the exception root for the ft package
(``WorkerFailure`` / ``RankFailure`` / ``NumericalFailure`` live here;
``supervisor``/``elastic`` re-export them), and the two legacy
injectors (``FailureInjector``, ``RankFailureInjector``) are thin
aliases over ``ChaosInjector`` kept for existing callers.
"""
from __future__ import annotations

import json
import random as _random
from dataclasses import dataclass, field, fields
from typing import Optional, Sequence

import numpy as np

CHAOS_SCHEMA_VERSION = 1

FAULT_KINDS = ("kill", "arrive", "straggle", "corrupt", "nan_spike")


# ---------------------------------------------------------------------------
# Failures (exception root for the ft package)
# ---------------------------------------------------------------------------

class WorkerFailure(RuntimeError):
    """A (simulated) lost worker / preemption."""


class RankFailure(WorkerFailure):
    """A specific rank died (vs. the anonymous ``WorkerFailure``)."""

    def __init__(self, step: int, rank: int) -> None:
        super().__init__(f"rank {rank} lost at step {step}")
        self.step = step
        self.rank = rank


class NumericalFailure(WorkerFailure):
    """The numerical-health sentinel tripped: a non-finite loss or
    gradient reached the optimizer boundary.  Recovery is rewind-only —
    the world is intact, so the supervisor restores the last good
    checkpoint on the SAME mesh instead of shrinking."""

    def __init__(self, step: int, what: str) -> None:
        super().__init__(f"non-finite {what} at step {step}")
        self.step = step
        self.what = what


class ChaosScheduleError(ValueError):
    """A FaultSchedule document is malformed (unknown schema version,
    unknown kind, bad/missing fields)."""


# ---------------------------------------------------------------------------
# The schedule DSL
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FaultEvent:
    """One scripted fault.  Field use by kind:

    ==========  =====================================================
    kind        fields
    ==========  =====================================================
    kill        ``rank`` (None = anonymous ``WorkerFailure``)
    arrive      ``devices`` — physical device indices joining standby
    straggle    ``rank``, ``factor`` (>1), ``duration`` (steps)
    corrupt     ``flips`` — bytes to flip in the newest checkpoint
    nan_spike   (no extra fields)
    ==========  =====================================================
    """
    step: int
    kind: str
    rank: Optional[int] = None
    devices: tuple = ()
    factor: float = 1.0
    duration: int = 1
    flips: int = 8

    def __post_init__(self):
        object.__setattr__(self, "devices",
                           tuple(int(d) for d in self.devices))

    def validate(self) -> "FaultEvent":
        if self.kind not in FAULT_KINDS:
            raise ChaosScheduleError(
                f"event at step {self.step}: unknown kind "
                f"{self.kind!r} (kinds: {list(FAULT_KINDS)})")
        if self.step < 0:
            raise ChaosScheduleError(
                f"event {self.kind!r}: step must be >= 0")
        if self.kind == "arrive" and not self.devices:
            raise ChaosScheduleError(
                f"arrive at step {self.step}: needs at least one device")
        if self.kind == "straggle":
            if self.rank is None:
                raise ChaosScheduleError(
                    f"straggle at step {self.step}: needs a rank")
            if self.factor <= 1.0:
                raise ChaosScheduleError(
                    f"straggle at step {self.step}: factor must be > 1 "
                    f"(got {self.factor})")
            if self.duration < 1:
                raise ChaosScheduleError(
                    f"straggle at step {self.step}: duration must be "
                    f">= 1")
        if self.kind == "corrupt" and self.flips < 1:
            raise ChaosScheduleError(
                f"corrupt at step {self.step}: flips must be >= 1")
        return self

    def to_dict(self) -> dict:
        return {f.name: (list(v) if isinstance(v := getattr(self, f.name),
                                               tuple) else v)
                for f in fields(self)}

    @classmethod
    def from_dict(cls, d: dict) -> "FaultEvent":
        known = {f.name for f in fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ChaosScheduleError(
                f"event: unknown field(s) {sorted(unknown)} (schema "
                f"{CHAOS_SCHEMA_VERSION} knows {sorted(known)})")
        try:
            return cls(**d).validate()
        except TypeError as e:
            raise ChaosScheduleError(f"event: {e}") from None


@dataclass(frozen=True)
class FaultSchedule:
    """An ordered, seeded fault script.  ``seed`` keys any randomness a
    consumer derives (e.g. which bytes ``corrupt_latest`` flips), so a
    schedule document replays identically everywhere."""
    events: tuple = ()
    seed: int = 0

    def __post_init__(self):
        evs = tuple(sorted((e.validate() for e in self.events),
                           key=lambda e: (e.step, FAULT_KINDS.index(e.kind))))
        object.__setattr__(self, "events", evs)

    def events_at(self, step: int, kind: Optional[str] = None) -> list:
        return [e for e in self.events
                if e.step == step and (kind is None or e.kind == kind)]

    def kinds(self) -> dict:
        out: dict[str, int] = {}
        for e in self.events:
            out[e.kind] = out.get(e.kind, 0) + 1
        return out

    # -- serialization ------------------------------------------------------
    def to_json(self) -> str:
        doc = {"schema": CHAOS_SCHEMA_VERSION, "seed": self.seed,
               "events": [e.to_dict() for e in self.events]}
        return json.dumps(doc, sort_keys=True, separators=(",", ":"))

    @staticmethod
    def from_json(s: str) -> "FaultSchedule":
        try:
            doc = json.loads(s)
        except json.JSONDecodeError as e:
            raise ChaosScheduleError(f"not JSON: {e}") from None
        if not isinstance(doc, dict):
            raise ChaosScheduleError("schedule document must be an object")
        schema = doc.get("schema")
        if schema != CHAOS_SCHEMA_VERSION:
            raise ChaosScheduleError(
                f"unknown chaos schema {schema!r} (this build reads "
                f"{CHAOS_SCHEMA_VERSION})")
        unknown = set(doc) - {"schema", "seed", "events"}
        if unknown:
            raise ChaosScheduleError(
                f"unknown top-level field(s) {sorted(unknown)}")
        evs = tuple(FaultEvent.from_dict(d) for d in doc.get("events", []))
        return FaultSchedule(events=evs, seed=int(doc.get("seed", 0)))

    @classmethod
    def random(cls, seed: int, n_steps: int, world: int,
               kinds: Sequence[str] = FAULT_KINDS,
               n_events: int = 4) -> "FaultSchedule":
        """A seeded random schedule for soak grids: ``n_events`` faults
        drawn from ``kinds`` at distinct steps in ``[1, n_steps)``.
        Kill events pick a random rank and pair with a later arrival of
        the same count so the soak can regrow."""
        rng = _random.Random(seed)
        steps = rng.sample(range(1, max(2, n_steps)),
                           min(n_events, max(1, n_steps - 1)))
        events = []
        next_device = world
        for s in sorted(steps):
            kind = rng.choice(list(kinds))
            if kind == "kill":
                events.append(FaultEvent(step=s, kind="kill",
                                         rank=rng.randrange(world)))
                if s + 1 < n_steps:
                    events.append(FaultEvent(step=s + 1, kind="arrive",
                                             devices=(next_device,)))
                    next_device += 1
            elif kind == "arrive":
                events.append(FaultEvent(step=s, kind="arrive",
                                         devices=(next_device,)))
                next_device += 1
            elif kind == "straggle":
                events.append(FaultEvent(
                    step=s, kind="straggle", rank=rng.randrange(world),
                    factor=1.5 + 2.0 * rng.random(),
                    duration=rng.randint(2, 6)))
            elif kind == "corrupt":
                events.append(FaultEvent(step=s, kind="corrupt",
                                         flips=rng.randint(1, 16)))
            else:
                events.append(FaultEvent(step=s, kind="nan_spike"))
        return cls(events=tuple(events), seed=seed)


# ---------------------------------------------------------------------------
# The injector
# ---------------------------------------------------------------------------

class ChaosInjector:
    """Executes a ``FaultSchedule`` against a supervision loop.

    Kill / arrive / corrupt / nan events fire ONCE (tracked per event
    identity) — a rewind that replays the same steps must not re-raise
    them.  Straggle windows are stateless: ``delay_factor(rank, step)``
    is a pure function, so replayed steps see the same slowdown."""

    def __init__(self, schedule: FaultSchedule) -> None:
        self.schedule = schedule
        self._fired: set = set()

    def _once(self, ev: FaultEvent) -> bool:
        key = id(ev)
        if key in self._fired:
            return False
        self._fired.add(key)
        return True

    def check(self, step: int) -> None:
        """Raise the scripted failure for ``step``, if any (once)."""
        for ev in self.schedule.events_at(step, "kill"):
            if self._once(ev):
                if ev.rank is None:
                    raise WorkerFailure(
                        f"injected failure at step {step}")
                raise RankFailure(step, int(ev.rank))

    def arrivals(self, step: int) -> list:
        """Physical device indices arriving at ``step`` (each event
        reported once)."""
        out: list[int] = []
        for ev in self.schedule.events_at(step, "arrive"):
            if self._once(ev):
                out.extend(ev.devices)
        return out

    def delay_factor(self, rank: int, step: int) -> float:
        """Product of active straggle windows covering (rank, step);
        1.0 when on-pace.  Stateless — safe under replay."""
        f = 1.0
        for ev in self.schedule.events:
            if (ev.kind == "straggle" and ev.rank == rank
                    and ev.step <= step < ev.step + ev.duration):
                f *= ev.factor
        return f

    def poison_grads(self, step: int, grads):
        """Apply any scripted nan_spike at ``step`` (once): multiply the
        first gradient leaf by NaN.  Returns (grads, poisoned)."""
        for ev in self.schedule.events_at(step, "nan_spike"):
            if self._once(ev):
                leaves, treedef = _tree_flatten(grads)
                leaves = list(leaves)
                leaves[0] = leaves[0] * float("nan")
                return _tree_unflatten(treedef, leaves), True
        return grads, False

    def corruptions(self, step: int) -> list:
        """Scripted corrupt events at ``step`` (each reported once)."""
        return [ev for ev in self.schedule.events_at(step, "corrupt")
                if self._once(ev)]


def _tree_flatten(tree):
    import jax
    return jax.tree_util.tree_flatten(tree)


def _tree_unflatten(treedef, leaves):
    import jax
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Legacy injectors — thin aliases over the schedule DSL
# ---------------------------------------------------------------------------

class FailureInjector(ChaosInjector):
    """Deprecated alias: anonymous kills at the given steps (once
    each).  New code should script a ``FaultSchedule`` directly."""

    def __init__(self, fail_at: tuple = ()) -> None:
        self.fail_at = tuple(fail_at)
        super().__init__(FaultSchedule(tuple(
            FaultEvent(step=int(s), kind="kill") for s in self.fail_at)))


class RankFailureInjector(ChaosInjector):
    """Deprecated alias: kill specific ranks at specific steps,
    ``{step: rank}`` (each fires once).  New code should script a
    ``FaultSchedule`` directly."""

    def __init__(self, fail_at: Optional[dict] = None) -> None:
        self.fail_at = dict(fail_at or {})
        super().__init__(FaultSchedule(tuple(
            FaultEvent(step=int(s), kind="kill", rank=int(r))
            for s, r in sorted(self.fail_at.items()))))


# ---------------------------------------------------------------------------
# Fault executors: numerics sentinel + checkpoint corruption
# ---------------------------------------------------------------------------

def check_numerics(step: int, loss, grads) -> None:
    """The numerical-health sentinel: raise ``NumericalFailure`` when
    the loss or any gradient leaf is non-finite.  Runs BEFORE the
    optimizer update, so a poisoned gradient can never reach the
    weights — recovery is a rewind to the last good checkpoint."""
    if not np.all(np.isfinite(np.asarray(loss))):
        raise NumericalFailure(step, "loss")
    import jax
    for leaf in jax.tree_util.tree_leaves(grads):
        a = np.asarray(leaf)
        # jax's dtype lattice, not a.dtype.kind: ml_dtypes customs
        # (bfloat16, fp8) register as numpy kind 'V', and a bf16 NaN
        # must trip the sentinel like any other float
        if jax.numpy.issubdtype(a.dtype, jax.numpy.floating) \
                and not np.all(np.isfinite(a)):
            raise NumericalFailure(step, "gradient")


def corrupt_latest(ckpt, flips: int = 8, seed: int = 0) -> int:
    """Flip ``flips`` bytes in the data region of the newest published
    checkpoint's largest leaf — the scripted bit-rot the manifest
    digest must catch.  Returns the corrupted step.

    Bytes are flipped at seeded offsets >= 128 so the .npy header stays
    parseable: the corruption is in the DATA, which is exactly what the
    per-leaf sha256 (not a file-size or magic check) must detect."""
    steps = ckpt.steps()
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {ckpt.root}")
    step = steps[-1]
    d = ckpt.step_dir(step)
    leaves = sorted(d.glob("*.npy"), key=lambda p: -p.stat().st_size)
    if not leaves:
        raise FileNotFoundError(f"no leaves under {d}")
    target = leaves[0]
    raw = bytearray(target.read_bytes())
    lo = min(128, max(0, len(raw) - 1))
    rng = _random.Random((seed, step, target.name).__repr__())
    for _ in range(flips):
        off = rng.randrange(lo, len(raw))
        raw[off] ^= 0xFF
    target.write_bytes(bytes(raw))
    return step


# ---------------------------------------------------------------------------
# Reporting
# ---------------------------------------------------------------------------

@dataclass
class ChaosReport:
    """One soak run's accounting — the chaos-level sibling of
    ``RecoveryReport`` (which it embeds per shrink)."""
    schedule_seed: int
    n_events: int
    kinds: dict
    steps: int
    final_world: int
    final_mesh: str
    recoveries: list = field(default_factory=list)   # RecoveryReport dicts
    growths: list = field(default_factory=list)      # GrowthReport dicts
    rebalances: list = field(default_factory=list)   # RebalanceReport dicts
    numeric_rewinds: int = 0
    corrupt_detected: int = 0
    corrupt_skipped_steps: list = field(default_factory=list)
    steps_lost_total: int = 0
    wall_seconds: float = 0.0

    def to_dict(self) -> dict:
        return dict(self.__dict__)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=1, sort_keys=True)


__all__ = ["CHAOS_SCHEMA_VERSION", "ChaosInjector", "ChaosReport",
           "ChaosScheduleError", "FAULT_KINDS", "FailureInjector",
           "FaultEvent", "FaultSchedule", "NumericalFailure",
           "RankFailure", "RankFailureInjector", "WorkerFailure",
           "check_numerics", "corrupt_latest"]
