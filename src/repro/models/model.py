"""Unified architecture definition: one ArchConfig drives dense / MoE /
SSM / hybrid / enc-dec / VLM model families (DESIGN.md §4).

Layers are stacked (leading ``n_layers`` axis) and applied under
``lax.scan`` so the lowered HLO stays small at 64-layer scale, and the
whole stack shards under pjit.  Training remat is per-layer
(``jax.checkpoint`` around the scan body, policy configurable).

Public entry points (all pure):
  init(cfg, key)                         -> params
  train_loss(cfg, params, batch)         -> scalar loss
  prefill(cfg, params, tokens, …)        -> (logits, cache)
  decode_step(cfg, params, token, cache) -> (logits, cache)
  init_cache(cfg, batch, max_seq)        -> cache pytree
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import layers as L


@dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    n_shared: int = 0
    d_expert: int = 0          # per-expert FFN width
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMCfg:
    state: int
    version: int = 1           # 1 = mamba1, 2 = mamba2
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                # dense | moe | ssm | audio | vlm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0          # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope: bool = True
    rope_theta: float = 1e4
    mrope: bool = False
    mrope_sections: tuple = (16, 24, 24)
    norm: str = "rmsnorm"
    act: str = "swiglu"
    tie_embeddings: bool = False
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    # hybrid (zamba2): one shared attention+mlp block applied every k
    # ssm layers (weights shared across applications)
    hybrid_every: int = 0
    # enc-dec (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 1500        # precomputed frame embeddings (stub)
    causal: bool = True
    # sub-quadratic decode support (long_500k): SSM/hybrid only
    subquadratic: bool = False
    sliding_window: int = 0    # hybrid decode attn window (0 = full)
    dtype: str = "bfloat16"
    remat: str = "full"        # none | full
    # chunked cross-entropy: compute logits `loss_chunk` tokens at a time
    # (a (B,S,vocab) logits tensor at 1M tokens x 152k vocab would be
    # hundreds of GB/device even sharded)
    loss_chunk: int = 0
    # fully unroll scans (cost-probe compiles: XLA cost_analysis counts
    # rolled while-loop bodies once, so FLOPs/bytes need explicit
    # iterations; never used for real execution)
    unroll_scans: bool = False
    # SSM scan chunk length (memory/recompute tradeoff knob)
    ssm_chunk: int = 128
    # source metadata
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.n_heads, 1))

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def param_count(self) -> int:
        return params_count(self)

    def active_param_count(self) -> int:
        return params_count(self, active_only=True)

    def reduced(self, n_layers=2, d_model=64, d_ff=128, vocab=256,
                n_heads=4, n_kv_heads=None, dtype="float32") -> "ArchConfig":
        """Small same-family config for CPU smoke tests."""
        kw: dict[str, Any] = dict(
            n_layers=n_layers, d_model=d_model, d_ff=d_ff, vocab=vocab,
            n_heads=n_heads, head_dim=d_model // n_heads,
            n_kv_heads=(n_kv_heads if n_kv_heads is not None
                        else max(1, min(self.n_kv_heads, n_heads))),
            dtype=dtype, remat="none")
        if self.moe:
            kw["moe"] = MoECfg(n_experts=4,
                               top_k=min(2, self.moe.top_k),
                               n_shared=min(1, self.moe.n_shared),
                               d_expert=d_ff // 2)
        if self.ssm:
            kw["ssm"] = SSMCfg(state=8, version=self.ssm.version,
                               headdim=16)
        if self.hybrid_every:
            kw["hybrid_every"] = 2
        if self.n_enc_layers:
            kw["n_enc_layers"] = 2
            kw["enc_seq"] = 16
        if self.mrope:
            half = (d_model // n_heads) // 2
            t = half // 4
            h = (half - t) // 2
            kw["mrope_sections"] = (t, h, half - t - h)
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# parameter counting (for roofline MODEL_FLOPS)
# ---------------------------------------------------------------------------

def params_count(cfg: ArchConfig, active_only: bool = False) -> int:
    d, dff = cfg.d_model, cfg.d_ff
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    attn = d * hq * hd + 2 * d * hkv * hd + hq * hd * d
    if cfg.qkv_bias:
        attn += (hq + 2 * hkv) * hd
    n_mlp_mats = 3 if cfg.act == "swiglu" else 2
    n = 0
    if cfg.ssm:
        di = cfg.ssm.expand * d
        ssm = d * 2 * di + di * d                       # in/out proj
        ssm += cfg.ssm.d_conv * di + di                 # conv w + b
        if cfg.ssm.version == 1:
            dt_rank = max(1, d // 16)
            ssm += di * (dt_rank + 2 * cfg.ssm.state)   # x_proj
            ssm += dt_rank * di + di                    # dt_proj + bias
            ssm += di * cfg.ssm.state + di              # A_log + D
        else:
            nh = di // cfg.ssm.headdim
            ssm += di * 2 * cfg.ssm.state               # bc_proj
            ssm += di * nh + nh + nh + nh               # dt_proj2/bias/A/D
        ssm += d                                        # layer norm
        n += cfg.n_layers * ssm
        if cfg.hybrid_every:
            n += attn + n_mlp_mats * d * dff + 2 * d    # shared block
    else:
        per_layer = attn + 2 * d                        # norms
        if cfg.moe:
            e = cfg.moe
            per_expert = n_mlp_mats * d * e.d_expert
            moe_all = e.n_experts * per_expert + d * e.n_experts
            moe_act = e.top_k * per_expert + d * e.n_experts
            if e.n_shared:
                shared = n_mlp_mats * d * e.d_expert * e.n_shared
                moe_all += shared
                moe_act += shared
            per_layer += moe_act if active_only else moe_all
        else:
            per_layer += n_mlp_mats * d * dff
        n += cfg.n_layers * per_layer
        if cfg.n_enc_layers:
            n += cfg.n_enc_layers * (attn + n_mlp_mats * d * dff + 2 * d)
            n += cfg.n_layers * (attn + d)              # cross-attn
    n += cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    n += d                                              # final norm
    return n


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _stack(fn, key, n, *args):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: fn(k, *args))(keys)


def init(cfg: ArchConfig, key: jax.Array) -> dict:
    dt = cfg.jdtype
    keys = jax.random.split(key, 8)
    p: dict[str, Any] = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab, cfg.d_model))
                  * 0.02).astype(dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(keys[1],
                        (cfg.d_model, cfg.vocab)) * 0.02).astype(dt)

    if cfg.ssm and not cfg.hybrid_every:       # pure SSM (falcon-mamba)
        def one(k):
            return {
                "norm": jnp.ones((cfg.d_model,), dt),
                "mamba": L.init_mamba(k, cfg.d_model, cfg.ssm.state,
                                      cfg.ssm.version, dt,
                                      cfg.ssm.expand, cfg.ssm.d_conv,
                                      cfg.ssm.headdim)}
        p["layers"] = _stack(lambda k: one(k), keys[2], cfg.n_layers)
    elif cfg.hybrid_every:                     # zamba2-style hybrid
        def one(k):
            return {
                "norm": jnp.ones((cfg.d_model,), dt),
                "mamba": L.init_mamba(k, cfg.d_model, cfg.ssm.state,
                                      cfg.ssm.version, dt,
                                      cfg.ssm.expand, cfg.ssm.d_conv,
                                      cfg.ssm.headdim)}
        p["layers"] = _stack(lambda k: one(k), keys[2], cfg.n_layers)
        p["shared_attn"] = {
            "norm1": jnp.ones((cfg.d_model,), dt),
            "attn": L.init_attn(keys[3], cfg.d_model, cfg.n_heads,
                                cfg.n_kv_heads, cfg.head_dim,
                                cfg.qkv_bias, dt),
            "norm2": jnp.ones((cfg.d_model,), dt),
            "mlp": L.init_mlp(keys[4], cfg.d_model, cfg.d_ff, cfg.act, dt),
        }
    else:                                      # attention stacks
        def one(k):
            k1, k2 = jax.random.split(k)
            lp = {
                "norm1": jnp.ones((cfg.d_model,), dt),
                "attn": L.init_attn(k1, cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.head_dim,
                                    cfg.qkv_bias, dt),
                "norm2": jnp.ones((cfg.d_model,), dt),
            }
            if cfg.moe:
                lp["moe"] = L.init_moe(k2, cfg.d_model, cfg.moe.d_expert,
                                       cfg.moe.n_experts,
                                       cfg.moe.n_shared, cfg.act, dt)
            else:
                lp["mlp"] = L.init_mlp(k2, cfg.d_model, cfg.d_ff,
                                       cfg.act, dt)
            return lp
        p["layers"] = _stack(lambda k: one(k), keys[2], cfg.n_layers)
        if cfg.n_enc_layers:                   # whisper enc-dec
            def enc_one(k):
                k1, k2 = jax.random.split(k)
                return {
                    "norm1": jnp.ones((cfg.d_model,), dt),
                    "attn": L.init_attn(k1, cfg.d_model, cfg.n_heads,
                                        cfg.n_kv_heads, cfg.head_dim,
                                        cfg.qkv_bias, dt),
                    "norm2": jnp.ones((cfg.d_model,), dt),
                    "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff,
                                      cfg.act, dt)}
            p["enc_layers"] = _stack(lambda k: enc_one(k), keys[5],
                                     cfg.n_enc_layers)

            def cross_one(k):
                return {
                    "norm": jnp.ones((cfg.d_model,), dt),
                    "attn": L.init_attn(k, cfg.d_model, cfg.n_heads,
                                        cfg.n_kv_heads, cfg.head_dim,
                                        cfg.qkv_bias, dt)}
            p["cross_layers"] = _stack(lambda k: cross_one(k), keys[6],
                                       cfg.n_layers)
    return p


# ---------------------------------------------------------------------------
# forward stacks
# ---------------------------------------------------------------------------

def _norm(cfg, w, x):
    return L.rmsnorm(x, w)


def _dec_layer(cfg, lp, x, enc_out=None, cross_lp=None,
               mrope_positions=None):
    if cfg.ssm:
        h, _, _ = L.mamba_block(lp["mamba"], _norm(cfg, lp["norm"], x),
                                state=cfg.ssm.state,
                                version=cfg.ssm.version,
                                headdim=cfg.ssm.headdim,
                                unroll_chunks=cfg.unroll_scans,
                                chunk=cfg.ssm_chunk)
        return x + h, jnp.zeros((), jnp.float32)
    a, _ = L.attention_block(lp["attn"], _norm(cfg, lp["norm1"], x), cfg,
                             mrope_positions=mrope_positions,
                             causal=cfg.causal)
    x = x + a
    if cross_lp is not None:
        # cross attention: keys/values from the encoder output
        c = _cross_attn(cfg, cross_lp["attn"],
                        _norm(cfg, cross_lp["norm"], x), enc_out)
        x = x + c
    aux = jnp.zeros((), jnp.float32)
    h = _norm(cfg, lp["norm2"], x)
    if cfg.moe:
        m, aux = _moe_dispatch(cfg, lp["moe"], h)
        x = x + m
    else:
        x = x + L.mlp_block(lp["mlp"], h, cfg.act)
    return x, aux


def _moe_dispatch(cfg, moe_params, h):
    """Choose the MoE implementation: explicit shard_map all-to-all EP
    when the launch layer requested it and the shapes divide, else the
    pjit-auto grouped dispatch."""
    kw = dict(n_experts=cfg.moe.n_experts, top_k=cfg.moe.top_k,
              act=cfg.act, capacity_factor=cfg.moe.capacity_factor)
    amap = L._AXIS_MAP
    mesh = amap.get("mesh")
    if amap.get("moe_a2a") and mesh is not None:
        import numpy as _np
        tp_axis = amap.get("tp")
        dp_axes = amap.get("dp")
        dp_axes = (dp_axes,) if isinstance(dp_axes, str) else tuple(dp_axes)
        tp = mesh.shape[tp_axis]
        dp = int(_np.prod([mesh.shape[a] for a in dp_axes]))
        b, s, _ = h.shape
        if (cfg.moe.n_experts % tp == 0 and s % tp == 0 and b % dp == 0):
            return L.moe_block_ep(moe_params, h, mesh=mesh,
                                  dp_axes=dp_axes, tp_axis=tp_axis, **kw)
    return L.moe_block(moe_params, h, **kw)


def _cross_attn(cfg, ap, x, enc_out):
    """Cross-attention: queries from x, keys/values from enc_out."""
    b, s, _ = x.shape
    se = enc_out.shape[1]
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ ap["wq"]).reshape(b, s, hq, hd).transpose(0, 2, 1, 3)
    k = (enc_out @ ap["wk"]).reshape(b, se, hkv, hd).transpose(0, 2, 1, 3)
    v = (enc_out @ ap["wv"]).reshape(b, se, hkv, hd).transpose(0, 2, 1, 3)
    from .attention import chunked_attention
    o = chunked_attention(q, k, v, causal=False)
    return o.transpose(0, 2, 1, 3).reshape(b, s, hq * hd) @ ap["wo"]


def _run_decoder(cfg: ArchConfig, p: dict, x: jax.Array,
                 enc_out=None, mrope_positions=None):
    """x: (B, S, D) embedded inputs -> (hidden, aux_loss)."""
    if cfg.hybrid_every:
        return _run_hybrid(cfg, p, x)

    have_cross = "cross_layers" in p

    def body(carry, lp):
        x = carry
        if have_cross:
            lp, cross_lp = lp
        else:
            cross_lp = None
        x, aux = _dec_layer(cfg, lp, x, enc_out=enc_out,
                            cross_lp=cross_lp,
                            mrope_positions=mrope_positions)
        x = L.constrain(x, "dp", "sp", None)
        return x, aux

    fn = body
    if cfg.remat == "full":
        fn = jax.checkpoint(body)
    xs = (p["layers"], p["cross_layers"]) if have_cross else p["layers"]
    x, auxs = jax.lax.scan(fn, x, xs, unroll=cfg.unroll_scans)
    return x, jnp.sum(auxs)


def _run_hybrid(cfg: ArchConfig, p: dict, x: jax.Array):
    """zamba2: groups of ``hybrid_every`` mamba2 layers, with ONE shared
    attention+MLP block (tied weights) applied between groups."""
    k = cfg.hybrid_every
    n_groups = cfg.n_layers // k
    grouped = jax.tree_util.tree_map(
        lambda a: a.reshape((n_groups, k) + a.shape[1:]), p["layers"])
    shared = p["shared_attn"]

    def layer_body(x, lp):
        h, _, _ = L.mamba_block(lp["mamba"], _norm(cfg, lp["norm"], x),
                                state=cfg.ssm.state,
                                version=cfg.ssm.version,
                                headdim=cfg.ssm.headdim,
                                unroll_chunks=cfg.unroll_scans,
                                chunk=cfg.ssm_chunk)
        return x + h, jnp.zeros((), jnp.float32)

    def group_body(x, glp):
        x, auxs = jax.lax.scan(layer_body, x, glp,
                               unroll=cfg.unroll_scans)
        a, _ = L.attention_block(shared["attn"],
                                 _norm(cfg, shared["norm1"], x), cfg,
                                 causal=cfg.causal,
                                 window=cfg.sliding_window or None)
        x = x + a
        x = x + L.mlp_block(shared["mlp"],
                            _norm(cfg, shared["norm2"], x), cfg.act)
        return x, jnp.sum(auxs)

    fn = jax.checkpoint(group_body) if cfg.remat == "full" else group_body
    x, auxs = jax.lax.scan(fn, x, grouped, unroll=cfg.unroll_scans)
    return x, jnp.sum(auxs)


def _run_encoder(cfg: ArchConfig, p: dict, frames: jax.Array):
    def body(x, lp):
        a, _ = L.attention_block(lp["attn"], _norm(cfg, lp["norm1"], x),
                                 cfg, causal=False)
        x = x + a
        x = x + L.mlp_block(lp["mlp"], _norm(cfg, lp["norm2"], x), cfg.act)
        return x, None
    fn = jax.checkpoint(body) if cfg.remat == "full" else body
    x, _ = jax.lax.scan(fn, frames, p["enc_layers"])
    return x


def _logits(cfg: ArchConfig, p: dict, h: jax.Array) -> jax.Array:
    h = _norm(cfg, p["final_norm"], h)
    if cfg.tie_embeddings:
        return h @ p["embed"].T
    return h @ p["lm_head"]


# ---------------------------------------------------------------------------
# training / serving entry points
# ---------------------------------------------------------------------------

def train_loss(cfg: ArchConfig, p: dict, batch: dict) -> jax.Array:
    """batch: tokens (B,S) int32, labels (B,S) int32 (-1 = ignore);
    audio adds frames (B,enc_seq,D); vlm may add mrope_positions."""
    x = L.constrain(p["embed"][batch["tokens"]], "dp", "sp", None)
    enc_out = None
    if cfg.n_enc_layers:
        enc_out = _run_encoder(cfg, p, batch["frames"].astype(cfg.jdtype))
    h, aux = _run_decoder(cfg, p, x, enc_out=enc_out,
                          mrope_positions=batch.get("mrope_positions"))
    labels = batch["labels"]
    loss = _ce_loss(cfg, p, h, labels)
    return loss + 0.01 * aux


def _ce_token_stats(cfg, p, h, labels):
    logits = _logits(cfg, p, h).astype(jnp.float32)
    # batch over dp, vocab over tp — without this constraint XLA has
    # been observed to replicate the vocab dim (tens of GB per device)
    logits = L.constrain(logits, "dp", None, "tp")
    valid = labels >= 0
    lbl = jnp.where(valid, labels, 0)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, lbl[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * valid
    return nll.sum(), valid.sum()


def _ce_loss(cfg, p, h, labels):
    b, s, d = h.shape
    c = cfg.loss_chunk
    if not c or s % c or s == c:
        nll, nv = _ce_token_stats(cfg, p, h, labels)
        return nll / jnp.maximum(nv, 1)

    hc = h.reshape(b, s // c, c, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, s // c, c).transpose(1, 0, 2)

    def body(carry, xs):
        hi, li = xs
        nll, nv = _ce_token_stats(cfg, p, hi, li)
        return (carry[0] + nll, carry[1] + nv), None

    chunk_fn = jax.checkpoint(body) if cfg.remat == "full" else body
    (nll, nv), _ = jax.lax.scan(
        chunk_fn, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        (hc, lc), unroll=cfg.unroll_scans)
    return nll / jnp.maximum(nv, 1)


def init_cache(cfg: ArchConfig, batch: int, max_seq: int) -> dict:
    dt = cfg.jdtype
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    cache: dict[str, Any] = {"len": jnp.zeros((), jnp.int32)}
    if cfg.ssm and not cfg.hybrid_every:
        di = cfg.ssm.expand * cfg.d_model
        if cfg.ssm.version == 1:
            ssm_shape = (cfg.n_layers, batch, di, cfg.ssm.state)
        else:
            nh = di // cfg.ssm.headdim
            ssm_shape = (cfg.n_layers, batch, nh, cfg.ssm.headdim,
                         cfg.ssm.state)
        cache["conv"] = jnp.zeros((cfg.n_layers, batch,
                                   cfg.ssm.d_conv - 1, di), dt)
        cache["ssm"] = jnp.zeros(ssm_shape, jnp.float32)
    elif cfg.hybrid_every:
        di = cfg.ssm.expand * cfg.d_model
        nh = di // cfg.ssm.headdim
        n_groups = cfg.n_layers // cfg.hybrid_every
        win = cfg.sliding_window or max_seq
        win = min(win, max_seq)
        cache["conv"] = jnp.zeros((cfg.n_layers, batch,
                                   cfg.ssm.d_conv - 1, di), dt)
        cache["ssm"] = jnp.zeros((cfg.n_layers, batch, nh,
                                  cfg.ssm.headdim, cfg.ssm.state),
                                 jnp.float32)
        cache["k"] = jnp.zeros((n_groups, batch, hkv, win, hd), dt)
        cache["v"] = jnp.zeros((n_groups, batch, hkv, win, hd), dt)
    else:
        cache["k"] = jnp.zeros((cfg.n_layers, batch, hkv, max_seq, hd), dt)
        cache["v"] = jnp.zeros((cfg.n_layers, batch, hkv, max_seq, hd), dt)
        if cfg.n_enc_layers:
            cache["cross_k"] = jnp.zeros(
                (cfg.n_layers, batch, hkv, cfg.enc_seq, hd), dt)
            cache["cross_v"] = jnp.zeros_like(cache["cross_k"])
    return cache


def prefill(cfg: ArchConfig, p: dict, batch: dict, max_seq: int):
    """Run the full prompt, return (last-token logits, filled cache).
    Uses the training forward (no incremental cache fill) then a cache
    built from the same projections — for the dry-run we prefill by
    running the chunked forward and materializing caches layerwise."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = p["embed"][tokens]
    cache = init_cache(cfg, b, max_seq)
    enc_out = None
    if cfg.n_enc_layers:
        enc_out = _run_encoder(cfg, p, batch["frames"].astype(cfg.jdtype))
        cache["enc_out"] = enc_out
    # for shapes/roofline purposes prefill = decoder forward; cache fill
    # is a cheap scatter of the per-layer K/V (done inside attention on
    # the serving path; here we run the stack and return hidden states)
    h, _ = _run_decoder(cfg, p, x, enc_out=enc_out,
                        mrope_positions=batch.get("mrope_positions"))
    logits = _logits(cfg, p, h[:, -1:, :])
    cache["len"] = jnp.full((), s, jnp.int32)
    return logits, cache


def decode_step(cfg: ArchConfig, p: dict, token: jax.Array, cache: dict):
    """One decode step. token: (B, 1) int32.  Returns (logits, cache)."""
    x = p["embed"][token]                              # (B,1,D)
    pos = cache["len"]

    if cfg.ssm and not cfg.hybrid_every:
        def body(x, xs):
            lp, conv, ssm = xs
            h, new_conv, new_ssm = L.mamba_block(
                lp["mamba"], _norm(cfg, lp["norm"], x),
                state=cfg.ssm.state, version=cfg.ssm.version,
                conv_state=conv, ssm_state=ssm, headdim=cfg.ssm.headdim)
            return x + h, (new_conv, new_ssm)
        x, (conv, ssm) = jax.lax.scan(
            body, x, (p["layers"], cache["conv"], cache["ssm"]),
            unroll=cfg.unroll_scans)
        cache = dict(cache, conv=conv, ssm=ssm,
                     len=cache["len"] + 1)
    elif cfg.hybrid_every:
        k = cfg.hybrid_every
        n_groups = cfg.n_layers // k
        grouped = jax.tree_util.tree_map(
            lambda a: a.reshape((n_groups, k) + a.shape[1:]), p["layers"])
        gconv = cache["conv"].reshape((n_groups, k)
                                      + cache["conv"].shape[1:])
        gssm = cache["ssm"].reshape((n_groups, k) + cache["ssm"].shape[1:])
        shared = p["shared_attn"]
        win = cache["k"].shape[3]
        # sliding-window cache position
        wpos = jnp.minimum(pos, win - 1)

        def group_body(x, xs):
            glp, conv_g, ssm_g, kc, vc = xs

            def layer_body(x, ys):
                lp, conv, ssm = ys
                h, nc, ns = L.mamba_block(
                    lp["mamba"], _norm(cfg, lp["norm"], x),
                    state=cfg.ssm.state, version=cfg.ssm.version,
                    conv_state=conv, ssm_state=ssm,
                    headdim=cfg.ssm.headdim)
                return x + h, (nc, ns)
            x, (nconv, nssm) = jax.lax.scan(layer_body, x,
                                            (glp, conv_g, ssm_g))
            a, (nk, nv) = L.attention_block(
                shared["attn"], _norm(cfg, shared["norm1"], x), cfg,
                kv_cache=(kc, vc), cache_len=wpos,
                window=cfg.sliding_window or None)
            x = x + a
            x = x + L.mlp_block(shared["mlp"],
                                _norm(cfg, shared["norm2"], x), cfg.act)
            return x, (nconv, nssm, nk, nv)

        x, (conv, ssm, kc, vc) = jax.lax.scan(
            group_body, x, (grouped, gconv, gssm, cache["k"], cache["v"]),
            unroll=cfg.unroll_scans)
        cache = dict(cache,
                     conv=conv.reshape(cache["conv"].shape),
                     ssm=ssm.reshape(cache["ssm"].shape),
                     k=kc, v=vc, len=cache["len"] + 1)
    else:
        have_cross = "cross_layers" in p

        def body(x, xs):
            if have_cross:
                lp, clp, kc, vc, ck, cv = xs
            else:
                lp, kc, vc = xs
            a, (nk, nv) = L.attention_block(
                lp["attn"], _norm(cfg, lp["norm1"], x), cfg,
                kv_cache=(kc, vc), cache_len=pos)
            x = x + a
            if have_cross:
                x = x + _cross_cached(cfg, clp, x, ck, cv)
            h = _norm(cfg, lp["norm2"], x)
            if cfg.moe:
                m, _ = L.moe_block(lp["moe"], h,
                                   n_experts=cfg.moe.n_experts,
                                   top_k=cfg.moe.top_k, act=cfg.act,
                                   capacity_factor=cfg.moe.capacity_factor)
                x = x + m
            else:
                x = x + L.mlp_block(lp["mlp"], h, cfg.act)
            if have_cross:
                return x, (nk, nv, ck, cv)
            return x, (nk, nv)

        if have_cross:
            xs = (p["layers"], p["cross_layers"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"])
            x, (kc, vc, ck, cv) = jax.lax.scan(body, x, xs,
                                               unroll=cfg.unroll_scans)
            cache = dict(cache, k=kc, v=vc, len=cache["len"] + 1)
        else:
            x, (kc, vc) = jax.lax.scan(
                body, x, (p["layers"], cache["k"], cache["v"]),
                unroll=cfg.unroll_scans)
            cache = dict(cache, k=kc, v=vc, len=cache["len"] + 1)

    return _logits(cfg, p, x), cache


def _cross_cached(cfg, clp, x, ck, cv):
    from .attention import decode_attention
    b, s, _ = x.shape
    hq, hd = cfg.n_heads, cfg.head_dim
    ap = clp["attn"]
    q = (_norm(cfg, clp["norm"], x) @ ap["wq"]).reshape(
        b, s, hq, hd).transpose(0, 2, 1, 3)
    o = decode_attention(q, ck, cv, ck.shape[2])
    return o.transpose(0, 2, 1, 3).reshape(b, s, hq * hd) @ ap["wo"]
