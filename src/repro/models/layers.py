"""Transformer building blocks, pure-functional JAX.

Everything takes explicit param pytrees (dicts of arrays) so layers stack
cleanly under ``lax.scan`` and shard cleanly under pjit.  Perf-critical
ops (rmsnorm, attention, expert matmul, ssm scan) route through an
``impl`` registry so the Pallas kernels can be swapped in on TPU while
the chunked-jnp references run everywhere (DESIGN.md §6).
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .attention import decode_attention

# ---------------------------------------------------------------------------
# impl registry (kernels plug in here)
# ---------------------------------------------------------------------------

_IMPLS: dict[str, Callable] = {}


def register_impl(name: str, fn: Callable) -> None:
    _IMPLS[name] = fn


def get_impl(name: str, default: Callable) -> Callable:
    return _IMPLS.get(name, default)


# ---------------------------------------------------------------------------
# logical-axis sharding constraints (set by the launch layer; no-op when
# no mapping is active, e.g. CPU smoke tests)
# ---------------------------------------------------------------------------

_AXIS_MAP: dict[str, Any] = {}


def set_axis_map(mapping: Optional[dict]) -> None:
    """mapping: logical -> mesh axis (or tuple), e.g.
    {"dp": ("pod", "data"), "tp": "model"}."""
    global _AXIS_MAP
    _AXIS_MAP = dict(mapping or {})


def constrain(x, *logical):
    """with_sharding_constraint on logical axes ('dp'/'tp'/None).
    Falls back to unconstrained when the spec doesn't apply (no ambient
    mesh, or a dim not divisible by the axis size)."""
    if not _AXIS_MAP:
        return x
    from jax.sharding import PartitionSpec as P
    axes = [(_AXIS_MAP.get(a) if a else None) for a in logical]
    try:
        return jax.lax.with_sharding_constraint(x, P(*axes))
    except Exception:
        return x


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def rmsnorm(x, w, eps: float = 1e-6):
    return get_impl("rmsnorm", rmsnorm_ref)(x, w, eps)


def layernorm(x, w, b, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w + b


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 1e4) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 1e4) -> jax.Array:
    """x: (B, H, S, D); positions: (B, S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[:, None, :, None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    return jnp.stack([o1, o2], axis=-1).reshape(x.shape).astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, sections=(16, 24, 24),
                theta: float = 1e4) -> jax.Array:
    """Qwen2-VL multimodal RoPE: rotary dims partitioned into (temporal,
    height, width) sections, each rotated by its own position stream.
    x: (B, H, S, D); positions: (3, B, S)."""
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(d, theta)                       # (half,)
    # section index for each rotary dim
    sec_pos = []
    for si, sec in enumerate(sections):
        sec_pos.extend([si] * sec)
    sec_idx = jnp.array(sec_pos)                       # (half,)
    pos = positions.astype(jnp.float32)                # (3, B, S)
    # choose, per rotary dim, the position stream of its section
    p = pos[sec_idx]                                   # (half, B, S)
    ang = jnp.moveaxis(p, 0, -1) * freqs               # (B, S, half)
    cos = jnp.cos(ang)[:, None]                        # (B,1,S,half)
    sin = jnp.sin(ang)[:, None]
    x1, x2 = x[..., 0::2], x[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    return jnp.stack([o1, o2], axis=-1).reshape(x.shape).astype(x.dtype)


def default_mrope_positions(batch: int, seq: int) -> jax.Array:
    """Text-only M-RoPE positions: all three streams equal."""
    p = jnp.broadcast_to(jnp.arange(seq)[None], (batch, seq))
    return jnp.stack([p, p, p])


# ---------------------------------------------------------------------------
# attention block (GQA, optional qkv bias / M-RoPE / window)
# ---------------------------------------------------------------------------

def init_attn(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
              qkv_bias: bool, dtype) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d_model ** -0.5
    p = {
        "wq": (jax.random.normal(k1, (d_model, n_heads * head_dim)) * s
               ).astype(dtype),
        "wk": (jax.random.normal(k2, (d_model, n_kv * head_dim)) * s
               ).astype(dtype),
        "wv": (jax.random.normal(k3, (d_model, n_kv * head_dim)) * s
               ).astype(dtype),
        "wo": (jax.random.normal(k4, (n_heads * head_dim, d_model)) * s
               ).astype(dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
    return p


def attention_block(p, x, cfg, *, positions=None, mrope_positions=None,
                    kv_cache=None, cache_len=None, causal=True,
                    window=None):
    """Returns (out, new_kv) where kv_cache is (k, v) of shape
    (B, Hkv, Smax, D) when decoding, else None."""
    b, s, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, hq, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    if _AXIS_MAP.get("attn_tp"):
        # tensor-parallel attention: heads over the model axis (falls
        # back to replicated on non-divisible head counts)
        q = constrain(q, "dp", "tp", None, None)
        k = constrain(k, "dp", "tp", None, None)
        v = constrain(v, "dp", "tp", None, None)
    else:
        # context-parallel attention: q sharded over seq ('sp'), full KV
        # gathered per shard — avoids the head-divisibility problem
        # (e.g. 40 heads on a 16-way axis), keeps flash transients local
        q = constrain(q, "dp", None, "sp", None)
        k = constrain(k, "dp", None, None, None)
        v = constrain(v, "dp", None, None, None)
    if positions is None:
        base = 0 if cache_len is None else cache_len
        positions = jnp.arange(s) + base
    if cfg.mrope:
        mp = (mrope_positions if mrope_positions is not None
              else default_mrope_positions(b, s) + (
                  0 if cache_len is None else cache_len))
        q = apply_mrope(q, mp, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, mp, cfg.mrope_sections, cfg.rope_theta)
    elif cfg.rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    new_cache = None
    if kv_cache is not None:
        ck, cv = kv_cache
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, 0, cache_len, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, 0, cache_len, 0))
        new_cache = (ck, cv)
        out = decode_attention(q, ck, cv, cache_len + s, window=window)
    else:
        # flash_attention_ref: linear-memory fwd AND bwd (custom VJP);
        # the Pallas kernel substitutes via the impl registry on TPU
        from .attention import flash_attention_ref
        attn = get_impl("attention", flash_attention_ref)
        kw = ({"unroll": True}
              if getattr(cfg, "unroll_scans", False)
              and attn is flash_attention_ref else {})
        out = attn(q, k, v, causal=causal, window=window, **kw)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
    return out @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, act: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s = d_model ** -0.5
    p = {"w_up": (jax.random.normal(k1, (d_model, d_ff)) * s).astype(dtype),
         "w_down": (jax.random.normal(k2, (d_ff, d_model))
                    * d_ff ** -0.5).astype(dtype)}
    if act == "swiglu":
        p["w_gate"] = (jax.random.normal(k3, (d_model, d_ff)) * s
                       ).astype(dtype)
    return p


def mlp_block(p, x, act: str = "swiglu"):
    if act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"])
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# MoE (shared + routed experts, top-k, GShard-style static dispatch)
# ---------------------------------------------------------------------------

def init_moe(key, d_model: int, d_expert: int, n_experts: int,
             n_shared: int, act: str, dtype) -> dict:
    keys = jax.random.split(key, 4)
    s = d_model ** -0.5
    p = {
        "router": (jax.random.normal(keys[0], (d_model, n_experts)) * s
                   ).astype(jnp.float32),
        # routed experts, stacked: (E, d_model, d_expert)…
        "we_up": (jax.random.normal(keys[1],
                  (n_experts, d_model, d_expert)) * s).astype(dtype),
        "we_down": (jax.random.normal(keys[2],
                    (n_experts, d_expert, d_model))
                    * d_expert ** -0.5).astype(dtype),
    }
    if act == "swiglu":
        p["we_gate"] = (jax.random.normal(keys[3],
                        (n_experts, d_model, d_expert)) * s).astype(dtype)
    if n_shared:
        p["shared"] = init_mlp(jax.random.fold_in(key, 7), d_model,
                               d_expert * n_shared, act, dtype)
    return p


def moe_gmm_ref(x, w):
    """Grouped matmul reference: x (E, cap, d) @ w (E, d, f)."""
    return jnp.einsum("ecd,edf->ecf", x, w)


def moe_expert_mm(x_e, p, act: str):
    """Expert computation on pre-dispatched tokens.
    x_e: (E, cap, d_model) -> (E, cap, d_model)."""
    gmm = get_impl("moe_gmm", moe_gmm_ref)
    if act == "swiglu":
        h = jax.nn.silu(gmm(x_e, p["we_gate"])) * gmm(x_e, p["we_up"])
    else:
        h = jax.nn.gelu(gmm(x_e, p["we_up"]))
    return gmm(h, p["we_down"])


def _router(p, xt, top_k):
    logits = xt.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)            # (T, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)  # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)
    return probs, gate_vals, gate_idx


def _dispatch_groups(b: int, s: int, target: int = 1024) -> int:
    """Number of sequence chunks per row so that b*n_sc ~ target groups
    (>= the mesh size, so the group dim shards over every axis)."""
    n_sc = 1
    while (b * n_sc * 2 <= target and s % (n_sc * 2) == 0
           and s // (n_sc * 2) >= 64):
        n_sc *= 2
    return n_sc


def moe_block(p, x, *, n_experts: int, top_k: int, act: str = "swiglu",
              capacity_factor: float = 1.25):
    """Token-choice top-k MoE with static capacity and **grouped local
    dispatch**: tokens are split into G groups (batch x seq-chunks, the
    group dim sharded over every mesh axis), each group argsorts its own
    tokens and gathers them into a per-group (E, cap_g, D) buffer with
    purely LOCAL indices (vmapped over groups), so the SPMD partitioner
    never sees a data-dependent access to a sharded dim.  The expert
    matmul then runs with experts over tp and group-capacity rows over
    dp — the single resharding between those layouts IS the EP
    all-to-all.  Per-(group,expert) capacity mirrors real per-peer a2a
    buffers.  x: (B, S, D)."""
    b, s, d = x.shape
    K, E = top_k, n_experts
    n_sc = _dispatch_groups(b, s)
    G = b * n_sc
    Tg = s // n_sc
    xt = x.reshape(G, Tg, d)
    # one consistent layout throughout the block: groups over dp,
    # experts over tp.  (Going 'dpt'-sharded here and resharding to
    # (dp, tp) at the matmul makes GSPMD's backward transposes fall into
    # 'involuntary full rematerialization' — full replication.)
    xt = constrain(xt, "dp", None, None)
    probs, gate_vals, gate_idx = _router(p, xt.reshape(G * Tg, d), K)
    cap = max(1, int(capacity_factor * Tg * K / E))
    gate_g = gate_vals.reshape(G, Tg, K)
    eid_g = gate_idx.reshape(G, Tg, K)

    def route_one(eid):
        """eid: (Tg, K) -> (slot token idx (E*cap,), keep (E*cap,),
        slot gate-pos (E*cap,))  — all local to the group."""
        tk = Tg * K
        flat = eid.reshape(tk)
        order = jnp.argsort(flat, stable=True)
        eid_s = flat[order]
        seg = jnp.searchsorted(eid_s, jnp.arange(E), side="left")
        pos = jnp.arange(tk, dtype=jnp.int32) - seg[eid_s]
        keep_s = pos < cap
        slot = jnp.where(keep_s, eid_s * cap + pos, E * cap)
        # invert: for each slot, which (token,k) feeds it
        inv = jnp.full((E * cap + 1,), tk, jnp.int32).at[slot].set(order)
        inv = inv[:E * cap]
        filled = inv < tk
        tok_of_slot = jnp.where(filled, inv // K, 0)
        k_of_slot = jnp.where(filled, inv % K, 0)
        return tok_of_slot, k_of_slot, filled

    tok_slot, k_slot, filled = jax.vmap(route_one)(eid_g)  # (G, E*cap)

    # local gather into per-group expert buffers
    def gather_one(xt_g, tok_g, fill_g):
        return xt_g[tok_g] * fill_g[:, None].astype(xt_g.dtype)
    x_ge = jax.vmap(gather_one)(xt, tok_slot, filled)   # (G, E*cap, D)
    x_ge = x_ge.reshape(G, E, cap, d)
    # EP layout for the expert matmul: experts over tp, groups over dp
    x_ge = constrain(x_ge, "dp", "tp", None, None)
    if act == "swiglu":
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", x_ge, p["we_gate"])) \
            * jnp.einsum("gecd,edf->gecf", x_ge, p["we_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", x_ge, p["we_up"]))
    h = constrain(h, "dp", "tp", None, None)
    y_ge = jnp.einsum("gecf,efd->gecd", h, p["we_down"])
    y_ge = constrain(y_ge, "dp", "tp", None, None)
    y_ge = y_ge.reshape(G, E * cap, d)

    # combine back to tokens with gate weights (local scatter-add)
    def combine_one(y_g, tok_g, k_g, fill_g, gates_g):
        gate_of_slot = gates_g[tok_g, k_g] * fill_g
        contrib = y_g * gate_of_slot[:, None].astype(y_g.dtype)
        return jnp.zeros((Tg, d), y_g.dtype).at[tok_g].add(contrib)
    y = jax.vmap(combine_one)(y_ge, tok_slot, k_slot,
                              filled.astype(jnp.float32), gate_g)
    y = constrain(y, "dp", None, None)
    if "shared" in p:
        y = y + jax.vmap(lambda xg: mlp_block(p["shared"], xg, act))(xt)
    aux = moe_aux_loss(probs, gate_idx, n_experts)
    return y.reshape(b, s, d), aux


def moe_block_ep(p, x, *, n_experts: int, top_k: int, act: str = "swiglu",
                 capacity_factor: float = 1.25, mesh=None,
                 dp_axes=("data",), tp_axis: str = "model"):
    """True expert-parallel MoE with explicit `lax.all_to_all` dispatch
    inside shard_map (DeepSeek/DeepEP-style, the paper's Figure 1 EP).

    Each device routes its LOCAL tokens, packs per-destination-rank send
    buffers (rank r owns experts [r*E_loc, (r+1)*E_loc)), all-to-alls
    tokens + routing metadata over the tp axis, computes its local
    experts, and all-to-alls results back for the gated combine.  Unlike
    the pjit-auto grouped dispatch (moe_block), tokens are never
    replicated across tp and the combine is a2a, not an all-reduce —
    per-device traffic drops from O(T*d) to O(T*K*d/tp).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    K, E = top_k, n_experts
    tp = mesh.shape[tp_axis]
    E_loc = E // tp
    dp_size = int(np.prod([mesh.shape[a] for a in dp_axes]))
    t_loc = (b // dp_size) * (s // tp)        # local tokens per device
    cap_send = max(1, int(capacity_factor * t_loc * K / tp))
    cap_e = max(1, int(capacity_factor * t_loc * K / E_loc))

    def body(xl, router, wg, wu, wd):
        bl, sl, _ = xl.shape
        tl = bl * sl
        xt = xl.reshape(tl, d)
        probs = jax.nn.softmax(
            xt.astype(jnp.float32) @ router[0].astype(jnp.float32), -1)
        gate_vals, gate_idx = jax.lax.top_k(probs, K)       # (tl, K)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)

        tk = tl * K
        eid = gate_idx.reshape(tk)
        tok = jnp.arange(tk, dtype=jnp.int32) // K
        dest = eid // E_loc                                  # (tk,)
        order = jnp.argsort(dest, stable=True)
        dest_s = dest[order]
        seg = jnp.searchsorted(dest_s, jnp.arange(tp), side="left")
        pos = jnp.arange(tk, dtype=jnp.int32) - seg[dest_s]
        keep = pos < cap_send
        slot = jnp.where(keep, dest_s * cap_send + pos, tp * cap_send)

        send_x = jnp.zeros((tp * cap_send + 1, d), xt.dtype
                           ).at[slot].set(xt[tok[order]])
        send_le = jnp.full((tp * cap_send + 1,), E_loc, jnp.int32
                           ).at[slot].set(eid[order] % E_loc)
        # remember where each send slot came from, for the combine
        tok_of_slot = jnp.full((tp * cap_send + 1,), tl, jnp.int32
                               ).at[slot].set(tok[order])
        gate_of_slot = jnp.zeros((tp * cap_send + 1,), jnp.float32
                                 ).at[slot].set(
            gate_vals.reshape(tk)[order] * keep)

        sx = send_x[:-1].reshape(tp, cap_send, d)
        sle = send_le[:-1].reshape(tp, cap_send)
        rx = jax.lax.all_to_all(sx, tp_axis, 0, 0, tiled=False)
        rle = jax.lax.all_to_all(sle, tp_axis, 0, 0, tiled=False)

        # local expert compute on received tokens
        tr = tp * cap_send
        xr = rx.reshape(tr, d)
        er = rle.reshape(tr)                                 # E_loc = drop
        order2 = jnp.argsort(er, stable=True)
        er_s = er[order2]
        seg2 = jnp.searchsorted(er_s, jnp.arange(E_loc), side="left")
        pos2 = jnp.arange(tr, dtype=jnp.int32) - seg2[er_s]
        keep2 = (pos2 < cap_e) & (er_s < E_loc)
        slot2_s = jnp.where(keep2, er_s * cap_e + pos2, E_loc * cap_e)
        slot_of_recv = jnp.zeros((tr,), jnp.int32).at[order2].set(slot2_s)

        buf = jnp.zeros((E_loc * cap_e + 1, d), xt.dtype
                        ).at[slot_of_recv].add(xr)
        x_e = buf[:E_loc * cap_e].reshape(E_loc, cap_e, d)
        if act == "swiglu":
            h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x_e, wg)) * \
                jnp.einsum("ecd,edf->ecf", x_e, wu)
        else:
            h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", x_e, wu))
        y_e = jnp.einsum("ecf,efd->ecd", h, wd)
        y_flat = jnp.concatenate(
            [y_e.reshape(E_loc * cap_e, d),
             jnp.zeros((1, d), y_e.dtype)], axis=0)
        y_r = y_flat[slot_of_recv]                           # (tr, d)

        y_back = jax.lax.all_to_all(
            y_r.reshape(tp, cap_send, d), tp_axis, 0, 0, tiled=False)
        # combine at the source with the stashed gates
        contrib = y_back.reshape(tp * cap_send, d) * \
            gate_of_slot[:-1, None].astype(y_back.dtype)
        y_tok = jnp.zeros((tl + 1, d), xt.dtype
                          ).at[tok_of_slot[:-1]].add(contrib)[:tl]

        # load-balance aux: global means via psum over every mesh axis
        all_axes = tuple(dp_axes) + (tp_axis,)
        n_tok_g = jax.lax.psum(jnp.float32(tl), all_axes)
        sum_probs = jax.lax.psum(probs.sum(0), all_axes)     # (E,)
        top1 = jax.nn.one_hot(gate_idx[:, 0], E).sum(0)
        sum_top1 = jax.lax.psum(top1, all_axes)
        aux = E * jnp.sum((sum_probs / n_tok_g) * (sum_top1 / n_tok_g))
        return y_tok.reshape(bl, sl, d), aux

    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    f = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp, tp_axis, None),      # x: batch@dp, seq@tp
                  P(None, None, None),       # router (wrapped, see call)
                  P(tp_axis, None, None),    # we_gate
                  P(tp_axis, None, None),    # we_up
                  P(tp_axis, None, None)),   # we_down
        out_specs=(P(dp, tp_axis, None), P()),
        check_rep=False)
    router = p["router"][None]               # add a dummy leading axis
    wg = p.get("we_gate", p["we_up"])
    y, aux = f(x, router, wg, p["we_up"], p["we_down"])
    if "shared" in p:
        y = y + mlp_block(p["shared"], x, act)
    return y, aux


def moe_block_dense(p, x, *, n_experts: int, top_k: int,
                    act: str = "swiglu", capacity_factor: float = 1.25):
    """GShard-style one-hot dispatch einsums — O(T·K·E·cap) memory, only
    usable at toy scale; serves as the oracle for the sort-based path."""
    b, s, d = x.shape
    n_tok = b * s
    xt = x.reshape(n_tok, d)
    probs, gate_vals, gate_idx = _router(p, xt, top_k)
    cap = max(1, int(capacity_factor * n_tok * top_k / n_experts))
    onehot = jax.nn.one_hot(gate_idx, n_experts, dtype=jnp.int32)
    flat = onehot.reshape(n_tok * top_k, n_experts)
    pos_in_e = jnp.cumsum(flat, axis=0) * flat - 1
    pos = pos_in_e.reshape(n_tok, top_k, n_experts)
    keep = (pos < cap) & (onehot > 0)
    pos_c = jnp.clip(pos, 0, cap - 1)
    disp = (jax.nn.one_hot(pos_c, cap, dtype=xt.dtype)
            * keep[..., None].astype(xt.dtype))
    disp_t = disp.sum(1)
    x_e = jnp.einsum("tec,td->ecd", disp_t, xt)
    y_e = moe_expert_mm(x_e, p, act)
    comb = (disp * gate_vals[..., None, None].astype(xt.dtype)).sum(1)
    y = jnp.einsum("tec,ecd->td", comb, y_e)
    if "shared" in p:
        y = y + mlp_block(p["shared"], xt, act)
    aux = moe_aux_loss(probs, gate_idx, n_experts)
    return y.reshape(b, s, d), aux


def moe_aux_loss(probs, gate_idx, n_experts: int) -> jax.Array:
    """Switch-style load-balancing loss."""
    me = probs.mean(axis=0)
    top1 = jax.nn.one_hot(gate_idx[:, 0], n_experts).mean(axis=0)
    return n_experts * jnp.sum(me * top1)


# ---------------------------------------------------------------------------
# Mamba (1 and 2) — selective SSM
# ---------------------------------------------------------------------------

def init_mamba(key, d_model: int, state: int, version: int, dtype,
               expand: int = 2, d_conv: int = 4, headdim: int = 64) -> dict:
    d_inner = expand * d_model
    ks = jax.random.split(key, 8)
    s = d_model ** -0.5
    p = {
        "in_proj": (jax.random.normal(ks[0], (d_model, 2 * d_inner)) * s
                    ).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (d_conv, d_inner)) * 0.2
                   ).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "out_proj": (jax.random.normal(ks[2], (d_inner, d_model))
                     * d_inner ** -0.5).astype(dtype),
    }
    if version == 1:
        dt_rank = max(1, d_model // 16)
        p.update({
            "x_proj": (jax.random.normal(ks[3],
                       (d_inner, dt_rank + 2 * state)) * s).astype(dtype),
            "dt_proj": (jax.random.normal(ks[4], (dt_rank, d_inner))
                        * dt_rank ** -0.5).astype(dtype),
            "dt_bias": jnp.zeros((d_inner,), dtype),
            "A_log": jnp.log(jnp.broadcast_to(
                jnp.arange(1, state + 1, dtype=jnp.float32),
                (d_inner, state))).astype(jnp.float32),
            "D": jnp.ones((d_inner,), jnp.float32),
        })
    else:  # mamba2 (SSD): scalar A per head
        n_heads = d_inner // headdim
        p.update({
            "bc_proj": (jax.random.normal(ks[3], (d_inner, 2 * state)) * s
                        ).astype(dtype),
            "dt_bias": jnp.zeros((n_heads,), jnp.float32),
            "A_log": jnp.zeros((n_heads,), jnp.float32),
            "D": jnp.ones((n_heads,), jnp.float32),
            "dt_proj2": (jax.random.normal(ks[4], (d_inner, n_heads))
                         * s).astype(dtype),
        })
    return p


def _causal_conv(x, w, b, state=None):
    """x: (B, S, C), w: (K, C). Returns (y, new_state (B, K-1, C))."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    new_state = xp[:, -(k - 1):] if k > 1 else None
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    return y + b, new_state


SSM_CHUNK = 128


def _pick_chunk(s: int, chunk: int) -> int:
    if s <= chunk:
        return s
    while s % chunk:
        chunk //= 2
    return max(chunk, 1)


def ssm_scan_ref(xz, dt, A, B, C, D, h0=None, chunk: int = SSM_CHUNK,
                 unroll_chunks: bool = False):
    """Selective scan (mamba1 core), chunked for linear backward memory.

    xz: (B,S,C) inputs; dt: (B,S,C); A: (C,N); B,C: (B,S,N); D: (C,)
    Returns (y (B,S,C), last_state (B,C,N)).

    The sequence is processed in checkpointed chunks: the outer scan
    saves only the chunk-boundary states for autodiff, and the decay
    terms exp(dt*A) are built per-step inside the chunk so a
    (B,S,C,N) tensor is never materialized — the same structure as the
    chunked Mamba kernel (kernels/mamba_scan.py uses this as oracle)."""
    b, s, c = xz.shape
    n = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((b, c, n), jnp.float32)
    q = _pick_chunk(s, chunk)
    nc = s // q

    def chunk_body(h, inp):
        xc, dtc, Bc, Cc = inp                 # (q,B,·)

        def step(h, t_inp):
            x_t, dt_t, B_t, C_t = t_inp       # (B,C) (B,C) (B,N) (B,N)
            dA_t = jnp.exp(dt_t[..., None] * A)          # (B,C,N)
            h = h * dA_t + (dt_t * x_t)[..., None] * B_t[:, None, :]
            y = jnp.einsum("bcn,bn->bc", h, C_t.astype(jnp.float32))
            return h, y

        h, ys = jax.lax.scan(step, h,
                             (xc.astype(jnp.float32),
                              dtc.astype(jnp.float32),
                              Bc.astype(jnp.float32),
                              Cc.astype(jnp.float32)))
        return h, ys

    xc = jnp.moveaxis(xz.reshape(b, nc, q, c), 1, 0).swapaxes(1, 2)
    dtc = jnp.moveaxis(dt.reshape(b, nc, q, c), 1, 0).swapaxes(1, 2)
    Bc = jnp.moveaxis(B.reshape(b, nc, q, n), 1, 0).swapaxes(1, 2)
    Cc = jnp.moveaxis(C.reshape(b, nc, q, n), 1, 0).swapaxes(1, 2)
    body = jax.checkpoint(chunk_body)
    hT, ys = jax.lax.scan(body, h0.astype(jnp.float32),
                          (xc, dtc, Bc, Cc), unroll=unroll_chunks)
    # ys: (nc, q, B, C) -> (B, S, C)
    y = ys.reshape(nc * q, b, c).swapaxes(0, 1).reshape(b, s, c)
    y = y.astype(xz.dtype) + xz * D.astype(xz.dtype)
    return y, hT


def mamba_block(p, x, *, state: int, version: int, conv_state=None,
                ssm_state=None, headdim: int = 64,
                unroll_chunks: bool = False, chunk: int = SSM_CHUNK):
    """Full Mamba block.  When conv_state/ssm_state are given (decode),
    processes S tokens incrementally and returns updated states."""
    b, s, d = x.shape
    xz = x @ p["in_proj"]
    xh, z = jnp.split(xz, 2, axis=-1)                   # (B,S,Ci)
    # SSM recurrence is independent per channel: shard d_inner over tp
    # (the sequence dim must stay whole for the scan)
    xh = constrain(xh, "dp", None, "tp")
    z = constrain(z, "dp", None, "tp")
    xh, new_conv = _causal_conv(xh, p["conv_w"], p["conv_b"], conv_state)
    xh = jax.nn.silu(xh)
    ci = xh.shape[-1]
    if version == 1:
        proj = xh @ p["x_proj"]
        dt_rank = p["dt_proj"].shape[0]
        dt, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + state], axis=-1)
        dt = jax.nn.softplus(dt @ p["dt_proj"] + p["dt_bias"])
        A = -jnp.exp(p["A_log"])
        scan = get_impl("mamba_scan", ssm_scan_ref)
        kw = ({"unroll_chunks": unroll_chunks, "chunk": chunk}
              if scan is ssm_scan_ref else {})
        y, hT = scan(xh, dt, A, Bm, Cm, p["D"], h0=ssm_state, **kw)
    else:
        nh = ci // headdim
        bc = xh @ p["bc_proj"]
        Bm, Cm = jnp.split(bc, 2, axis=-1)              # (B,S,N)
        dt = jax.nn.softplus(xh @ p["dt_proj2"] + p["dt_bias"])  # (B,S,H)
        A = -jnp.exp(p["A_log"])                        # (H,)
        xh_h = xh.reshape(b, s, nh, headdim)
        y, hT = _ssd_scan(xh_h, dt, A, Bm, Cm, p["D"], ssm_state,
                          chunk=chunk, unroll_chunks=unroll_chunks)
        y = y.reshape(b, s, ci)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"], new_conv, hT


def _ssd_scan(x_h, dt, A, B, C, D, h0=None, chunk: int = SSM_CHUNK,
              unroll_chunks: bool = False):
    """Mamba2 SSD scan, chunked like ssm_scan_ref.
    x_h: (B,S,H,P); dt: (B,S,H); A: (H,); B,C: (B,S,N).
    State: (B,H,P,N)."""
    b, s, h, p_ = x_h.shape
    n = B.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((b, h, p_, n), jnp.float32)
    q = _pick_chunk(s, chunk)
    nc = s // q

    def chunk_body(hc, inp):
        xc, dtc, Bc, Cc = inp                # (q, B, ...)

        def step(hc, t_inp):
            x_t, dt_t, B_t, C_t = t_inp      # (B,H,P) (B,H) (B,N) (B,N)
            dA_t = jnp.exp(dt_t * A)         # (B,H)
            hc = hc * dA_t[..., None, None] + jnp.einsum(
                "bhp,bn->bhpn", x_t * dt_t[..., None], B_t)
            y = jnp.einsum("bhpn,bn->bhp", hc, C_t)
            return hc, y

        hc, ys = jax.lax.scan(step, hc,
                              (xc.astype(jnp.float32),
                               dtc.astype(jnp.float32),
                               Bc.astype(jnp.float32),
                               Cc.astype(jnp.float32)))
        return hc, ys

    def to_chunks(a, feat_shape):
        return jnp.moveaxis(a.reshape((b, nc, q) + feat_shape), 1, 0
                            ).swapaxes(1, 2)

    xc = to_chunks(x_h, (h, p_))
    dtc = to_chunks(dt, (h,))
    Bc = to_chunks(B, (n,))
    Cc = to_chunks(C, (n,))
    body = jax.checkpoint(chunk_body)
    hT, ys = jax.lax.scan(body, h0.astype(jnp.float32),
                          (xc, dtc, Bc, Cc), unroll=unroll_chunks)
    y = ys.reshape(nc * q, b, h, p_).swapaxes(0, 1).reshape(b, s, h, p_)
    y = y.astype(x_h.dtype) + x_h * D[None, None, :, None].astype(
        x_h.dtype)
    return y, hT
