"""Unified JAX model zoo for the assigned architectures."""
from .model import ArchConfig, MoECfg, SSMCfg, decode_step, init, \
    init_cache, params_count, prefill, train_loss

__all__ = ["ArchConfig", "MoECfg", "SSMCfg", "decode_step", "init",
           "init_cache", "params_count", "prefill", "train_loss"]
