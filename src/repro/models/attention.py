"""Attention implementations.

``chunked_attention`` is the default: an online-softmax attention that
scans over KV blocks, so peak memory is O(seq * block) instead of
O(seq^2) — required for the 32k prefill dry-runs on the production mesh
and it doubles as the pure-jnp oracle for the Pallas flash kernel
(kernels/flash_attention.py).

Layouts: q (B, Hq, Sq, D), k/v (B, Hkv, Skv, D); GQA repeats kv heads.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def repeat_kv(x: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return x
    b, h, s, d = x.shape
    return jnp.broadcast_to(x[:, :, None], (b, h, n_rep, s, d)).reshape(
        b, h * n_rep, s, d)


def naive_attention(q, k, v, *, causal: bool = True,
                    q_offset: int = 0, sm_scale: Optional[float] = None,
                    window: Optional[int] = None) -> jax.Array:
    """O(Sq*Skv) reference — only for tiny test shapes."""
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    k = repeat_kv(k, hq // hkv)
    v = repeat_kv(v, hq // hkv)
    scale = sm_scale if sm_scale is not None else d ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    skv = k.shape[2]
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(v.dtype), v)


def chunked_attention(q, k, v, *, causal: bool = True, q_offset: int = 0,
                      sm_scale: Optional[float] = None,
                      window: Optional[int] = None,
                      block_kv: int = 512) -> jax.Array:
    """Online-softmax attention, scanning KV in blocks of ``block_kv``.

    Equivalent to naive_attention for any shapes (same math, different
    association order), with O(Skv/block) sequential steps and no
    materialized (Sq, Skv) score matrix.
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    n_rep = hq // hkv
    scale = sm_scale if sm_scale is not None else d ** -0.5
    nb = -(-skv // block_kv)
    pad = nb * block_kv - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = k.reshape(b, hkv, nb, block_kv, d)
    vb = v.reshape(b, hkv, nb, block_kv, d)
    qpos = jnp.arange(sq) + q_offset
    q32 = (q * scale).astype(jnp.float32)

    def step(carry, blk):
        m, l, acc = carry
        kblk, vblk, kpos = blk                  # (b,hkv,bk,d) ×2, (bk,)
        kblk = repeat_kv(kblk, n_rep)
        vblk = repeat_kv(vblk, n_rep)
        s = jnp.einsum("bhqd,bhkd->bhqk", q32,
                       kblk.astype(jnp.float32))
        mask = kpos[None, :] <= (qpos[:, None] if causal
                                 else jnp.full((sq, 1), skv + q_offset))
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        mask &= (kpos < skv)[None, :]           # padding
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p, vblk.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hq, sq), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((b, hq, sq), dtype=jnp.float32)
    a0 = jnp.zeros((b, hq, sq, d), dtype=jnp.float32)
    kpos_all = jnp.arange(nb * block_kv).reshape(nb, block_kv)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (jnp.moveaxis(kb, 2, 0), jnp.moveaxis(vb, 2, 0), kpos_all))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# flash attention (custom VJP): linear-memory forward AND backward.
# The plain chunked_attention above is mathematically identical but its
# scan saves per-block probabilities for autodiff — O(Sq*Skv) residuals.
# This version saves only (out, logsumexp) and recomputes scores per
# block in the backward, exactly like FlashAttention-2; it is the
# pure-jnp oracle for kernels/flash_attention.py.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, causal: bool = True, q_offset: int = 0,
                    window: Optional[int] = None, block_kv: int = 512,
                    unroll: bool = False):
    out, _ = _flash_fwd_impl(q, k, v, causal, q_offset, window, block_kv,
                             unroll)
    return out


def _flash_fwd_impl(q, k, v, causal, q_offset, window, block_kv,
                    unroll: bool = False):
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    n_rep = hq // hkv
    scale = d ** -0.5
    nb = -(-skv // block_kv)
    pad = nb * block_kv - skv
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))) if pad else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))) if pad else v
    kb = jnp.moveaxis(kp.reshape(b, hkv, nb, block_kv, d), 2, 0)
    vb = jnp.moveaxis(vp.reshape(b, hkv, nb, block_kv, d), 2, 0)
    qpos = jnp.arange(sq) + q_offset
    q32 = (q * scale).astype(jnp.float32)
    kpos_all = jnp.arange(nb * block_kv).reshape(nb, block_kv)

    def step(carry, blk):
        m, l, acc = carry
        kblk, vblk, kpos = blk
        kblk = repeat_kv(kblk, n_rep).astype(jnp.float32)
        vblk = repeat_kv(vblk, n_rep).astype(jnp.float32)
        s = jnp.einsum("bhqd,bhkd->bhqk", q32, kblk)
        mask = _blk_mask(kpos, qpos, causal, window, skv, q_offset)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p, vblk)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hq, sq), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((b, hq, sq), dtype=jnp.float32)
    a0 = jnp.zeros((b, hq, sq, d), dtype=jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, kpos_all),
                                  unroll=unroll)
    out = (acc / jnp.maximum(l[..., None], 1e-30)).astype(q.dtype)
    lse = m + jnp.log(jnp.maximum(l, 1e-30))       # logsumexp rows
    return out, lse


def _blk_mask(kpos, qpos, causal, window, skv, q_offset):
    sq = qpos.shape[0]
    if causal:
        mask = kpos[None, :] <= qpos[:, None]
    else:
        mask = jnp.ones((sq, kpos.shape[0]), dtype=bool)
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    mask &= (kpos < skv)[None, :]
    return mask


def _flash_fwd(q, k, v, causal, q_offset, window, block_kv,
               unroll=False):
    out, lse = _flash_fwd_impl(q, k, v, causal, q_offset, window,
                               block_kv, unroll)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, q_offset, window, block_kv, unroll, res, dout):
    q, k, v, out, lse = res
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    n_rep = hq // hkv
    scale = d ** -0.5
    nb = -(-skv // block_kv)
    pad = nb * block_kv - skv
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))) if pad else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))) if pad else v
    kb = jnp.moveaxis(kp.reshape(b, hkv, nb, block_kv, d), 2, 0)
    vb = jnp.moveaxis(vp.reshape(b, hkv, nb, block_kv, d), 2, 0)
    kpos_all = jnp.arange(nb * block_kv).reshape(nb, block_kv)
    qpos = jnp.arange(sq) + q_offset
    q32 = (q * scale).astype(jnp.float32)
    do32 = dout.astype(jnp.float32)
    # D_i = rowsum(dout * out)
    delta = jnp.sum(do32 * out.astype(jnp.float32), axis=-1)  # (b,hq,sq)

    def step(dq_acc, blk):
        kblk, vblk, kpos = blk
        kr = repeat_kv(kblk, n_rep).astype(jnp.float32)
        vr = repeat_kv(vblk, n_rep).astype(jnp.float32)
        s = jnp.einsum("bhqd,bhkd->bhqk", q32, kr)
        mask = _blk_mask(kpos, qpos, causal, window, skv, q_offset)
        s = jnp.where(mask[None, None], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                   # (b,hq,sq,bk)
        dv_r = jnp.einsum("bhqk,bhqd->bhkd", p, do32)
        dp = jnp.einsum("bhqd,bhkd->bhqk", do32, vr)
        ds = p * (dp - delta[..., None])                  # (b,hq,sq,bk)
        dq_blk = jnp.einsum("bhqk,bhkd->bhqd", ds, kr) * scale
        dk_r = jnp.einsum("bhqk,bhqd->bhkd", ds, q32)
        # fold grouped heads back to kv heads
        dk_g = dk_r.reshape(b, hkv, n_rep, block_kv, d).sum(axis=2)
        dv_g = dv_r.reshape(b, hkv, n_rep, block_kv, d).sum(axis=2)
        return dq_acc + dq_blk, (dk_g, dv_g)

    dq0 = jnp.zeros((b, hq, sq, d), jnp.float32)
    dq, (dk_b, dv_b) = jax.lax.scan(step, dq0, (kb, vb, kpos_all),
                                    unroll=unroll)
    dk = jnp.moveaxis(dk_b, 0, 2).reshape(b, hkv, nb * block_kv, d)
    dv = jnp.moveaxis(dv_b, 0, 2).reshape(b, hkv, nb * block_kv, d)
    if pad:
        dk, dv = dk[:, :, :skv], dv[:, :, :skv]
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def flash_attention_ref(q, k, v, *, causal=True, q_offset=0,
                        sm_scale=None, window=None, block_kv=512,
                        unroll=False):
    """Signature-compatible wrapper used as the default attention impl."""
    return flash_attention(q, k, v, causal, q_offset, window, block_kv,
                           unroll)


def decode_attention(q, k_cache, v_cache, cache_len, *,
                     sm_scale: Optional[float] = None,
                     window: Optional[int] = None) -> jax.Array:
    """Single-token decode: q (B, Hq, 1, D) against a (B, Hkv, S, D)
    cache with ``cache_len`` valid positions."""
    b, hq, _, d = q.shape
    hkv, smax = k_cache.shape[1], k_cache.shape[2]
    k = repeat_kv(k_cache, hq // hkv)
    v = repeat_kv(v_cache, hq // hkv)
    scale = sm_scale if sm_scale is not None else d ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", (q * scale).astype(jnp.float32),
                   k.astype(jnp.float32))
    kpos = jnp.arange(smax)[None, None, None, :]
    mask = kpos < cache_len
    if window is not None:
        mask &= kpos >= cache_len - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
