"""Pipeline-parallel schedule builders (paper §6.1/§6.3).

The paper adapts TorchTitan's schedule builders to Piper's API in tens of
LoC; we do the same in JAX.  A builder produces per-rank instruction
sequences of ``PipeOp``s which are emitted as Piper directives:
``Place`` for the stage placement, ``Split`` for microbatches, and one
``Order`` per PP rank (overlapped F/B pairs become nested filter lists —
the DualPipeV mechanism).

Builders:
  gpipe              all-forward then all-backward
  1f1b               canonical PipeDream-flush warmup/steady/drain
  zb1f1b             ZeroBubble-H1-style: 1F1B order with the backward
                     split into Bi (critical) and Bw (bubble filler) —
                     the paper's PASS=Bi/Bw mechanism (§4.1)
  interleaved_1f1b   v virtual stages per rank (stage = chunk*R + rank)
  dualpipev          V-placement (rank r hosts stages r and 2R-1-r) with
                     steady-state overlapped forward+backward microbatch
                     pairs as in DualPipeV [35]

All builders are *generative*: the per-rank tables come from a unit-time
pipeline simulation with the policy's priority rule, so every emitted
schedule respects the pipeline data dependencies by construction (an
invalid hand table would otherwise surface as an IR cycle at compile
time).  The canonical 1F1B table is asserted against the closed form in
tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

from .directives import Order, Place, Split
from .filters import F


@dataclass(frozen=True)
class PipeOp:
    stage: int
    mb: int
    pas: str   # "F" | "B"


# extra in-flight microbatches allowed beyond 2*(R-r) in dualpipev —
# the default for Pipeline(cap_offset=None); sweep it through the
# Pipeline fragment (tuned against the timeline simulator; see
# tests/test_simulator.py — at 6 the comm-free makespan is within ~4%
# of interleaved-1F1B)
DUALPIPEV_CAP_OFFSET = 6


# per-rank sequence entries: PipeOp or tuple[PipeOp, PipeOp] (overlap pair)
RankSeq = list


def stages_of_rank(kind: str, rank: int, n_ranks: int,
                   n_stages: int) -> list[int]:
    if kind == "zb1f1b":
        kind = "1f1b"
    if kind in ("gpipe", "1f1b"):
        # contiguous blocks: v consecutive stages per rank (v=1 is the
        # classic case; v>1 lets 1F1B run the same fine-grained model a
        # DualPipeV/interleaved comparison uses)
        v = n_stages // n_ranks
        return [rank * v + c for c in range(v)]
    if kind == "interleaved_1f1b":
        v = n_stages // n_ranks
        return [c * n_ranks + rank for c in range(v)]
    if kind == "dualpipev":
        assert n_stages == 2 * n_ranks
        return [rank, 2 * n_ranks - 1 - rank]
    raise ValueError(f"unknown schedule kind {kind!r}")


def rank_of_stage(kind: str, stage: int, n_ranks: int, n_stages: int) -> int:
    for r in range(n_ranks):
        if stage in stages_of_rank(kind, r, n_ranks, n_stages):
            return r
    raise ValueError(stage)


def _generate(kind: str, n_ranks: int, n_stages: int,
              n_microbatches: int, split: bool = False,
              cap_offset: Optional[int] = None) -> list[RankSeq]:
    """``split=True`` emits ZeroBubble-style Bi/Bw ops: Bi propagates
    cotangents (pipeline-critical), Bw computes weight grads and is used
    as bubble filler (lowest priority) — required for DualPipeV's drain
    phase to stay busy.  ``cap_offset`` overrides the dualpipev
    in-flight headroom (default ``DUALPIPEV_CAP_OFFSET``)."""
    R, S, M = n_ranks, n_stages, n_microbatches
    dpv_offset = (DUALPIPEV_CAP_OFFSET if cap_offset is None
                  else cap_offset)
    B_TAG = "Bi" if split else "B"
    W_TAG = "Bw"
    my_stages = [stages_of_rank(kind, r, R, S) for r in range(R)]
    done: set[PipeOp] = set()
    seqs: list[RankSeq] = [[] for _ in range(R)]
    total = (3 if split else 2) * S * M

    def ready(op: PipeOp) -> bool:
        if op in done:
            return False
        if op.pas == "F":
            return op.stage == 0 or PipeOp(op.stage - 1, op.mb, "F") in done
        if op.pas == W_TAG:
            return PipeOp(op.stage, op.mb, B_TAG) in done
        if PipeOp(op.stage, op.mb, "F") not in done:
            return False
        return op.stage == S - 1 or PipeOp(op.stage + 1, op.mb,
                                           B_TAG) in done

    def inflight(r: int) -> int:
        f = sum(1 for op in done
                if op.pas == "F" and op.stage in my_stages[r])
        b = sum(1 for op in done
                if op.pas == B_TAG and op.stage in my_stages[r])
        return f - b

    def cap(r: int) -> int:
        if kind == "gpipe":
            return 10 ** 9
        if kind == "1f1b":
            return (R - r) * (S // R)
        if kind == "interleaved_1f1b":
            # Megatron-style: warmup = (R-r-1)*2 + (v-1)*R ops, steady
            # state alternates F/B, so in-flight peaks at warmup+1
            v = S // R
            return (R - r - 1) * 2 + (v - 1) * R + 1
        if kind == "dualpipev":
            return 2 * (R - r) + dpv_offset
        raise ValueError(kind)

    def candidates(r: int, pas: str) -> list[PipeOp]:
        ops = [PipeOp(s, m, pas) for s in my_stages[r] for m in range(M)]
        ops = [op for op in ops if ready(op)]
        if kind == "interleaved_1f1b":
            # wave-major: microbatch waves of R per virtual chunk
            # (chunk0 wave0, chunk1 wave0, chunk0 wave1, …)
            ops.sort(key=lambda op: (op.mb // R,
                                     op.stage if pas == "F" else -op.stage,
                                     op.mb % R))
        else:
            # earliest microbatch first; forwards prefer earlier stages,
            # backwards prefer later stages (drain the V tail first)
            ops.sort(key=lambda op: (op.mb, op.stage if pas == "F"
                                     else -op.stage))
        return ops

    # synchronous rounds: ops completed in round t unblock round t+1
    while len(done) < total:
        round_done: list[PipeOp] = []
        for r in range(R):
            fs = candidates(r, "F")
            bs = candidates(r, B_TAG)
            ws = candidates(r, W_TAG) if split else []
            pick = None
            if kind == "dualpipev":
                # steady state: overlap an F with a B from opposite halves
                pair = None
                for b in bs:
                    for f in fs:
                        if (f.stage < R) != (b.stage < R):
                            pair = (f, b)
                            break
                    if pair:
                        break
                if pair is not None:
                    pick = pair
                elif bs and (inflight(r) >= cap(r) or not fs):
                    pick = bs[0]
                elif fs and inflight(r) < cap(r):
                    pick = fs[0]
                elif bs:
                    pick = bs[0]
                elif ws:
                    pick = ws[0]  # weight-grad ops fill the bubbles
            else:
                prefer_b = bs and (inflight(r) >= cap(r) or not fs)
                if prefer_b:
                    pick = bs[0]
                elif fs and inflight(r) < cap(r):
                    pick = fs[0]
                elif bs:
                    pick = bs[0]
                elif ws:
                    pick = ws[0]
            if pick is None:
                continue
            seqs[r].append(pick)
            round_done.extend(pick if isinstance(pick, tuple) else [pick])
        if not round_done:
            raise RuntimeError(
                f"schedule generator stalled: {kind} R={R} S={S} M={M} "
                f"({len(done)}/{total})")
        done.update(round_done)
    return seqs


def build_rank_sequences(kind: str, n_ranks: int, n_microbatches: int,
                         n_stages: Optional[int] = None,
                         split: Optional[bool] = None,
                         cap_offset: Optional[int] = None) -> list[RankSeq]:
    """``split`` defaults to True for dualpipev (whose drain phase relies
    on Bi/Bw splitting, as in [35]) and False otherwise.  ``cap_offset``
    sweeps the dualpipev in-flight headroom (``Pipeline(cap_offset=)``;
    None keeps ``DUALPIPEV_CAP_OFFSET``)."""
    if n_stages is None:
        n_stages = {"gpipe": n_ranks, "1f1b": n_ranks, "zb1f1b": n_ranks,
                    "interleaved_1f1b": 2 * n_ranks,
                    "dualpipev": 2 * n_ranks}[kind]
    if split is None:
        split = kind in ("dualpipev", "zb1f1b")
    gen_kind = "1f1b" if kind == "zb1f1b" else kind
    return _generate(gen_kind, n_ranks, n_stages, n_microbatches,
                     split=split, cap_offset=cap_offset)


def emit_directives(
    kind: str,
    seqs: list[RankSeq],
    device_groups: Sequence[Sequence[int]],
    n_stages: int,
    pp_dim: str = "pp",
    mb_dim: str = "MB",
    p2p_stream: str = "pp_comm",
    extra_filter: Optional[dict] = None,
) -> list:
    """Translate per-rank sequences into Piper directives.

    ``device_groups[r]``: devices of PP rank r (its DP replicas).
    Returns [Place…, Split, Order…] — caller appends Replicate/Shard
    directives between Place and Split as the strategy requires."""
    R = len(seqs)
    n_mb = 1 + max(op.mb for seq in seqs for ops in seq
                   for op in (ops if isinstance(ops, tuple) else (ops,)))
    directives: list = []
    for s in range(n_stages):
        r = rank_of_stage(kind, s, R, n_stages)
        directives.append(Place(F(**{pp_dim: s}),
                                devices=list(device_groups[r]),
                                stream=p2p_stream))
    directives.append(Split(F(), dim=mb_dim, num_microbatches=n_mb))

    def flt(op: PipeOp):
        spec = {pp_dim: op.stage, mb_dim: op.mb, "PASS": op.pas}
        if extra_filter:
            spec.update(extra_filter)
        return F(**spec)

    orders = []
    for r, seq in enumerate(seqs):
        items = []
        for ops in seq:
            if isinstance(ops, tuple):
                items.append([flt(o) for o in ops])
            else:
                items.append(flt(ops))
        orders.append(Order(items))
    directives.extend(orders)
    return directives


def canonical_1f1b(rank: int, n_ranks: int, n_mb: int) -> list[PipeOp]:
    """Closed-form 1F1B table (for validating the generator)."""
    w = min(n_mb, n_ranks - rank)
    seq = [PipeOp(rank, i, "F") for i in range(w)]
    fb, bb = w, 0
    while bb < n_mb:
        seq.append(PipeOp(rank, bb, "B"))
        bb += 1
        if fb < n_mb:
            seq.append(PipeOp(rank, fb, "F"))
            fb += 1
    return seq
