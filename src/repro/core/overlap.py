"""Joint compute–communication overlap engine (IR pass layer).

The finalization passes in ``passes.py`` only *dedupe* communication
(elide duplicate all-gathers, merge per-microbatch all-reduces); every
remaining ZeRO collective is still per-bucket and dispatched
just-in-time, so its latency sits on the critical path.  This module is
the pass layer that makes the paper's joint compute/communication
scheduling claim real: it rewrites the finalized training DAG so the
timeline simulator and the interpreter agree on *when* ZeRO collectives
may run, and then lets them run early enough to hide behind compute.

Three cooperating passes (run by ``passes.run_all`` when the compiler is
handed an ``OverlapConfig``, after p2p insertion / elision / merging and
before the centralized scheduler):

``bucket_zero_collectives``
    DDP-style size-bounded fusion: param all-gathers (ZeRO-3) and grad
    reduce-scatters (ZeRO-2/3) that share a (device group, stream,
    microbatch) are greedily packed into fused comm nodes of at most
    ``bucket_bytes`` payload.  Fusion is numerics-transparent by
    construction: a fused node's members keep *distinct* param buckets
    (same-bucket collectives of different microbatches are never fused),
    so each per-bucket gather/reduction executes exactly the math it
    executed unfused — the interpreter simply iterates the fused
    members.  The memory ledger charges one fused buffer over the union
    of the members' lifetimes (materialization to last consumer).

``prefetch_gathers``
    Lookahead prefetch: the param all-gather feeding the j-th
    gather-consuming chunk of a device group gets a temporal edge from
    chunk j-k, so at most ``prefetch`` (= k) full-param buffers are ever
    in flight.  k = 1 models today's just-in-time dispatch — the gather
    is fully exposed before its consumer (this is the honest
    "overlap off" baseline, matching what the interpreter's FSDP-style
    ``gather_limit`` rate limiter always enforced dynamically).  k >= 2
    hoists gathers behind the preceding chunks' compute.  The chosen k
    is exported as ``dag.meta["gather_limit"]`` so the interpreter's
    dynamic limiter and the static temporal edges stay in lockstep.

``assign_overlap_streams``
    Hoists param gathers onto a dedicated ``gather`` stream and grad
    reduce-scatters onto a ``reduce`` stream when the user's Replicate
    directive left them on the default stream (where they would
    serialize with compute — the Fig. 4b failure mode).  Reduce-scatters
    are *sunk* implicitly: the scheduler anchors a fused reduce right
    after its last producing backward chunk, so it overlaps the
    remaining backward compute instead of racing the pipeline's critical
    p2p traffic.

The engine also sets ``dag.meta["bubble_aware"]``, which switches the
centralized scheduler's comm anchoring to the stream-occupancy lookahead
score (see ``scheduler.build_plan``): ready comm tasks are dispatched
into simulated pipeline bubbles instead of queueing behind compute whose
gates have not opened yet.

All rewrites preserve interpreter numerics bit-for-bit versus the
non-overlapped plan (tests/test_overlap.py asserts exact equality).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from .dag import Node, TrainingDAG

DEFAULT_BUCKET_BYTES = 32 << 20   # 32 MiB fused-collective payload cap
DEFAULT_PREFETCH = 4              # full-param buffers in flight


@dataclass(frozen=True)
class OverlapConfig:
    """Knobs of the overlap engine.

    ``enabled=False`` is the honest no-overlap baseline: no fusion, no
    stream hoisting, no bubble-aware scheduling, and prefetch pinned to
    1 (just-in-time gather dispatch).  Both modes go through the same
    memory accounting, so benchmarks compare like for like."""
    enabled: bool = True
    bucket_bytes: int = DEFAULT_BUCKET_BYTES   # 0 disables fusion
    prefetch: int = DEFAULT_PREFETCH           # gather lookahead depth k
    gather_stream: Optional[str] = "gather"    # dedicated prefetch lane
    reduce_stream: Optional[str] = "reduce"    # grad reduce-scatter lane
    bubble_aware: bool = True

    @staticmethod
    def off() -> "OverlapConfig":
        return OverlapConfig(enabled=False)

    def to_dict(self) -> dict:
        return {"enabled": self.enabled, "bucket_bytes": self.bucket_bytes,
                "prefetch": self.prefetch, "bubble_aware": self.bubble_aware}


def apply_overlap(dag: TrainingDAG, cfg: OverlapConfig) -> dict:
    """Run the overlap pass layer; returns (and stores in ``dag.meta``)
    the rewrite statistics."""
    stats = {"fused_gathers": 0, "fused_reduce_scatters": 0,
             "prefetch_edges": 0}
    k = max(1, int(cfg.prefetch)) if cfg.enabled else 1
    label = (f"Overlap(prefetch={k}, "
             f"bucket_mb={cfg.bucket_bytes >> 20})" if cfg.enabled
             else "Overlap(enabled=False)")
    if cfg.enabled and cfg.bucket_bytes > 0:
        with dag.origin(label):
            stats.update(bucket_zero_collectives(dag, cfg.bucket_bytes))
    else:
        dag.meta.setdefault("fused_gathers", 0)
        dag.meta.setdefault("fused_reduce_scatters", 0)
    if cfg.enabled:
        assign_overlap_streams(dag, cfg.gather_stream, cfg.reduce_stream)
    with dag.origin(label):
        stats["prefetch_edges"] = prefetch_gathers(dag, k)
    dag.meta["gather_limit"] = k
    dag.meta["bubble_aware"] = bool(cfg.enabled and cfg.bubble_aware)
    dag.meta["overlap"] = {"enabled": cfg.enabled, "prefetch": k,
                           "bucket_bytes":
                               cfg.bucket_bytes if cfg.enabled else 0,
                           **stats}
    dag.validate()
    return stats


# ---------------------------------------------------------------------------
# pass 1: size-bounded collective bucketing
# ---------------------------------------------------------------------------

def _is_param_gather(n: Node) -> bool:
    return n.is_comm and n.op == "all_gather" and n.payload == "param"


def _is_grad_rs(n: Node) -> bool:
    return n.is_comm and n.op == "reduce_scatter" and n.payload == "grad"


def bucket_zero_collectives(dag: TrainingDAG, budget: int) -> dict:
    """Fuse small ZeRO collectives into byte-bounded buckets.

    Candidates group by (participants, stream, microbatch [, pass]) and
    are packed greedily in consumer/producer order; a run closes when
    adding the next member would exceed ``budget`` or repeat a (param
    bucket, part) already in the run.  Members of a fused node always
    carry distinct param buckets for the same microbatch, which is what
    keeps fusion numerics-transparent (per-bucket math is unchanged,
    only the rendezvous is shared)."""
    topo = dag.topo_index()
    n_g = _fuse_group(
        dag, topo, budget,
        nodes=[n for n in dag.comms() if _is_param_gather(n)
               and not dag.preds(n.id)],
        group_key=lambda n: (tuple(n.group or ()), n.stream,
                             n.dims.get("PASS"), n.dims.get("MB")),
        order_key=lambda n: min((topo[e.dst] for e in dag.out_edges(n.id)),
                                default=topo[n.id]),
        fuse=_fuse_gather_run)
    n_r = _fuse_group(
        dag, topo, budget,
        nodes=[n for n in dag.comms() if _is_grad_rs(n)
               and not dag.out_edges(n.id)
               and not any(u == n.id for (u, _) in dag.temporal)],
        group_key=lambda n: (tuple(n.group or ()), n.stream,
                             n.dims.get("MB")),
        order_key=lambda n: max((topo[e.src] for e in dag.in_edges(n.id)),
                                default=topo[n.id]),
        fuse=_fuse_rs_run)
    dag.meta["fused_gathers"] = dag.meta.get("fused_gathers", 0) + n_g
    dag.meta["fused_reduce_scatters"] = \
        dag.meta.get("fused_reduce_scatters", 0) + n_r
    return {"fused_gathers": n_g, "fused_reduce_scatters": n_r}


def _member_ident(n: Node) -> list[tuple]:
    """(bucket, part) identities a node carries (fused nodes carry many)."""
    members = n.meta.get("fused_members")
    if members:
        return [(m["bucket"], m.get("part", 0)) for m in members]
    return [(n.meta.get("bucket"), n.meta.get("part", 0))]


def _fuse_group(dag, topo, budget, *, nodes, group_key, order_key,
                fuse) -> int:
    groups: dict[tuple, list[Node]] = {}
    for n in nodes:
        groups.setdefault(group_key(n), []).append(n)
    fused = 0
    for key in sorted(groups, key=repr):
        members = sorted(groups[key], key=lambda n: (order_key(n), n.id))
        runs: list[list[Node]] = [[]]
        run_bytes = 0
        run_idents: set[tuple] = set()
        for n in members:
            nb = n.total_out_bytes()
            idents = set(_member_ident(n))
            if runs[-1] and (run_bytes + nb > budget
                            or (run_idents & idents)):
                runs.append([])
                run_bytes, run_idents = 0, set()
            runs[-1].append(n)
            run_bytes += nb
            run_idents |= idents
        for run in runs:
            if len(run) >= 2:
                fuse(dag, run)
                fused += 1
    return fused


def _fuse_gather_run(dag: TrainingDAG, run: list[Node]) -> Node:
    """Replace a run of param all-gathers with one fused gather.  Each
    member's output slot survives as a distinct slot of the fused node;
    consumer chunks re-point their ``param_from_comm`` at it so the
    runtime charges a single fused full-param buffer from
    materialization to the *last* member's last consumer."""
    buckets, specs = [], []
    for n in run:
        buckets.extend(n.meta.get("buckets") or [n.meta["bucket"]])
        specs.extend(n.out_specs)
    first = run[0]
    fused = dag.new_node(
        kind="comm", op="all_gather",
        name="all_gather:" + "+".join(buckets),
        dims=dict(first.dims), devices=first.devices, group=first.group,
        stream=first.stream, payload="param", out_specs=specs,
        meta={"buckets": buckets, "fused": len(run),
              "pass": "apply_overlap"})
    slot = 0
    member_ids = set()
    for n in run:
        n_slots = len(n.out_specs)
        for e in list(dag.out_edges(n.id)):
            dag.edges.remove(e)
            dag.add_edge(fused.id, slot + e.src_out, e.dst, e.dst_in,
                         e.spec)
        slot += n_slots
        member_ids.add(n.id)
    _remap_temporal(dag, member_ids, fused.id)
    for node in dag.nodes.values():
        if node.meta.get("param_from_comm") in member_ids:
            node.meta["param_from_comm"] = fused.id
    for n in run:
        dag.remove_node(n.id)
    return fused


def _fuse_rs_run(dag: TrainingDAG, run: list[Node]) -> Node:
    """Replace a run of grad reduce-scatters with one fused node.  The
    members' per-bucket reductions are recorded in ``fused_members`` and
    executed one by one by the interpreter — identical math, shared
    dispatch."""
    buckets, specs, members = [], [], []
    for n in run:
        sub = n.meta.get("fused_members") or [{
            "bucket": n.meta.get("bucket"),
            "part": n.meta.get("part", 0),
            "n_parts": n.meta.get("n_parts", 1),
            "accumulated": bool(n.meta.get("accumulated"))}]
        members.extend(sub)
        buckets.extend(m["bucket"] for m in sub)
        specs.extend(n.out_specs)
    first = run[0]
    fused = dag.new_node(
        kind="comm", op="reduce_scatter",
        name="reduce_scatter:" + "+".join(dict.fromkeys(buckets)),
        dims=dict(first.dims), devices=first.devices, group=first.group,
        stream=first.stream, payload="grad", out_specs=specs,
        meta={"buckets": list(dict.fromkeys(buckets)),
              "fused_members": members, "fused": len(run),
              "pass": "apply_overlap"})
    member_ids = set()
    for i, n in enumerate(run):
        for e in list(dag.in_edges(n.id)):
            dag.edges.remove(e)
            dag.add_edge(e.src, e.src_out, fused.id, i, e.spec)
        member_ids.add(n.id)
    _remap_temporal(dag, member_ids, fused.id)
    for bucket, sinks in list(dag.grad_sinks.items()):
        dag.grad_sinks[bucket] = [
            ((fused.id, 0) if nid in member_ids else (nid, s))
            for (nid, s) in sinks]
    for n in run:
        dag.remove_node(n.id)
    return fused


def _remap_temporal(dag: TrainingDAG, member_ids: set[int],
                    new_id: int) -> None:
    moved = {(u, v) for (u, v) in dag.temporal
             if u in member_ids or v in member_ids}
    for (u, v) in moved:
        dag.temporal.discard((u, v))
        dag.add_temporal(new_id if u in member_ids else u,
                         new_id if v in member_ids else v)


# ---------------------------------------------------------------------------
# pass 2: dedicated streams
# ---------------------------------------------------------------------------

def assign_overlap_streams(dag: TrainingDAG,
                           gather_stream: Optional[str],
                           reduce_stream: Optional[str]) -> None:
    """Hoist ZeRO collectives off the default compute stream.  Streams
    the user already dedicated (``Replicate(gather_stream=...)``) are
    respected."""
    from .passes import DEFAULT_STREAM
    for n in dag.comms():
        on_default = n.stream in (None, DEFAULT_STREAM)
        if _is_param_gather(n) and gather_stream and on_default:
            n.stream = gather_stream
        elif _is_grad_rs(n) and reduce_stream and on_default:
            n.stream = reduce_stream


# ---------------------------------------------------------------------------
# pass 3: lookahead prefetch
# ---------------------------------------------------------------------------

def prefetch_gathers(dag: TrainingDAG, k: int) -> int:
    """Gate each param all-gather k gather-consuming chunks ahead of its
    first consumer: temporal edge chunk[j-k] -> gather(chunk[j]).  This
    bounds in-flight full-param buffers to k per device group (the
    memory ledger's honesty condition) while letting the gather's wire
    time hide behind chunks j-k..j-1.  Edges are provably acyclic: the
    anchor chunk precedes the gather's first consumer in topological
    order, and every path out of a gather goes through a consumer.

    Returns the number of temporal edges added."""
    topo = dag.topo_index()
    seq_of: dict[tuple, list[int]] = {}
    for n in sorted(dag.chunks(), key=lambda n: topo[n.id]):
        seq_of.setdefault(tuple(n.devices or ()), []).append(n.id)
    index_of = {nid: i for seq in seq_of.values()
                for i, nid in enumerate(seq)}
    added = 0
    gathers = sorted((n for n in dag.comms() if _is_param_gather(n)),
                     key=lambda n: topo[n.id])
    for g in gathers:
        if dag.preds(g.id):
            continue  # already gated (idempotence / user-ordered)
        consumers = [e.dst for e in dag.out_edges(g.id)
                     if dag.nodes[e.dst].is_chunk]
        if not consumers:
            continue
        first = min(consumers, key=lambda c: topo[c])
        seq = seq_of.get(tuple(dag.nodes[first].devices or ()), [])
        j = index_of.get(first)
        if j is None or j - k < 0:
            continue  # within the first k chunks: free to prefetch at t=0
        dag.add_temporal(seq[j - k], g.id)
        added += 1
    return added
