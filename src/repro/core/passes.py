"""Compiler finalization passes (paper §4.2 phase 2 tail).

  insert_p2p         — send/recv comms at cross-placement data edges
  elide_allgathers   — collapse duplicate param all-gathers (ZeRO-3)
  merge_grad_reduces — collapse per-microbatch all-reduces into one
                       accumulated reduce (classic grad accumulation);
                       ZeRO-2 reduce-scatters are kept per-microbatch so
                       full-gradient buffers can be freed (paper §6.2)
  assign_default_streams — unassigned nodes run on the default stream

When the compiler is handed an ``OverlapConfig``, the joint
compute–communication overlap engine (``overlap.py``: collective
bucketing, lookahead gather prefetch, bubble-aware scheduling hints)
runs as the tail of this pass layer, after the dedup passes above.
"""
from __future__ import annotations

from .dag import PASS_B, TrainingDAG, ValueSpec

DEFAULT_STREAM = "main"


def insert_p2p(dag: TrainingDAG) -> None:
    """Insert p2p comm nodes on data edges whose endpoints have different
    placements.  Replicated groups transfer pairwise (rank i -> rank i).

    A value consumed by several nodes on the same destination placement is
    sent ONCE and retained on the receiver (the runtime frees it after the
    last consumer) — e.g. a stage boundary activation consumed by both the
    next stage's forward and (as residual) its backward."""
    p2p_streams = dag.meta.get("p2p_streams", {})
    # (src_node, src_out, dst_devices) -> p2p comm node
    existing: dict[tuple, int] = {}
    for e in list(dag.edges):
        src, dst = dag.nodes[e.src], dag.nodes[e.dst]
        if src.devices is None or dst.devices is None:
            continue
        if tuple(src.devices) == tuple(dst.devices):
            continue
        if (src.is_comm and src.op == "p2p") or (
                dst.is_comm and dst.op == "p2p"):
            continue
        sd, dd = tuple(src.devices), tuple(dst.devices)
        if set(sd) & set(dd):
            raise ValueError(
                f"overlapping-but-unequal placements {sd} -> {dd} between "
                f"{src.short()} and {dst.short()}: Shard/Replicate devices "
                "must match their neighbours' placement (paper §4.1: 'this "
                "requires that the preceding or subsequent Chunk has the "
                "same devices')")
        key = (e.src, e.src_out, dd)
        if key in existing:
            comm_id = existing[key]
            dag.edges.remove(e)
            dag.add_edge(comm_id, 0, e.dst, e.dst_in, e.spec)
            continue
        if len(sd) == len(dd):
            pairs = list(zip(sd, dd))
        elif len(sd) == 1:
            pairs = [(sd[0], d) for d in dd]
        elif len(dd) == 1:
            pairs = [(s, dd[0]) for s in sd]
        else:
            raise ValueError(
                f"cannot pair devices {sd} -> {dd} for p2p between "
                f"{src.short()} and {dst.short()}")
        # stream intent survives Split via node.meta (the id-keyed map
        # only covers pre-Split nodes)
        stream = (src.meta.get("p2p_stream") or dst.meta.get("p2p_stream")
                  or p2p_streams.get(e.src) or p2p_streams.get(e.dst))
        comm = dag.new_node(
            kind="comm", op="p2p", name=f"p2p:{src.name}->{dst.name}",
            dims=dict(dst.dims), devices=tuple(sd) + tuple(dd),
            stream=stream, payload="act", out_specs=[e.spec],
            meta={"pairs": pairs})
        dag.splice_comm_on_edge(e, comm)
        existing[key] = comm.id


def elide_allgathers(dag: TrainingDAG) -> None:
    """If two directly adjacent chunks consume the same (ZeRO-3 sharded)
    bucket, drop the second all-gather and extend the first buffer's
    lifetime (paper: 'collapses these into one allgather')."""
    for e in list(dag.edges):
        src, dst = dag.nodes.get(e.src), dag.nodes.get(e.dst)
        if src is None or dst is None or not (src.is_chunk and dst.is_chunk):
            continue
        if not src.bucket or src.bucket != dst.bucket:
            continue
        g_src = src.meta.get("param_from_comm")
        g_dst = dst.meta.get("param_from_comm")
        if g_src is None or g_dst is None or g_src == g_dst:
            continue
        if dag.nodes[g_src].devices != dag.nodes[g_dst].devices:
            continue
        dag.remove_node(g_dst)
        dst.meta["param_from_comm"] = g_src
        dag.meta.setdefault("elided_allgathers", 0)
        dag.meta["elided_allgathers"] += 1


def merge_grad_reduces(dag: TrainingDAG) -> None:
    """Collapse per-microbatch gradient all-reduces of a bucket into one
    accumulated all-reduce after the last backward chunk.  Only applies to
    unsharded gradients; ZeRO-2 reduce-scatters stay per-microbatch (the
    paper reduces 'after every backward pass instead of accumulating' to
    realize the memory savings)."""
    topo_pos = dag.topo_index()
    for bucket, b in dag.buckets.items():
        if b.replica_devices is None or b.shard_grads:
            continue
        ars = [n for n in dag.comms()
               if n.op == "all_reduce" and n.meta.get("bucket") == bucket]
        by_part: dict[int, list] = {}
        for n in ars:
            by_part.setdefault(n.meta.get("part", 0), []).append(n)
        new_sinks = []
        for part, group in sorted(by_part.items()):
            if len(group) <= 1:
                if group:
                    new_sinks.append((group[0].id, 0))
                continue
            group.sort(key=lambda n: topo_pos[n.id])
            keep = group[-1]
            producers = []
            for n in group:
                for e in dag.in_edges(n.id):
                    producers.append(e.src)
            for n in group[:-1]:
                dag.remove_node(n.id)
            keep.meta["accumulated"] = True
            keep.meta["n_accumulated"] = len(group)
            for p in producers:
                if p != keep.id and p in dag.nodes:
                    dag.add_temporal(p, keep.id)
            new_sinks.append((keep.id, 0))
            dag.meta.setdefault("merged_reduces", 0)
            dag.meta["merged_reduces"] += len(group) - 1
        if new_sinks:
            dag.grad_sinks[bucket] = new_sinks


def assign_default_streams(dag: TrainingDAG) -> None:
    for n in dag.nodes.values():
        if n.stream is None:
            n.stream = DEFAULT_STREAM


def assign_default_devices(dag: TrainingDAG) -> None:
    """Nodes untouched by placement directives run on device 0 (the paper
    validates all placements are present; we default like its future-work
    propagation note, but only to the trivial single device)."""
    for n in dag.nodes.values():
        if n.devices is None:
            n.devices = dag.default_devices


def run_all(dag: TrainingDAG, overlap=None) -> None:
    assign_default_devices(dag)
    insert_p2p(dag)
    elide_allgathers(dag)
    merge_grad_reduces(dag)
    assign_default_streams(dag)
    if overlap is not None:
        from .overlap import apply_overlap  # late: overlap imports us
        apply_overlap(dag, overlap)
    dag.validate()
