"""Compiler finalization passes (paper §4.2 phase 2 tail) and the
activation-memory IR transformations (DESIGN.md §11).

  apply_remat        — rewrite backward chunks' residual edges for the
                       ``Remat`` directive: stash the vjp residuals as
                       explicit forward outputs (``policy="none"``)
                       instead of re-running the forward (``"full"``,
                       today's default), or alternate per chunk
                       (``"selective"``).  Runs on the single-device DAG
                       right after autodiff, before any directives.
  insert_p2p         — send/recv comms at cross-placement data edges
  elide_allgathers   — collapse duplicate param all-gathers (ZeRO-3)
  merge_grad_reduces — collapse per-microbatch all-reduces into one
                       accumulated reduce (classic grad accumulation);
                       ZeRO-2 reduce-scatters are kept per-microbatch so
                       full-gradient buffers can be freed (paper §6.2)
  apply_offload      — ``Offload`` directive: splice d2h/h2d host
                       round-trip comm nodes on residual edges whose
                       forward->backward stash window exceeds ``depth``
                       chunks, on a dedicated offload stream
  assign_default_streams — unassigned nodes run on the default stream

When the compiler is handed an ``OverlapConfig``, the joint
compute–communication overlap engine (``overlap.py``: collective
bucketing, lookahead gather prefetch, bubble-aware scheduling hints)
runs as the tail of this pass layer, after the dedup passes above.
"""
from __future__ import annotations

from .dag import TrainingDAG, ValueSpec

DEFAULT_STREAM = "main"

REMAT_POLICIES = ("full", "selective", "none")


# ---------------------------------------------------------------------------
# Remat — programmable residual policy (runs before directives)
# ---------------------------------------------------------------------------

def apply_remat(dag: TrainingDAG, policy: str, params: dict,
                scope: dict | None = None) -> int:
    """Rewrite backward chunks' residual edges for the declared
    activation-memory policy.

    ``"full"`` (the default the repo always had): each backward chunk
    re-runs its forward under ``jax.vjp`` from the chunk-boundary
    activations — nothing to rewrite.  ``"none"``: the forward chunk is
    rewritten to emit its vjp residuals as additional outputs, and the
    backward chunk consumes those stashed arrays instead of re-running
    the forward — less recompute (B ~= 2xF instead of 3xF), more live
    activation memory (the residuals stay resident across the
    forward->backward stash window).  ``"selective"`` applies ``"none"``
    to every other matched chunk (Checkmate-style middle point).

    ``scope`` restricts the policy to forward chunks whose ``dims``
    match the given {dim: index} mapping (e.g. ``{"pp": 0}``); ``None``
    matches every chunk.  ``params`` supplies bucket param shapes for
    the ``jax.eval_shape`` residual probe (nothing is allocated).

    Must run on the single-device DAG after ``build_backward`` and
    before any directives (Split clones the rewritten pairs per
    microbatch; ``static_out_slots`` tells Split which residual specs do
    not scale with the batch).  Returns the number of stashed chunks.
    """
    if policy not in REMAT_POLICIES:
        raise ValueError(f"unknown remat policy {policy!r} "
                         f"(choose from {REMAT_POLICIES})")
    import jax

    def in_scope(node) -> bool:
        if not scope:
            return True
        return all(node.dims.get(d) == v for d, v in scope.items())

    fwd_ids = [nid for nid in dag.toposort()
               if dag.nodes[nid].is_chunk
               and dag.nodes[nid].dims.get("PASS") == "F"
               and in_scope(dag.nodes[nid])]
    param_avals = {
        k: jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), v)
        for k, v in params.items()}
    stashed = 0
    for idx, nid in enumerate(fwd_ids):
        chunk_policy = policy if policy != "selective" else \
            ("none" if idx % 2 == 0 else "full")
        fwd = dag.nodes[nid]
        bwds = [b for b in (fwd.meta.get("bwd_node"),
                            fwd.meta.get("bw_node")) if b is not None]
        fwd.meta["remat"] = chunk_policy
        for b in bwds:
            dag.nodes[b].meta["remat"] = chunk_policy
        if chunk_policy == "none" and _stash_residuals(dag, fwd, bwds,
                                                       param_avals):
            stashed += 1
    dag.meta["remat"] = {"policy": policy, "stashed": stashed,
                         "scope": dict(scope) if scope else None}
    return stashed


def _chunk_in_avals(dag: TrainingDAG, nid: int, m: int):
    """ShapeDtypeStructs of a chunk's ``m`` data-input slots."""
    import jax
    specs = [None] * m
    for e in dag.in_edges(nid):
        if 0 <= e.dst_in < m:
            specs[e.dst_in] = e.spec
    for (spec, consumers) in dag.inputs.values():
        for (cnid, slot) in consumers:
            if cnid == nid and 0 <= slot < m:
                specs[slot] = spec
    if any(s is None for s in specs):
        missing = [j for j, s in enumerate(specs) if s is None]
        raise ValueError(f"chunk {dag.nodes[nid].short()} has unfed "
                         f"input slots {missing}")
    return [jax.ShapeDtypeStruct(s.shape, s.dtype) for s in specs]


def _stash_residuals(dag: TrainingDAG, fwd, bwd_ids: list[int],
                     param_avals: dict) -> bool:
    """Rewrite one forward/backward chunk pair to residual-stash form.

    The forward's exec fn becomes ``vjp``-under-the-hood: it returns the
    original outputs plus the vjp closure's residual arrays (the vjp
    function is a pytree; its leaves are the residuals and its treedef
    is static, captured once at build time under ``jax.eval_shape``).
    Each backward chunk reconstructs the closure from the stashed leaves
    and applies it — no forward re-run.  Residual leaves whose shape
    does not scale with the batch (e.g. saved weights) are recorded in
    ``meta["static_out_slots"]`` so Split leaves their specs alone.
    """
    import jax
    from .dag import ValueSpec

    m = fwd.meta.get("n_inputs", 0)
    k = fwd.n_outputs
    base_fn = fwd.fn
    has_bucket = fwd.bucket is not None
    in_avals = _chunk_in_avals(dag, fwd.id, m)
    bkt_aval = param_avals.get(fwd.bucket) if has_bucket else None

    def probe(avals):
        """(treedef, out_avals) of the vjp at the given input avals.
        The treedef embeds the transpose jaxpr — it is SHAPE-SPECIALIZED,
        so the backward re-derives it for the shapes it actually sees
        (Split shrinks every chunk to microbatch shapes)."""
        captured = {}

        def run(bucket, *ins):
            if has_bucket:
                outs, vjp = jax.vjp(base_fn, bucket, *ins)
            else:
                outs, vjp = jax.vjp(lambda *i: base_fn(None, *i), *ins)
            leaves, treedef = jax.tree_util.tree_flatten(vjp)
            captured["treedef"] = treedef
            return tuple(outs) + tuple(leaves)

        out_avals = jax.eval_shape(run, bkt_aval, *avals)
        return captured["treedef"], out_avals

    def scaled_avals(scale: int):
        return [jax.ShapeDtypeStruct(
            ((a.shape[0] // scale,) + tuple(a.shape[1:])) if a.shape
            else a.shape, a.dtype) for a in in_avals]

    _, out_avals = probe(in_avals)
    res_avals = out_avals[k:]
    n_res = len(res_avals)
    if n_res == 0:
        return False  # nothing to stash; full == none for this chunk

    # which residual slots scale with the batch?  probe again with every
    # data input's leading dim doubled; leaves whose shape is unchanged
    # (saved weights, scalars) must keep their spec across Split.
    batch_scaled: set[int] = set(range(n_res))
    try:
        doubled = [jax.ShapeDtypeStruct(
            (2 * a.shape[0],) + tuple(a.shape[1:]) if a.shape else a.shape,
            a.dtype) for a in in_avals]
        _, out2 = probe(doubled)
        batch_scaled = {
            i for i, (a, b) in enumerate(zip(res_avals, out2[k:]))
            if tuple(a.shape) != tuple(b.shape)}
    except Exception:
        pass  # conservatively treat every residual as batch-scaled

    def fwd_stash(bucket, *ins):
        if has_bucket:
            outs, vjp = jax.vjp(base_fn, bucket, *ins)
        else:
            outs, vjp = jax.vjp(lambda *i: base_fn(None, *i), *ins)
        return tuple(outs) + tuple(jax.tree_util.tree_leaves(vjp))
    fwd_stash.__name__ = f"stash_{getattr(base_fn, '__name__', 'chunk')}"

    fwd.fn = fwd_stash
    fwd.n_outputs = k + n_res
    fwd.out_specs = list(fwd.out_specs) + [
        ValueSpec(tuple(a.shape), str(a.dtype)) for a in res_avals]
    fwd.meta["pass"] = "apply_remat"
    fwd.meta["n_res"] = n_res
    fwd.meta["static_out_slots"] = sorted(k + i for i in range(n_res)
                                          if i not in batch_scaled)

    treedef_cache: dict[int, object] = {}

    def treedef_for(scale: int):
        if scale not in treedef_cache:
            treedef_cache[scale], _ = probe(scaled_avals(scale))
        return treedef_cache[scale]

    def runtime_scale(leaves) -> int:
        """Microbatch shrink factor of the runtime leaves vs the build-
        time (full batch) residual avals — Split divides every chunk
        input's leading dim by the same microbatch count."""
        for i, leaf in enumerate(leaves):
            a = res_avals[i]
            if i in batch_scaled and a.shape and leaf.shape \
                    and a.shape[0] != leaf.shape[0]:
                return max(a.shape[0] // max(leaf.shape[0], 1), 1)
        return 1

    def make_stash_bwd(pass_tag: str):
        def bwd(bucket, *args):
            leaves, cots = args[:n_res], args[n_res:]
            treedef = treedef_for(runtime_scale(leaves))
            vjp = jax.tree_util.tree_unflatten(treedef, list(leaves))
            grads = vjp(tuple(cots))
            if has_bucket:
                bucket_grads, in_cots = grads[0], grads[1:]
            else:
                bucket_grads, in_cots = None, grads
            if pass_tag == "Bi":
                return (None,) + tuple(in_cots)
            if pass_tag == "Bw":
                return (bucket_grads,) + (None,) * m
            return (bucket_grads,) + tuple(in_cots)
        bwd.__name__ = (f"{pass_tag.lower()}_stash_"
                        f"{getattr(base_fn, '__name__', 'chunk')}")
        return bwd

    for bid in bwd_ids:
        bwd = dag.nodes[bid]
        # drop the old residual input edges (forward inputs re-fed to
        # the backward, slots 0..m-1) and graph-input feed references
        dag.edges = [e for e in dag.edges
                     if not (e.dst == bid and 0 <= e.dst_in < m)]
        for name, (spec, consumers) in list(dag.inputs.items()):
            kept = [(cnid, slot) for (cnid, slot) in consumers
                    if not (cnid == bid and 0 <= slot < m)]
            if len(kept) != len(consumers):
                dag.inputs[name] = (spec, kept)
        # cotangent inputs shift from slot m+j to slot n_res+j
        remapped = []
        for e in dag.edges:
            if e.dst == bid and e.dst_in >= m:
                remapped.append(e)
        for e in remapped:
            dag.edges.remove(e)
            dag.edges.append(e.moved(dst_in=e.dst_in - m + n_res))
        for key in ("seed_slots", "zero_cot_slots"):
            if key in bwd.meta:
                bwd.meta[key] = [s - m + n_res for s in bwd.meta[key]]
        # stash edges: forward residual slot k+i feeds backward slot i
        for i, a in enumerate(res_avals):
            dag.add_edge(fwd.id, k + i, bid, i,
                         ValueSpec(tuple(a.shape), str(a.dtype)))
        bwd.meta["n_inputs"] = n_res + k
        bwd.meta["n_cots"] = k
        bwd.meta["pass"] = "apply_remat"
        bwd.fn = make_stash_bwd(bwd.dims.get("PASS"))
    return True


def insert_p2p(dag: TrainingDAG) -> None:
    """Insert p2p comm nodes on data edges whose endpoints have different
    placements.  Replicated groups transfer pairwise (rank i -> rank i).

    A value consumed by several nodes on the same destination placement is
    sent ONCE and retained on the receiver (the runtime frees it after the
    last consumer) — e.g. a stage boundary activation consumed by both the
    next stage's forward and (as residual) its backward."""
    p2p_streams = dag.meta.get("p2p_streams", {})
    # (src_node, src_out, dst_devices) -> p2p comm node
    existing: dict[tuple, int] = {}
    for e in list(dag.edges):
        src, dst = dag.nodes[e.src], dag.nodes[e.dst]
        if src.devices is None or dst.devices is None:
            continue
        if tuple(src.devices) == tuple(dst.devices):
            continue
        if (src.is_comm and src.op == "p2p") or (
                dst.is_comm and dst.op == "p2p"):
            continue
        sd, dd = tuple(src.devices), tuple(dst.devices)
        if set(sd) & set(dd):
            raise ValueError(
                f"overlapping-but-unequal placements {sd} -> {dd} between "
                f"{src.short()} and {dst.short()}: Shard/Replicate devices "
                "must match their neighbours' placement (paper §4.1: 'this "
                "requires that the preceding or subsequent Chunk has the "
                "same devices')")
        key = (e.src, e.src_out, dd)
        if key in existing:
            comm_id = existing[key]
            dag.edges.remove(e)
            dag.add_edge(comm_id, 0, e.dst, e.dst_in, e.spec)
            continue
        if len(sd) == len(dd):
            pairs = list(zip(sd, dd))
        elif len(sd) == 1:
            pairs = [(sd[0], d) for d in dd]
        elif len(dd) == 1:
            pairs = [(s, dd[0]) for s in sd]
        else:
            raise ValueError(
                f"cannot pair devices {sd} -> {dd} for p2p between "
                f"{src.short()} and {dst.short()}")
        # stream intent survives Split via node.meta (the id-keyed map
        # only covers pre-Split nodes)
        stream = (src.meta.get("p2p_stream") or dst.meta.get("p2p_stream")
                  or p2p_streams.get(e.src) or p2p_streams.get(e.dst))
        comm = dag.new_node(
            kind="comm", op="p2p", name=f"p2p:{src.name}->{dst.name}",
            dims=dict(dst.dims), devices=tuple(sd) + tuple(dd),
            stream=stream, payload="act", out_specs=[e.spec],
            meta={"pairs": pairs, "pass": "insert_p2p",
                  "origin": f"insert_p2p({src.name!r} -> {dst.name!r})"})
        dag.splice_comm_on_edge(e, comm)
        existing[key] = comm.id


def elide_allgathers(dag: TrainingDAG) -> None:
    """If two directly adjacent chunks consume the same (ZeRO-3 sharded)
    bucket, drop the second all-gather and extend the first buffer's
    lifetime (paper: 'collapses these into one allgather')."""
    for e in list(dag.edges):
        src, dst = dag.nodes.get(e.src), dag.nodes.get(e.dst)
        if src is None or dst is None or not (src.is_chunk and dst.is_chunk):
            continue
        if not src.bucket or src.bucket != dst.bucket:
            continue
        if src.dims.get("PASS") != dst.dims.get("PASS"):
            # remat-stash residual edges make a forward and its backward
            # directly adjacent; never extend the forward's gather across
            # the stash window — ZeRO-3 re-gathers in the backward, and
            # pinning the full-param buffer for the whole window would
            # defeat sharding (and deadlock the FSDP-style rate limiter)
            continue
        g_src = src.meta.get("param_from_comm")
        g_dst = dst.meta.get("param_from_comm")
        if g_src is None or g_dst is None or g_src == g_dst:
            continue
        if dag.nodes[g_src].devices != dag.nodes[g_dst].devices:
            continue
        dag.remove_node(g_dst)
        dst.meta["param_from_comm"] = g_src
        # the surviving gather was rewritten in place (its buffer now
        # lives across both consumers) — blame the pass in provenance
        dag.nodes[g_src].meta["pass"] = "elide_allgathers"
        dag.meta.setdefault("elided_allgathers", 0)
        dag.meta["elided_allgathers"] += 1


def merge_grad_reduces(dag: TrainingDAG) -> None:
    """Collapse per-microbatch gradient all-reduces of a bucket into one
    accumulated all-reduce after the last backward chunk.  Only applies to
    unsharded gradients; ZeRO-2 reduce-scatters stay per-microbatch (the
    paper reduces 'after every backward pass instead of accumulating' to
    realize the memory savings)."""
    topo_pos = dag.topo_index()
    for bucket, b in dag.buckets.items():
        if b.replica_devices is None or b.shard_grads:
            continue
        ars = [n for n in dag.comms()
               if n.op == "all_reduce" and n.meta.get("bucket") == bucket]
        by_part: dict[int, list] = {}
        for n in ars:
            by_part.setdefault(n.meta.get("part", 0), []).append(n)
        new_sinks = []
        for _part, group in sorted(by_part.items()):
            if len(group) <= 1:
                if group:
                    new_sinks.append((group[0].id, 0))
                continue
            group.sort(key=lambda n: topo_pos[n.id])
            keep = group[-1]
            producers = []
            for n in group:
                for e in dag.in_edges(n.id):
                    producers.append(e.src)
            for n in group[:-1]:
                dag.remove_node(n.id)
            keep.meta["accumulated"] = True
            keep.meta["n_accumulated"] = len(group)
            keep.meta["pass"] = "merge_grad_reduces"
            with dag.origin(f"merge_grad_reduces({bucket!r})"):
                for p in producers:
                    if p != keep.id and p in dag.nodes:
                        dag.add_temporal(p, keep.id)
            new_sinks.append((keep.id, 0))
            dag.meta.setdefault("merged_reduces", 0)
            dag.meta["merged_reduces"] += len(group) - 1
        if new_sinks:
            dag.grad_sinks[bucket] = new_sinks


# ---------------------------------------------------------------------------
# Offload — host round-trip for long-stash residuals
# ---------------------------------------------------------------------------

def apply_offload(dag: TrainingDAG, payload: str = "act", depth: int = 2,
                  stream: str = "offload") -> int:
    """Splice ``d2h``/``h2d`` host round-trip comm nodes on residual
    edges — data edges from a forward-pass chunk to a backward-pass
    chunk on the same placement (boundary activations and, under
    ``Remat(policy="none")``, stashed vjp residuals).

    Only stashes whose forward->backward window exceeds ``depth`` chunks
    (in the device's dataflow order) are offloaded: short windows are
    not worth the round-trip.  The activation leaves the device ledger
    at ``d2h`` completion and is re-charged at ``h2d``; a temporal edge
    gates each ``h2d`` on the chunk ``depth`` positions before its
    consumer, so fetches overlap the preceding compute while at most
    ~``depth`` fetched-back buffers sit resident early (the PipeDream
    stash-depth pressure knob, per schedule).  Both nodes run on a
    dedicated ``stream`` so the DMA never serializes with compute.

    Runs after ``insert_p2p`` (cross-device residuals go through p2p
    and are skipped).  Returns the number of round-trip pairs."""
    if payload != "act":
        raise ValueError(f"Offload payload {payload!r} not supported "
                         "(only 'act' — activation residuals)")
    topo = dag.topo_index()
    seq_of: dict[tuple, list[int]] = {}
    for n in sorted(dag.chunks(), key=lambda n: topo[n.id]):
        seq_of.setdefault(tuple(n.devices or ()), []).append(n.id)
    index_of = {nid: i for seq in seq_of.values()
                for i, nid in enumerate(seq)}
    pairs = 0
    origin = f"Offload(depth={depth}, stream={stream!r})"
    for e in list(dag.edges):
        src, dst = dag.nodes[e.src], dag.nodes[e.dst]
        if not (src.is_chunk and dst.is_chunk) or e.dst_in < 0:
            continue
        if src.dims.get("PASS") != "F" or \
                dst.dims.get("PASS") not in ("B", "Bi", "Bw"):
            continue
        if tuple(src.devices or ()) != tuple(dst.devices or ()):
            continue
        if index_of[e.dst] - index_of[e.src] <= depth:
            continue  # short stash window: not worth the round-trip
        devices = tuple(src.devices or ())
        # batch-static residuals (stashed weights) are FULL copies on
        # every replica, not per-device batch shards — the cost model
        # and ledger must not divide them by the group size
        static = e.src_out in src.meta.get("static_out_slots", ())
        # separate out/in lanes (one DMA queue per direction, like p2p's
        # #snd/#rcv split): a fetch gated far in the future must never
        # head-of-line-block later stashes from freeing device memory
        d2h = dag.new_node(
            kind="comm", op="d2h", name=f"offload_out:{src.name}",
            dims=dict(dst.dims), devices=devices, group=devices,
            stream=f"{stream}#out", payload=payload, out_specs=[e.spec],
            meta={"offload": True, "offload_static": static,
                  "pass": "apply_offload", "origin": origin})
        h2d = dag.new_node(
            kind="comm", op="h2d", name=f"offload_in:{dst.name}",
            dims=dict(dst.dims), devices=devices, group=devices,
            stream=f"{stream}#in", payload=payload, out_specs=[e.spec],
            meta={"offload": True, "offload_static": static,
                  "pass": "apply_offload", "origin": origin})
        dag.edges.remove(e)
        dag.add_edge(e.src, e.src_out, d2h.id, 0, e.spec)
        dag.add_edge(d2h.id, 0, h2d.id, 0, e.spec)
        dag.add_edge(h2d.id, 0, e.dst, e.dst_in, e.spec)
        gate_j = index_of[e.dst] - depth
        if gate_j > index_of[e.src]:
            with dag.origin(origin):
                dag.add_temporal(seq_of[devices][gate_j], h2d.id)
        pairs += 1
    dag.meta["offload"] = {"payload": payload, "depth": depth,
                           "stream": stream, "pairs": pairs}
    return pairs


def assign_default_streams(dag: TrainingDAG) -> None:
    for n in dag.nodes.values():
        if n.stream is None:
            n.stream = DEFAULT_STREAM


def assign_default_devices(dag: TrainingDAG) -> None:
    """Nodes untouched by placement directives run on device 0 (the paper
    validates all placements are present; we default like its future-work
    propagation note, but only to the trivial single device)."""
    for n in dag.nodes.values():
        if n.devices is None:
            n.devices = dag.default_devices


def run_all(dag: TrainingDAG, overlap=None, offload=None) -> None:
    """``offload``: an ``(payload, depth, stream)``-shaped object (the
    strategy's Offload fragment) or None.

    Under ``REPRO_CHECK_PASSES=1`` (on by default in the test suite via
    ``tests/conftest.py``) the DAG is re-validated at every pass
    boundary, so a pass that corrupts edges or placement fails at its
    own boundary instead of three passes later.  Streams/devices are
    only fully assigned late in the pipeline, so the boundary check
    runs ``toposort`` + dangling-edge checks (the full ``validate``
    still runs once at the end).  On top of the structural checks, each
    boundary **translation-validates** the pass: the DAG's dataflow
    fingerprint (``repro.analysis.equiv``) is captured at entry and a
    pass whose output fingerprints differently raises
    ``PlanVerificationError`` with a PIPER026 diagnostic naming the
    pass — fusion, elision, merging, offload splicing and transport
    insertion are all fingerprint-invariant by construction, so any
    drift is a real rewrite bug."""
    import os
    check = os.environ.get("REPRO_CHECK_PASSES", "") not in ("", "0")
    ref_fp = [None]

    def boundary(pass_name: str) -> None:
        if not check:
            return
        try:
            # dangling references first: toposort KeyErrors on them
            for e in dag.edges:
                if e.src not in dag.nodes or e.dst not in dag.nodes:
                    raise ValueError(f"dangling edge {e}")
            for (u, v) in dag.temporal:
                if u not in dag.nodes or v not in dag.nodes:
                    raise ValueError(f"dangling temporal edge {(u, v)}")
            dag.toposort()
        except ValueError as exc:
            raise ValueError(
                f"DAG invalid at pass boundary after {pass_name!r} "
                f"(REPRO_CHECK_PASSES): {exc}") from exc
        if ref_fp[0] is not None:
            # function-local import: analysis imports core freely
            from ..analysis.diagnostics import (AnalysisReport,
                                                PlanVerificationError)
            from ..analysis.equiv import (certify_equivalent,
                                          dataflow_fingerprint_safe)
            after = dataflow_fingerprint_safe(dag)
            diags = certify_equivalent(ref_fp[0], after, pass_name)
            if diags:
                raise PlanVerificationError(AnalysisReport(
                    diagnostics=diags,
                    meta={"phase": "pass-boundary", "pass": pass_name}))
            if after is not None:
                ref_fp[0] = after

    if check:
        from ..analysis.equiv import dataflow_fingerprint_safe
        ref_fp[0] = dataflow_fingerprint_safe(dag)

    assign_default_devices(dag)
    boundary("assign_default_devices")
    insert_p2p(dag)
    boundary("insert_p2p")
    elide_allgathers(dag)
    boundary("elide_allgathers")
    merge_grad_reduces(dag)
    boundary("merge_grad_reduces")
    if offload is not None:
        apply_offload(dag, payload=offload.payload, depth=offload.depth,
                      stream=offload.stream)
        boundary("apply_offload")
    assign_default_streams(dag)
    boundary("assign_default_streams")
    if overlap is not None:
        from .overlap import apply_overlap  # late: overlap imports us
        apply_overlap(dag, overlap)
        boundary("apply_overlap")
    dag.validate()
