"""Piper core: IR, directives, Strategy API, compiler, scheduler."""
from .compiler import CompiledProgram, compile_training
from .dag import Bucket, Edge, Node, TrainingDAG, ValueSpec
from .directives import Order, Place, Replicate, Shard, Split
from .filters import F
from .overlap import OverlapConfig, apply_overlap
from .plan import DevicePlan, GlobalPlan, ScheduleRejected, Task
from .scheduler import build_plan, validate_comm_order
from .strategy import (SCHEMA_VERSION, ExpertParallel, Mesh, Offload,
                       Overlap, Pipeline, RawDirectives, Remat, Strategy,
                       StrategyError, ZeRO)
from .trace import Recorder, TracedValue

__all__ = [
    "Bucket", "CompiledProgram", "DevicePlan", "Edge", "ExpertParallel",
    "F", "GlobalPlan", "Mesh", "Node", "Offload", "Order", "Overlap",
    "OverlapConfig", "Pipeline", "Place", "RawDirectives", "Recorder",
    "Remat", "Replicate", "SCHEMA_VERSION", "ScheduleRejected", "Shard",
    "Split", "Strategy", "StrategyError", "Task", "TracedValue",
    "TrainingDAG", "ValueSpec", "ZeRO", "apply_overlap", "build_plan",
    "compile_training", "validate_comm_order",
]
