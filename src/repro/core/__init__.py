"""Piper core: IR, directives, compiler, centralized scheduler."""
from .compiler import CompiledProgram, compile_training
from .dag import Bucket, Edge, Node, TrainingDAG, ValueSpec
from .directives import Order, Place, Replicate, Shard, Split
from .filters import F
from .overlap import OverlapConfig, apply_overlap
from .plan import DevicePlan, GlobalPlan, ScheduleRejected, Task
from .scheduler import build_plan, validate_comm_order
from .trace import Recorder, TracedValue

__all__ = [
    "Bucket", "CompiledProgram", "DevicePlan", "Edge", "F", "GlobalPlan",
    "Node", "Order", "OverlapConfig", "Place", "Recorder", "Replicate",
    "ScheduleRejected", "Shard", "Split", "Task", "TracedValue",
    "TrainingDAG", "ValueSpec", "apply_overlap", "build_plan",
    "compile_training", "validate_comm_order",
]
