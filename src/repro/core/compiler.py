"""The Piper compiler (paper §4.2): annotated model + strategy -> plans.

Phase 1: trace the annotated model into a single-device DAG of forward
Chunks and build per-chunk backward Chunks.
Phase 2: lower the user's ``Strategy`` to scheduling directives (or take
a legacy hand-assembled directive list), apply them in order, then run
the finalization passes (p2p insertion, all-gather elision, reduce
merging, stream defaults, optional overlap engine) and hand the DAG to
the centralized scheduler.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

from . import passes
from .autodiff import build_backward
from .dag import TrainingDAG
from .directives import Directive
from .plan import GlobalPlan
from .scheduler import build_plan
from .strategy import RawDirectives, Strategy
from .trace import Recorder


def _directive_label(d: Directive) -> str:
    """Provenance label for a directive: the source-fragment label that
    ``Strategy.lower`` attached, else a short structural description
    (hand-assembled ``RawDirectives`` lists carry no fragment)."""
    label = getattr(d, "origin", None)
    if label:
        return label
    name = type(d).__name__
    devs = getattr(d, "devices", None)
    if devs is not None:
        ds = list(devs)
        dtxt = (f"devices={ds}" if len(ds) <= 4
                else f"devices=[{ds[0]}..{ds[-1]}]x{len(ds)}")
        return f"{name}({dtxt})"
    return name


@dataclass
class CompiledProgram:
    dag: TrainingDAG
    plan: GlobalPlan
    params: dict[str, Any]
    schedule: Sequence[Directive]
    strategy: Optional[Strategy] = None
    stats: dict[str, Any] = field(default_factory=dict)
    # the trace closure, kept so the SAME model can be re-lowered under
    # a different Strategy at runtime (elastic recovery recompiles for
    # the shrunk mesh; ft/elastic.py).  None for hand-built programs.
    forward: Optional[Callable] = None
    inputs: Optional[dict[str, tuple]] = None

    def recompile(self, strategy: Strategy,
                  params: Optional[dict[str, Any]] = None
                  ) -> "CompiledProgram":
        """Re-lower the same traced model under ``strategy`` — plan
        compilation as a runtime event.  ``params`` overrides the bucket
        tree (shapes must match; tracing is shape-only, so avals work).
        Only programs built by ``compile_training`` carry the closure."""
        if self.forward is None or self.inputs is None:
            raise ValueError(
                "this CompiledProgram was not built by compile_training "
                "(no recorded forward/inputs) — nothing to recompile")
        return compile_training(
            self.forward, params if params is not None else self.params,
            self.inputs, strategy=strategy)

    def input_shapes(self) -> dict[str, tuple[tuple[int, ...], str]]:
        """Static base (pre-``Split``) graph-input shapes the runtime
        feeds: ``{name: (shape, dtype)}``.  Microbatched inputs report
        their unsplit leading dim — exactly what a ``run(batch)`` caller
        must supply.  The SPMD executor's schedule replay and the
        ``--backend`` drivers build batches from this."""
        dag = self.dag
        mb = dag.meta.get("microbatch_inputs", {})
        sub_names = {sub for info in mb.values() for sub in info["names"]}
        out: dict[str, tuple[tuple[int, ...], str]] = {}
        for name, (spec, _consumers) in dag.inputs.items():
            if name in sub_names:
                continue
            out[name] = (tuple(spec.shape), str(spec.dtype))
        for base, info in mb.items():
            spec, _ = dag.inputs[info["names"][0]]
            shape = ((spec.shape[0] * info["k"],) + tuple(spec.shape[1:])
                     if spec.shape else spec.shape)
            out[base] = (tuple(shape), str(spec.dtype))
        return out


def _certified_remat(dag: TrainingDAG, remat, params: dict) -> None:
    """Run ``passes.apply_remat`` under translation validation: remat
    rewrites forward/backward pairs in place (stash residuals as extra
    outputs, re-wire the backward's inputs), which must leave the
    dataflow fingerprint unchanged — ``Remat`` trades memory for
    recompute, never math.  Certification is on under
    ``REPRO_CHECK_PASSES=1`` (the whole test suite; see
    tests/conftest.py), matching the ``passes.run_all`` boundaries."""
    import os
    check = os.environ.get("REPRO_CHECK_PASSES", "") not in ("", "0")
    before = None
    if check:
        from ..analysis.equiv import dataflow_fingerprint_safe
        before = dataflow_fingerprint_safe(dag)
    passes.apply_remat(dag, remat.policy, params=params,
                       scope=remat.scope_dict())
    if before is not None:
        from ..analysis.diagnostics import (AnalysisReport,
                                            PlanVerificationError)
        from ..analysis.equiv import (certify_equivalent,
                                      dataflow_fingerprint_safe)
        diags = certify_equivalent(
            before, dataflow_fingerprint_safe(dag), "apply_remat")
        if diags:
            raise PlanVerificationError(AnalysisReport(
                diagnostics=diags,
                meta={"phase": "pass-boundary", "pass": "apply_remat"}))


def compile_training(
    forward: Callable[[Recorder, dict], Any],
    params: dict[str, Any],
    inputs: dict[str, tuple],
    schedule: Sequence[Directive] = (),
    build_bwd: bool = True,
    split_backward: bool = False,
    overlap=None,
    strategy: Optional[Strategy] = None,
    analyze: str = "quick",
) -> CompiledProgram:
    """``forward(rec, tvs)`` builds the model using ``rec.annotate`` /
    ``rec.region`` and returns the loss TracedValue.  ``inputs`` maps
    graph input name -> (shape, dtype).

    ``strategy`` is the front door: a ``core.strategy.Strategy`` whose
    fragments lower to the directive list in canonical order and also
    derive ``split_backward`` (from the Pipeline fragment) and the
    overlap-engine config (from the Overlap fragment).

    ``schedule`` / ``split_backward`` / ``overlap`` are the deprecated
    directive-list spelling; a non-empty ``schedule`` is wrapped into a
    ``RawDirectives`` fragment so both paths share one pipeline.  The
    two spellings are mutually exclusive.

    The strategy's ``Remat`` fragment rewrites the backward chunks'
    residual policy (``passes.apply_remat``) right after autodiff; the
    ``Offload`` fragment splices host round-trip nodes in the
    finalization passes (``passes.apply_offload``).

    ``analyze`` selects the static-verifier depth run on the finished
    plan (``repro.analysis``): ``"quick"`` (default) runs the cheap
    graph passes — interface consistency, comm ordering, stream races;
    ``"deep"`` additionally replays the whole plan through the abstract
    executor (deadlock + buffer-lifetime analysis); ``"off"`` skips
    verification.  Error-severity diagnostics raise
    ``PlanVerificationError`` (a ``ScheduleRejected``)."""
    if strategy is not None:
        if schedule or split_backward or overlap is not None:
            raise ValueError(
                "pass either strategy= or the legacy schedule=/"
                "split_backward=/overlap= arguments, not both")
        strategy.validate()
        split_backward = strategy.split_backward
        overlap = strategy.overlap_config()
    else:
        if schedule:
            warnings.warn(
                "compile_training(schedule=...) is deprecated: declare "
                "a core.strategy.Strategy and pass strategy= instead",
                DeprecationWarning, stacklevel=2)
        strategy = Strategy(
            mesh=None, fragments=(RawDirectives(
                tuple(schedule), split_backward=bool(split_backward)),))
    remat = strategy.remat
    offload = strategy.offload

    rec = Recorder(params)
    tvs = {name: rec.input(name, shape, dtype)
           for name, (shape, dtype) in inputs.items()}
    loss = forward(rec, tvs)
    dag = rec.finalize(*(loss if isinstance(loss, tuple) else (loss,)))

    if build_bwd:
        build_backward(dag, split_backward=split_backward)
        if remat is not None and remat.policy != "full":
            _certified_remat(dag, remat, params)

    directives = strategy.lower(dag=dag)
    for directive in directives:
        # provenance: nodes/temporal edges a directive introduces carry
        # the emitting fragment's label (Strategy.lower attaches one) so
        # static-analysis diagnostics can name the culprit directive
        with dag.origin(_directive_label(directive)):
            directive.apply(dag)

    pipe = strategy.pipeline
    if pipe is not None and pipe.mb_split is not None:
        # scheduling metadata only: cost models and the dispatcher read
        # the per-rank microbatch assignment here; the lowered numerics
        # are bit-identical with or without it (see Pipeline docstring)
        dag.meta["mb_split"] = pipe.mb_split_dict()

    passes.run_all(dag, overlap=overlap, offload=offload)
    plan = build_plan(dag)
    prog = CompiledProgram(dag=dag, plan=plan, params=params,
                           schedule=tuple(directives), strategy=strategy,
                           forward=forward, inputs=dict(inputs))
    prog.stats = {**dag.stats(),
                  "devices": len(plan.devices),
                  "elided_allgathers": dag.meta.get("elided_allgathers", 0),
                  "merged_reduces": dag.meta.get("merged_reduces", 0),
                  "fused_gathers": dag.meta.get("fused_gathers", 0),
                  "fused_reduce_scatters":
                      dag.meta.get("fused_reduce_scatters", 0)}
    if analyze != "off":
        # function-local import: core stays importable on its own and
        # the analysis package imports core freely
        from ..analysis import analyze as analyze_plan
        report = analyze_plan(prog, depth=analyze)
        prog.stats["analysis"] = {"depth": analyze,
                                  "diagnostics": len(report.diagnostics),
                                  "codes": sorted(set(report.codes()))}
        report.raise_if_errors()
    return prog
