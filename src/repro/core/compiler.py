"""The Piper compiler (paper §4.2): annotated model + schedule -> plans.

Phase 1: trace the annotated model into a single-device DAG of forward
Chunks and build per-chunk backward Chunks.
Phase 2: apply the user's scheduling directives in order, then run the
finalization passes (p2p insertion, all-gather elision, reduce merging,
stream defaults) and hand the DAG to the centralized scheduler.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

from . import passes
from .autodiff import build_backward
from .dag import TrainingDAG
from .directives import Directive
from .plan import GlobalPlan
from .scheduler import build_plan
from .trace import Recorder


@dataclass
class CompiledProgram:
    dag: TrainingDAG
    plan: GlobalPlan
    params: dict[str, Any]
    schedule: Sequence[Directive]
    stats: dict[str, Any] = field(default_factory=dict)


def compile_training(
    forward: Callable[[Recorder, dict], Any],
    params: dict[str, Any],
    inputs: dict[str, tuple],
    schedule: Sequence[Directive] = (),
    build_bwd: bool = True,
    split_backward: bool = False,
    overlap=None,
) -> CompiledProgram:
    """``forward(rec, tvs)`` builds the model using ``rec.annotate`` /
    ``rec.region`` and returns the loss TracedValue.  ``inputs`` maps graph
    input name -> (shape, dtype).  ``split_backward`` emits ZeroBubble
    Bi/Bw chunk pairs (needed by dualpipev schedules).  ``overlap`` is an
    optional ``overlap.OverlapConfig``: when given, the joint
    compute–communication overlap engine (collective bucketing, lookahead
    gather prefetch, bubble-aware scheduling) runs as the tail of the
    finalization pass layer."""
    rec = Recorder(params)
    tvs = {name: rec.input(name, shape, dtype)
           for name, (shape, dtype) in inputs.items()}
    loss = forward(rec, tvs)
    dag = rec.finalize(*(loss if isinstance(loss, tuple) else (loss,)))

    if build_bwd:
        build_backward(dag, split_backward=split_backward)

    for directive in schedule:
        directive.apply(dag)

    passes.run_all(dag, overlap=overlap)
    plan = build_plan(dag)
    prog = CompiledProgram(dag=dag, plan=plan, params=params,
                           schedule=tuple(schedule))
    prog.stats = {**dag.stats(),
                  "devices": len(plan.devices),
                  "elided_allgathers": dag.meta.get("elided_allgathers", 0),
                  "merged_reduces": dag.meta.get("merged_reduces", 0),
                  "fused_gathers": dag.meta.get("fused_gathers", 0),
                  "fused_reduce_scatters":
                      dag.meta.get("fused_reduce_scatters", 0)}
    return prog
