"""Centralized scheduler (paper §4.3.1).

Decomposes the global training DAG into per-device sub-plans and resolves a
total order per (device, stream) with the paper's list policy:

  1. pick the ready node (all upstream nodes scheduled) with the most
     downstream dependencies;
  2. append each of its per-device task instances to the queue of the
     task's stream;
  3. mark it scheduled, unblocking successors.

Ties break on node id, making the policy deterministic — which is what
guarantees that all ranks in a collective group dispatch communications in
the same order (paper §4.3.2).  The scheduler then *validates* the
per-direction p2p ordering rule and rejects schedules that violate it.
"""
from __future__ import annotations

import heapq
from collections import defaultdict

from .dag import Node, TrainingDAG
from .passes import DEFAULT_STREAM
from .plan import (ROLE_COLL, ROLE_COMPUTE, ROLE_RECV, ROLE_SEND,
                   DevicePlan, GlobalPlan, ScheduleRejected, Task, TaskKey)


def _node_tasks(node: Node) -> list[Task]:
    """Instantiate a DAG node into per-device tasks."""
    stream = node.stream or DEFAULT_STREAM
    if node.is_chunk:
        return [Task(node.id, d, ROLE_COMPUTE, stream)
                for d in node.devices]
    if node.op == "p2p":
        tasks = []
        for (s, d) in node.meta["pairs"]:
            # paper: separate streams (and communicators) for each p2p
            # direction — sends and recvs never share a queue.
            tasks.append(Task(node.id, s, ROLE_SEND, f"{stream}#snd"))
            tasks.append(Task(node.id, d, ROLE_RECV, f"{stream}#rcv"))
        return tasks
    # collective
    return [Task(node.id, d, ROLE_COLL, stream) for d in node.group]


def build_plan(dag: TrainingDAG) -> GlobalPlan:
    prio = dag.descendants_count()
    preds: dict[int, set[int]] = {nid: dag.preds(nid) for nid in dag.nodes}
    succs: dict[int, set[int]] = {nid: dag.succs(nid) for nid in dag.nodes}

    # ---- overlap groups: positional interleave (paper §4.3.1) -------------
    # Members of a nested Order group are 'symmetric' sub-DAGs the user
    # wants interleaved; give their nodes the group's max priority and
    # tie-break by (position within member, member index) so dispatch
    # alternates member0[0], member1[0], member0[1], member1[1], …
    eff_prio = dict(prio)
    ilv_rank = {nid: 0 for nid in dag.nodes}
    topo_pos = {nid: i for i, nid in enumerate(dag.toposort())}
    for group in dag.overlap_groups:
        live = [sorted((n for n in member if n in dag.nodes),
                       key=lambda n: topo_pos[n])
                for member in group]
        all_nodes = [n for mem in live for n in mem]
        if not all_nodes:
            continue
        gmax = max(prio[n] for n in all_nodes)
        for mi, mem in enumerate(live):
            for pos, n in enumerate(mem):
                eff_prio[n] = gmax
                ilv_rank[n] = pos * len(live) + mi

    def hkey(nid: int) -> tuple:
        return (-eff_prio[nid], ilv_rank[nid], nid)

    # ---- global list scheduling over nodes --------------------------------
    def list_schedule(key_fn):
        order: list[int] = []
        remaining = {nid: len(p) for nid, p in preds.items()}
        ready = [(key_fn(nid), nid)
                 for nid, c in remaining.items() if c == 0]
        heapq.heapify(ready)
        scheduled: set[int] = set()
        while ready:
            _, nid = heapq.heappop(ready)
            if nid in scheduled:
                continue
            scheduled.add(nid)
            order.append(nid)
            for s in succs[nid]:
                remaining[s] -= 1
                if remaining[s] == 0:
                    heapq.heappush(ready, (key_fn(s), s))
        if len(order) != len(dag.nodes):
            raise ScheduleRejected("scheduler could not order all nodes "
                                   "(cycle from Order directives?)")
        return order

    # pass 1: priority order establishes chunk positions
    pos = {nid: i for i, nid in enumerate(list_schedule(hkey))}

    # pass 2: comms anchor to their consumers (gathers/p2p dispatch
    # just-in-time, in consumer order) or producers (grad reductions
    # right after the producing backward) — without this, independent
    # comms (e.g. ZeRO-3 all-gathers, all ready at t=0) land in priority
    # order on their stream while Order directives reorder the consuming
    # chunks, and the two in-order streams deadlock.
    #
    # Bubble-aware mode (set by the overlap engine via
    # ``dag.meta["bubble_aware"]``) extends the descendants-count
    # priority with a stream-occupancy lookahead score: a collective
    # anchors at its *gate* (last producer / prefetch temporal edge)
    # instead of just-before its first consumer, so a comm that is
    # already ready dispatches into the simulated bubble in front of it
    # rather than queueing on its in-order stream behind a comm whose
    # gate has not opened yet (head-of-line blocking would leave the
    # bubble empty).  Anchor ties break toward the least-occupied
    # (device-group, stream) lane.  Gather lanes stay deadlock-free
    # under the interpreter's rate limiter because the overlap engine's
    # prefetch gates are monotone in consumer order.  p2p keeps its
    # production-order anchor — the paper's §4.3.2 send/recv ordering
    # rule is a correctness constraint, not a performance choice.
    bubble_aware = bool(dag.meta.get("bubble_aware"))
    temporal_preds: dict[int, list[int]] = defaultdict(list)
    for (u, v) in dag.temporal:
        temporal_preds[v].append(u)
    anchor = {}
    occupancy: dict[tuple, float] = defaultdict(float)
    occ_load: dict[int, float] = defaultdict(float)
    for nid, node in sorted(dag.nodes.items(),
                            key=lambda kv: pos[kv[0]]):
        if node.is_chunk:
            anchor[nid] = (pos[nid], 0)
            continue
        consumers = [pos[e.dst] for e in dag.out_edges(nid)]
        producers = [pos[e.src] for e in dag.in_edges(nid)]
        if node.op == "p2p" or not consumers:
            # sends dispatch in production order (paper §4.3.2: the
            # receiver must consume in the order produced); grad
            # reductions right after their producing backward
            anchor[nid] = (max(producers, default=pos[nid]), 1)
        elif bubble_aware:
            gates = producers + [pos[u] for u in temporal_preds[nid]]
            anchor[nid] = (max(gates, default=-1), 2)
            lane = (node.devices, node.stream)
            occ_load[nid] = occupancy[lane]
            occupancy[lane] += node.total_out_bytes()
        else:
            anchor[nid] = (min(consumers), -1)   # just before consumer

    sched_order = list_schedule(
        lambda nid: (anchor[nid], occ_load[nid], pos[nid]))

    # ---- decompose into per-device tasks -----------------------------------
    devices = sorted({d for n in dag.nodes.values() for d in n.devices})
    plans = {d: DevicePlan(device=d) for d in devices}
    tasks_of: dict[int, list[Task]] = {}
    for nid in sched_order:
        node = dag.nodes[nid]
        tasks = _node_tasks(node)
        # rendezvous peers
        if node.is_comm and node.op != "p2p":
            keys = [t.key for t in tasks]
            for t in tasks:
                t.peers = [k for k in keys if k != t.key]
        elif node.is_comm and node.op == "p2p":
            by_pair = defaultdict(list)
            for t in tasks:
                by_pair[t.node].append(t)
            sends = [t for t in tasks if t.role == ROLE_SEND]
            recvs = [t for t in tasks if t.role == ROLE_RECV]
            for s, r in zip(sends, recvs):
                s.peers = [r.key]
                r.peers = [s.key]
        tasks_of[nid] = tasks
        for t in tasks:
            plans[t.device].append(t)

    # ---- task-level dependencies -------------------------------------------
    def instances_on(nid: int, device: int) -> list[TaskKey]:
        return [t.key for t in tasks_of[nid] if t.device == device]

    for nid in sched_order:
        node = dag.nodes[nid]
        for t in tasks_of[nid]:
            deps: list[TaskKey] = []
            for e in dag.in_edges(nid):
                src_node = dag.nodes[e.src]
                if node.is_comm and node.op == "p2p":
                    if t.role == ROLE_SEND:
                        deps += instances_on(e.src, t.device)
                    # recv depends on its paired send (set via peers below)
                else:
                    local = instances_on(e.src, t.device)
                    if local:
                        deps += local
                    elif src_node.is_comm and src_node.op == "p2p":
                        # consume from the recv task on this device
                        deps += [k for k in instances_on(e.src, t.device)]
                        deps += [tk.key for tk in tasks_of[e.src]
                                 if tk.device == t.device
                                 and tk.role == ROLE_RECV]
                    else:
                        # cross-device data dep without p2p: collective
                        # produced it on its own group; depend on all
                        deps += [tk.key for tk in tasks_of[e.src]]
            if t.role == ROLE_RECV:
                deps += t.peers  # recv waits for its send
            for (u, v) in dag.temporal:
                if v != nid:
                    continue
                local = instances_on(u, t.device)
                deps += local if local else [tk.key for tk in tasks_of[u]]
            # dedupe, keep deterministic order
            seen = set()
            t.deps = [k for k in deps
                      if not (k in seen or seen.add(k)) and k != t.key]

    plan = GlobalPlan(device_plans=plans, priorities=prio, devices=devices,
                      node_order=list(sched_order))
    validate_comm_order(dag, plan)
    return plan


def validate_comm_order(dag: TrainingDAG, plan: GlobalPlan) -> None:
    """Enforce the paper's communication-ordering rules.

    (a) collectives: all ranks of a (group, stream) communicator must
        dispatch the group's collectives in the same order;
    (b) p2p: for each (src, dst, stream) direction, the send order on src
        must equal the recv order on dst.

    The checks themselves live in the static verifier
    (``repro.analysis.commorder``) which reports PIPER004/PIPER005
    diagnostics naming the first diverging op and its provenance; a
    violation raises ``PlanVerificationError``, a ``ScheduleRejected``
    subclass, so callers keep working unchanged.  Imported function-
    locally — core must stay importable without the analysis package
    (and vice versa at module-load time)."""
    from ..analysis.commorder import comm_order_diagnostics
    from ..analysis.diagnostics import AnalysisReport
    diags = comm_order_diagnostics(dag, plan)
    if diags:
        report = AnalysisReport(diagnostics=diags,
                                meta={"pass": "comm_order"})
        report.raise_if_errors()
