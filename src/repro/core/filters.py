"""Filter algebra for Piper scheduling directives (paper §4.1).

A filter is a mapping ``dim -> value`` where value is:
  - a concrete index/value (``PP=0``, ``PASS="F"``),
  - ``"*"``  : match every node that HAS the tag,
  - ``"-"``  : match only nodes that do NOT have the tag.
Omitting a dim from the filter matches all occurrences of that dim
(present or absent).  ``PASS=*`` is implied unless specified.
"""
from __future__ import annotations

from typing import Any, Iterable

from .dag import Node, TrainingDAG

MATCH_ALL = "*"
MATCH_NONE = "-"


class F:
    """A node filter, e.g. ``F(pp=1, ep="-")`` == paper's ``(PP=1, EP=-)``."""

    def __init__(self, **spec: Any) -> None:
        self.spec = dict(spec)

    def matches(self, node: Node) -> bool:
        for dim, val in self.spec.items():
            has = dim in node.dims
            if val == MATCH_NONE:
                if has:
                    return False
            elif val == MATCH_ALL:
                if not has:
                    return False
            else:
                if not has or node.dims[dim] != val:
                    return False
        return True

    def select(self, dag: TrainingDAG) -> list[int]:
        return [nid for nid in dag.toposort()
                if self.matches(dag.nodes[nid])]

    def __repr__(self) -> str:
        inner = ", ".join(f"{k}={v}" for k, v in self.spec.items())
        return f"F({inner})"


def as_filter(f) -> F:
    if isinstance(f, F):
        return f
    if isinstance(f, dict):
        return F(**f)
    raise TypeError(f"cannot interpret {f!r} as a filter")


def select_union(dag: TrainingDAG, filters: Iterable[F]) -> list[int]:
    seen: set[int] = set()
    out: list[int] = []
    for f in filters:
        for nid in as_filter(f).select(dag):
            if nid not in seen:
                seen.add(nid)
                out.append(nid)
    return out


def sources_within(dag: TrainingDAG, sub: set[int]) -> list[int]:
    """Nodes in ``sub`` with no predecessor inside ``sub``."""
    return [nid for nid in sub if not (dag.preds(nid) & sub)]


def sinks_within(dag: TrainingDAG, sub: set[int]) -> list[int]:
    """Nodes in ``sub`` with no successor inside ``sub``."""
    return [nid for nid in sub if not (dag.succs(nid) & sub)]
