"""Filter algebra for Piper scheduling directives (paper §4.1).

A filter is a mapping ``dim -> value`` where value is:
  - a concrete index/value (``PP=0``, ``PASS="F"``),
  - ``"*"``  : match every node that HAS the tag,
  - ``"-"``  : match only nodes that do NOT have the tag.
Omitting a dim from the filter matches all occurrences of that dim
(present or absent).  ``PASS=*`` is implied unless specified.
"""
from __future__ import annotations

from typing import Any, Iterable

from .dag import Node, TrainingDAG

MATCH_ALL = "*"
MATCH_NONE = "-"


class F:
    """A node filter, e.g. ``F(pp=1, ep="-")`` == paper's ``(PP=1, EP=-)``."""

    def __init__(self, **spec: Any) -> None:
        self.spec = dict(spec)

    def matches(self, node: Node) -> bool:
        for dim, val in self.spec.items():
            has = dim in node.dims
            if val == MATCH_NONE:
                if has:
                    return False
            elif val == MATCH_ALL:
                if not has:
                    return False
            else:
                if not has or node.dims[dim] != val:
                    return False
        return True

    def select(self, dag: TrainingDAG) -> list[int]:
        return [nid for nid in dag.toposort()
                if self.matches(dag.nodes[nid])]

    def __repr__(self) -> str:
        inner = ", ".join(f"{k}={v}" for k, v in self.spec.items())
        return f"F({inner})"


def as_filter(f) -> F:
    if isinstance(f, F):
        return f
    if isinstance(f, dict):
        return F(**f)
    raise TypeError(f"cannot interpret {f!r} as a filter")


def select_union(dag: TrainingDAG, filters: Iterable[F]) -> list[int]:
    seen: set[int] = set()
    out: list[int] = []
    for f in filters:
        for nid in as_filter(f).select(dag):
            if nid not in seen:
                seen.add(nid)
                out.append(nid)
    return out


def no_match_report(dag: TrainingDAG, filters, what: str = "nodes") -> str:
    """Actionable diagnostic for a filter that selected nothing: the dim
    names (with their value sets) that actually exist in the DAG, plus
    the nearest-matching nodes — the ones satisfying the most filter
    constraints — so a typo'd dim name or off-by-one stage index is
    visible in the error itself."""
    if isinstance(filters, (F, dict)):
        filters = [filters]
    filters = [as_filter(f) for f in filters]
    dims: dict[str, set] = {}
    for node in dag.nodes.values():
        for k, v in node.dims.items():
            dims.setdefault(k, set()).add(v)
    dim_desc = ", ".join(
        f"{k}∈{{{', '.join(str(v) for v in sorted(vals, key=str)[:8])}}}"
        for k, vals in sorted(dims.items())) or "<none>"

    def satisfied(f: F, node: Node) -> int:
        n = 0
        for dim, val in f.spec.items():
            has = dim in node.dims
            if val == MATCH_NONE:
                n += not has
            elif val == MATCH_ALL:
                n += has
            else:
                n += has and node.dims[dim] == val
        return n

    def score(node: Node) -> int:
        return max((satisfied(f, node) for f in filters), default=0)

    ranked = sorted(dag.nodes.values(), key=lambda n: (-score(n), n.id))
    nearest = ", ".join(n.short() for n in ranked[:3]) or "<empty DAG>"
    return (f"matched no {what}.  Available dims: {dim_desc}.  "
            f"Nearest nodes: {nearest}")


def sources_within(dag: TrainingDAG, sub: set[int]) -> list[int]:
    """Nodes in ``sub`` with no predecessor inside ``sub``."""
    return [nid for nid in sub if not (dag.preds(nid) & sub)]


def sinks_within(dag: TrainingDAG, sub: set[int]) -> list[int]:
    """Nodes in ``sub`` with no successor inside ``sub``."""
    return [nid for nid in sub if not (dag.succs(nid) & sub)]
