"""First-class Strategy API: named-axis mesh, composable fragments,
serializable plans.

The paper's user surface is "a small set of model annotations and
scheduling directives"; this module is the declarative layer over the
raw ``Place/Replicate/Shard/Split/Order`` directive language so humans,
the autotuner (``repro.tune``), and the plan cache all speak ONE
dialect:

  mesh  = Mesh(pp=4, dp=2)                    # named axes, rank-major
  strat = Strategy(mesh, Pipeline("1f1b", n_mb=8)
                         | ZeRO(stage=3)
                         | Overlap(prefetch=4, bucket_mb=32))
  prog  = compile_training(fwd, params, inputs, strategy=strat)

A ``Strategy`` lowers to today's directive list in a *canonical* order —
Place..., Replicate/Shard..., Split, Order... — so the documented
Split-before-Order footgun (directives.py) cannot be expressed through
this API, and the lowered plan is identical to the hand-assembled lists
the repo used before (tests/test_strategy.py asserts per-device plan
parity for every schedule kind).

Strategies serialize: ``Strategy.to_json()`` emits a canonical
(sorted-keys, compact separators) JSON document with a schema version,
``Strategy.from_json`` round-trips it byte-stably and rejects unknown
schema versions or fragment kinds.  The autotuner's plan cache stores
these documents, and ``launch/train.py --strategy plan.json`` replays
one.

Schema version policy: ``SCHEMA_VERSION`` names the exact field set —
it bumps whenever a serialized field changes meaning, a fragment's
lowering changes semantics, or any field or fragment kind is ADDED
(``to_dict`` always emits every field and ``from_dict`` rejects unknown
ones, so "additive" changes are not readable by older builds either).
Readers reject newer and older versions alike — a stale strategy is
re-derived, never guessed at.

Version history: 1 = PR 3 (Pipeline/ZeRO/ExpertParallel/Overlap);
2 = PR 4 (adds Remat + Offload kinds, Pipeline.cap_offset,
RawDirectives.split_backward); 3 = PR 7 (adds Pipeline.mb_split, the
straggler-rebalance per-rank microbatch assignment).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, fields
from typing import Optional, Sequence, Union

import numpy as np

from .directives import Directive, Replicate, Shard
from .filters import F
from .overlap import OverlapConfig
from .passes import REMAT_POLICIES

SCHEMA_VERSION = 3

# the five generative PP schedule builders in core/schedules.py; kept
# here (and re-exported by tune.space) so strategy validation does not
# import the builder module at class-definition time
SCHEDULE_KINDS = ("gpipe", "1f1b", "zb1f1b", "interleaved_1f1b",
                  "dualpipev")


class StrategyError(ValueError):
    """A strategy failed validation / (de)serialization.  The message
    always names the offending fragment or JSON field."""


# ---------------------------------------------------------------------------
# Mesh — named-axis logical device mesh
# ---------------------------------------------------------------------------

class Mesh:
    """A logical device mesh with *named* axes, e.g. ``Mesh(pp=4, dp=2)``.

    Axis order is significant: devices are numbered rank-major (the
    first axis is slowest-varying), so ``Mesh(pp=4, dp=2)`` numbers
    device = pp_rank * 2 + dp_index — exactly the rank-major groups the
    schedule benches and ``tune.space.MeshSpec`` always hand-assembled.
    Fragments reference axes by name instead of raw device-id lists.
    """

    def __init__(self, **axes: int) -> None:
        if not axes:
            raise StrategyError("Mesh needs at least one named axis, "
                                "e.g. Mesh(pp=4, dp=2)")
        for name, size in axes.items():
            if not isinstance(size, int) or isinstance(size, bool) \
                    or size < 1:
                raise StrategyError(
                    f"Mesh axis {name!r} must be a positive int, "
                    f"got {size!r}")
        self._axes: tuple[tuple[str, int], ...] = tuple(axes.items())

    # -- shape accessors ----------------------------------------------------
    @property
    def axis_names(self) -> tuple[str, ...]:
        return tuple(n for n, _ in self._axes)

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(s for _, s in self._axes)

    @property
    def n_devices(self) -> int:
        n = 1
        for _, s in self._axes:
            n *= s
        return n

    def axis_size(self, name: str, default: Optional[int] = None) -> int:
        for n, s in self._axes:
            if n == name:
                return s
        if default is not None:
            return default
        raise StrategyError(
            f"Mesh has no axis {name!r} (axes: {list(self.axis_names)})")

    def __getitem__(self, name: str) -> int:
        return self.axis_size(name)

    def __contains__(self, name: str) -> bool:
        return name in self.axis_names

    # -- device-group derivation (rank-major) -------------------------------
    def device_array(self) -> np.ndarray:
        """Device ids as an ndarray of the mesh shape (rank-major)."""
        return np.arange(self.n_devices).reshape(self.shape)

    def resized(self, axis: str, size: int) -> "Mesh":
        """A new mesh with ``axis`` resized to ``size`` (same axis order,
        ranks renumbered rank-major) — the elastic planner's primitive
        for deriving a shrunk mesh from surviving ranks
        (``ft.elastic.shrink_for_survivors``)."""
        if axis not in self:
            raise StrategyError(
                f"Mesh has no axis {axis!r} (axes: {list(self.axis_names)})")
        return Mesh(**{n: (size if n == axis else s)
                       for n, s in self._axes})

    def rank_coords(self, rank: int) -> dict[str, int]:
        """Axis coordinates of a rank-major device id."""
        if not 0 <= rank < self.n_devices:
            raise StrategyError(
                f"rank {rank} outside mesh of {self.n_devices} devices")
        coords = {}
        for name, s in reversed(self._axes):
            coords[name] = rank % s
            rank //= s
        return dict(reversed(coords.items()))

    def device_groups(self, axis: str) -> list[list[int]]:
        """One group per coordinate along ``axis``: group ``i`` holds
        every device whose ``axis`` coordinate is ``i`` (all other axes
        flattened, rank-major).  ``Mesh(pp=4, dp=2).device_groups("pp")``
        == ``[[0, 1], [2, 3], [4, 5], [6, 7]]`` — the per-PP-rank DP
        replica groups every schedule builder in this repo expects."""
        arr = self.device_array()
        k = self.axis_names.index(axis)
        moved = np.moveaxis(arr, k, 0)
        return [list(map(int, moved[i].reshape(-1)))
                for i in range(self.axis_size(axis))]

    # -- serialization / identity -------------------------------------------
    def to_dict(self) -> dict:
        return {"axes": [[n, s] for n, s in self._axes]}

    @staticmethod
    def from_dict(d: dict) -> "Mesh":
        try:
            axes = {str(n): int(s) for n, s in d["axes"]}
        except (KeyError, TypeError, ValueError) as e:
            raise StrategyError(f"bad mesh spec {d!r}: {e}") from None
        return Mesh(**axes)

    def __eq__(self, other: object) -> bool:
        return isinstance(other, Mesh) and self._axes == other._axes

    def __hash__(self) -> int:
        return hash(self._axes)

    def __repr__(self) -> str:
        inner = ", ".join(f"{n}={s}" for n, s in self._axes)
        return f"Mesh({inner})"


# ---------------------------------------------------------------------------
# Fragments
# ---------------------------------------------------------------------------

class _Chain:
    """Result of ``frag | frag`` — an ordered fragment collection that
    keeps composing with ``|`` until handed to ``Strategy``."""

    def __init__(self, frags: Sequence["Fragment"]) -> None:
        self.fragments = tuple(frags)

    def __or__(self, other):
        if isinstance(other, _Chain):
            return _Chain(self.fragments + other.fragments)
        if isinstance(other, Fragment):
            return _Chain(self.fragments + (other,))
        return NotImplemented

    def __iter__(self):
        return iter(self.fragments)

    def __repr__(self) -> str:
        return " | ".join(repr(f) for f in self.fragments)


@dataclass(frozen=True)
class Fragment:
    """Base class: one composable piece of a distributed strategy.

    A fragment *declares* intent; ``Strategy.lower`` turns the declared
    set into the canonical directive list.  Fragments compose with
    ``|`` and serialize via ``to_dict``/``from_dict`` (keyed by the
    class attribute ``kind``)."""

    kind = "fragment"

    def __or__(self, other):
        if isinstance(other, Fragment):
            return _Chain((self, other))
        if isinstance(other, _Chain):
            return _Chain((self,) + other.fragments)
        return NotImplemented

    def validate(self, strategy: "Strategy") -> None:
        pass

    def to_dict(self) -> dict:
        d = {"kind": self.kind}
        for f in fields(self):
            d[f.name] = getattr(self, f.name)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Fragment":
        kw = {k: v for k, v in d.items() if k != "kind"}
        known = {f.name for f in fields(cls)}
        unknown = set(kw) - known
        if unknown:
            raise StrategyError(
                f"fragment kind {d.get('kind')!r}: unknown field(s) "
                f"{sorted(unknown)} (schema {SCHEMA_VERSION} knows "
                f"{sorted(known)})")
        try:
            return cls(**kw)
        except TypeError as e:
            raise StrategyError(
                f"fragment kind {d.get('kind')!r}: {e}") from None


@dataclass(frozen=True)
class Pipeline(Fragment):
    """Pipeline parallelism: one of the five generative schedule
    builders over the mesh's ``axis``, with ``n_mb`` microbatches.
    ``n_stages`` defaults to the repo convention of 2 stages per rank
    (so every kind runs the same fine-grained model and makespans stay
    apples-to-apples).  ``split_backward=None`` derives the ZeroBubble
    Bi/Bw split from the kind (dualpipev / zb1f1b need it).

    ``mb_split`` is the straggler-rebalance assignment: an optional
    ``{rank: microbatch_count}`` mapping (counts sum to ``n_mb``)
    produced by ``tune.rebalance_microbatches`` and applied mid-run by
    ``ft.elastic.ElasticSupervisor`` as a *recompile* of the same
    fragments.  It is scheduling metadata — the lowered plan records it
    in ``dag.meta['mb_split']`` for cost models and the (future) MPMD
    dispatcher, and the compiled numerics are bit-identical with or
    without it.  Rank ids refer to THIS strategy's mesh, so
    ``for_mesh`` drops the split on any mesh change (a rebalance is a
    property of one concrete world; it is re-derived after an elastic
    shrink or regrowth)."""
    kind = "pipeline"

    schedule: str = "1f1b"
    n_mb: int = 2
    axis: str = "pp"
    n_stages: Optional[int] = None
    p2p_stream: str = "pp_comm"
    split_backward: Optional[bool] = None
    # dualpipev in-flight microbatch headroom beyond 2*(R-r); None keeps
    # the builder's tuned default (schedules.DUALPIPEV_CAP_OFFSET = 6)
    cap_offset: Optional[int] = None
    # ((rank, count), ...) or None — see class docstring
    mb_split: Optional[tuple] = None

    def __post_init__(self):
        s = self.mb_split
        if isinstance(s, dict):
            s = tuple(sorted((int(r), int(c)) for r, c in s.items()))
        elif s is not None:
            try:
                s = tuple(sorted((int(r), int(c)) for r, c in s))
            except (TypeError, ValueError):
                raise StrategyError(
                    f"fragment Pipeline: mb_split must map ranks to "
                    f"microbatch counts, got {self.mb_split!r}") from None
        object.__setattr__(self, "mb_split", s)

    def mb_split_dict(self) -> Optional[dict]:
        return dict(self.mb_split) if self.mb_split is not None else None

    def validate(self, strategy: "Strategy") -> None:
        if self.schedule not in SCHEDULE_KINDS:
            raise StrategyError(
                f"fragment {self!r}: unknown schedule "
                f"{self.schedule!r} (kinds: {list(SCHEDULE_KINDS)})")
        if self.n_mb < 1:
            raise StrategyError(f"fragment {self!r}: n_mb must be >= 1")
        if self.cap_offset is not None and self.cap_offset < 0:
            raise StrategyError(
                f"fragment {self!r}: cap_offset must be >= 0")
        mesh = strategy.mesh
        if self.axis not in mesh:
            raise StrategyError(
                f"fragment {self!r}: mesh {mesh!r} has no axis "
                f"{self.axis!r}")
        pp = mesh[self.axis]
        S = self.stages(mesh)
        if S % pp:
            raise StrategyError(
                f"fragment {self!r}: n_stages={S} not divisible by "
                f"{self.axis}={pp}")
        if self.schedule == "dualpipev" and S != 2 * pp:
            raise StrategyError(
                f"fragment {self!r}: dualpipev V-placement requires "
                f"n_stages == 2*{self.axis} (got {S} != {2 * pp})")
        if self.mb_split is not None:
            ranks = [r for r, _ in self.mb_split]
            counts = [c for _, c in self.mb_split]
            if len(set(ranks)) != len(ranks):
                raise StrategyError(
                    f"fragment {self!r}: mb_split names duplicate ranks")
            bad = [r for r in ranks if not 0 <= r < mesh.n_devices]
            if bad:
                raise StrategyError(
                    f"fragment {self!r}: mb_split ranks {bad} outside "
                    f"mesh of {mesh.n_devices} devices")
            if any(c < 0 for c in counts):
                raise StrategyError(
                    f"fragment {self!r}: mb_split counts must be >= 0")
            if sum(counts) != self.n_mb:
                raise StrategyError(
                    f"fragment {self!r}: mb_split counts sum to "
                    f"{sum(counts)}, not n_mb={self.n_mb} (the split "
                    "re-assigns microbatches, it never changes their "
                    "number)")

    def stages(self, mesh: Mesh) -> int:
        return self.n_stages if self.n_stages is not None \
            else 2 * mesh[self.axis]

    def resolved_split_backward(self) -> bool:
        if self.split_backward is not None:
            return bool(self.split_backward)
        return self.schedule in ("dualpipev", "zb1f1b")


@dataclass(frozen=True)
class ZeRO(Fragment):
    """Data parallelism over the mesh's ``axis`` with a ZeRO stage:
    0/1 replicate (all-reduce grads; ZeRO-1 optimizer-state dedup is the
    runtime default), 2 shards grads (reduce-scatter), 3 shards params
    too (all-gather before use).  ``bucket_mb`` > 0 chunks the grad
    collectives (Replicate.bucket_sz)."""
    kind = "zero"

    stage: int = 1
    bucket_mb: int = 0
    axis: str = "dp"
    reduce_stream: str = "dp"
    gather_stream: str = "ag"

    def validate(self, strategy: "Strategy") -> None:
        if self.stage not in (0, 1, 2, 3):
            raise StrategyError(
                f"fragment {self!r}: ZeRO stage must be 0..3")
        if self.bucket_mb < 0:
            raise StrategyError(
                f"fragment {self!r}: bucket_mb must be >= 0")
        if self.axis not in strategy.mesh:
            raise StrategyError(
                f"fragment {self!r}: mesh {strategy.mesh!r} has no axis "
                f"{self.axis!r}")
        if strategy.pipeline is None:
            raise StrategyError(
                f"fragment {self!r}: ZeRO needs a Pipeline fragment to "
                "define the per-stage device groups it replicates over")


@dataclass(frozen=True)
class ExpertParallel(Fragment):
    """Expert parallelism: Shard the ``dim``-annotated expert chunks
    across each stage's device group (all-to-all on the activation
    edges).  ``degree=None`` means the full group; an explicit degree
    must match the group size (this runtime shards experts over exactly
    the stage's replicas)."""
    kind = "expert_parallel"

    degree: Optional[int] = None
    axis: str = "dp"
    dim: str = "ep"
    stream: str = "ep"

    def validate(self, strategy: "Strategy") -> None:
        if self.axis not in strategy.mesh:
            raise StrategyError(
                f"fragment {self!r}: mesh {strategy.mesh!r} has no axis "
                f"{self.axis!r}")
        size = strategy.mesh[self.axis]
        if self.degree is not None and self.degree != size:
            raise StrategyError(
                f"fragment {self!r}: degree {self.degree} != mesh axis "
                f"{self.axis}={size} (experts shard over exactly the "
                "stage's device group)")
        if strategy.pipeline is None:
            raise StrategyError(
                f"fragment {self!r}: ExpertParallel needs a Pipeline "
                "fragment to define the per-stage device groups")


@dataclass(frozen=True)
class Overlap(Fragment):
    """Joint compute–communication overlap engine knobs (PR-2 pass
    layer): gather lookahead ``prefetch`` and fused-collective budget
    ``bucket_mb`` (0 disables fusion).  ``enabled=False`` is the honest
    just-in-time baseline.  Not a directive — lowers to the compiler's
    ``OverlapConfig``."""
    kind = "overlap"

    prefetch: int = 4
    bucket_mb: int = 32
    enabled: bool = True
    bubble_aware: bool = True

    def validate(self, strategy: "Strategy") -> None:
        if self.prefetch < 1:
            raise StrategyError(
                f"fragment {self!r}: prefetch must be >= 1 (1 = "
                "just-in-time dispatch; omit the fragment for the "
                "legacy no-engine plan)")
        if self.bucket_mb < 0:
            raise StrategyError(
                f"fragment {self!r}: bucket_mb must be >= 0")

    def to_overlap_config(self) -> OverlapConfig:
        return OverlapConfig(enabled=self.enabled,
                             bucket_bytes=self.bucket_mb << 20,
                             prefetch=self.prefetch,
                             bubble_aware=self.bubble_aware)

    @staticmethod
    def from_config(cfg: OverlapConfig) -> "Overlap":
        return Overlap(prefetch=max(1, int(cfg.prefetch)),
                       bucket_mb=int(cfg.bucket_bytes) >> 20,
                       enabled=bool(cfg.enabled),
                       bubble_aware=bool(cfg.bubble_aware))


@dataclass(frozen=True)
class Remat(Fragment):
    """Programmable activation-residual policy (DESIGN.md §11):

      ``"full"``      per-chunk rematerialization — each backward chunk
                      re-runs its forward under ``jax.vjp`` from the
                      boundary activations (the repo's historical
                      hard-coded behavior; still the default);
      ``"none"``      stash the vjp residuals as explicit IR values —
                      no forward re-run, ~2/3 the backward compute, the
                      residuals stay live across the forward->backward
                      stash window;
      ``"selective"`` alternate the two per chunk (Checkmate-style
                      compute/memory middle point).

    ``scope`` restricts the policy to chunks matching a {dim: index}
    mapping, e.g. ``Remat("none", scope={"pp": 0})`` stashes only stage
    0 (the deepest 1F1B stash).  Lowers to ``passes.apply_remat``."""
    kind = "remat"

    policy: str = "full"
    scope: Optional[tuple] = None       # ((dim, index), ...) or None

    def __post_init__(self):
        s = self.scope
        if isinstance(s, dict):
            s = tuple(sorted(s.items()))
        elif s is not None:
            s = tuple((str(d), v) for d, v in s)
        object.__setattr__(self, "scope", s)

    def scope_dict(self) -> Optional[dict]:
        return dict(self.scope) if self.scope is not None else None

    def validate(self, strategy: "Strategy") -> None:
        if self.policy not in REMAT_POLICIES:
            raise StrategyError(
                f"fragment {self!r}: policy must be one of "
                f"{list(REMAT_POLICIES)}")
        if self.scope is not None:
            for item in self.scope:
                if (not isinstance(item, tuple) or len(item) != 2
                        or not isinstance(item[0], str)):
                    raise StrategyError(
                        f"fragment {self!r}: scope must map dim names "
                        "to indices, e.g. {'pp': 0}")

    def to_dict(self) -> dict:
        return {"kind": self.kind, "policy": self.policy,
                "scope": ([[d, v] for d, v in self.scope]
                          if self.scope is not None else None)}


@dataclass(frozen=True)
class Offload(Fragment):
    """Host offload of long-stash residuals (DESIGN.md §11): splice
    d2h/h2d round-trip nodes on residual edges whose forward->backward
    window exceeds ``depth`` chunks, on a dedicated ``stream`` — the
    activation leaves the device ledger between stash and fetch, and the
    fetch is gated ``depth`` chunks ahead of the consumer so the DMA
    hides behind compute.  Lowers to ``passes.apply_offload``."""
    kind = "offload"

    payload: str = "act"
    depth: int = 2
    stream: str = "offload"

    def validate(self, strategy: "Strategy") -> None:
        if self.payload != "act":
            raise StrategyError(
                f"fragment {self!r}: payload must be 'act' (activation "
                "residuals are the only offloadable payload)")
        if self.depth < 1:
            raise StrategyError(
                f"fragment {self!r}: depth must be >= 1")


@dataclass(frozen=True)
class RawDirectives(Fragment):
    """Escape hatch wrapping a hand-assembled directive list — what the
    deprecated ``compile_training(schedule=...)`` shim turns its input
    into.  ``split_backward`` carries the ZeroBubble Bi/Bw flag the
    legacy keyword used to.  Not serializable (directives hold closures
    and filters), and not composable with structured placement fragments
    (Pipeline/ZeRO/ExpertParallel): the canonical lowering order cannot
    be enforced across an opaque list.  Compiler-side fragments (Overlap,
    Remat, Offload) do compose — they are not directives."""
    kind = "raw"

    directives: tuple = ()
    split_backward: bool = False

    def __post_init__(self):
        object.__setattr__(self, "directives", tuple(self.directives))

    def validate(self, strategy: "Strategy") -> None:
        for d in self.directives:
            if not isinstance(d, Directive):
                raise StrategyError(
                    f"fragment RawDirectives: {d!r} is not a Directive")

    def to_dict(self) -> dict:
        raise StrategyError(
            "RawDirectives is not serializable — express the strategy "
            "with structured fragments (Pipeline/ZeRO/ExpertParallel/"
            "Overlap) to get a JSON-round-trippable plan")


FRAGMENT_KINDS: dict[str, type] = {
    Pipeline.kind: Pipeline,
    ZeRO.kind: ZeRO,
    ExpertParallel.kind: ExpertParallel,
    Overlap.kind: Overlap,
    Remat.kind: Remat,
    Offload.kind: Offload,
    RawDirectives.kind: RawDirectives,
}

# structured fragments that may appear at most once per strategy
_SINGLETON_KINDS = (Pipeline, ZeRO, ExpertParallel, Overlap, Remat,
                    Offload)
# compiler-side fragments: not lowered to directives, so they need no
# mesh and may compose with a RawDirectives backbone
_COMPILER_KINDS = (Overlap, Remat, Offload)


# ---------------------------------------------------------------------------
# Strategy
# ---------------------------------------------------------------------------

FragmentsLike = Union[Fragment, _Chain, Sequence[Fragment]]


@dataclass(frozen=True)
class Strategy:
    """A complete declarative distributed-training strategy: a named
    axis ``mesh`` plus composable ``fragments``.

        Strategy(Mesh(pp=2, dp=2),
                 Pipeline("dualpipev", n_mb=8) | ZeRO(stage=3)
                 | ExpertParallel() | Overlap(prefetch=4, bucket_mb=32))

    ``strategy | fragment`` appends.  ``lower()`` emits the canonical
    directive list (Place..., Replicate/Shard..., Split, Order...);
    ``compile_training(strategy=...)`` is the front door that also
    derives ``split_backward`` and the overlap engine config from the
    fragments."""

    mesh: Optional[Mesh] = None
    fragments: tuple = ()

    def __init__(self, mesh: Optional[Mesh] = None,
                 fragments: FragmentsLike = ()) -> None:
        if isinstance(fragments, Fragment):
            fragments = (fragments,)
        elif isinstance(fragments, _Chain):
            fragments = fragments.fragments
        object.__setattr__(self, "mesh", mesh)
        object.__setattr__(self, "fragments", tuple(fragments))

    # -- composition --------------------------------------------------------
    def __or__(self, other):
        if isinstance(other, Fragment):
            return Strategy(self.mesh, self.fragments + (other,))
        if isinstance(other, _Chain):
            return Strategy(self.mesh, self.fragments + other.fragments)
        return NotImplemented

    def _only(self, cls):
        found = [f for f in self.fragments if isinstance(f, cls)]
        if len(found) > 1:
            raise StrategyError(
                f"fragment {found[1]!r}: duplicate {cls.__name__} "
                f"fragment (already have {found[0]!r})")
        return found[0] if found else None

    @property
    def pipeline(self) -> Optional[Pipeline]:
        return self._only(Pipeline)

    @property
    def zero(self) -> Optional[ZeRO]:
        return self._only(ZeRO)

    @property
    def expert_parallel(self) -> Optional[ExpertParallel]:
        return self._only(ExpertParallel)

    @property
    def overlap(self) -> Optional[Overlap]:
        return self._only(Overlap)

    @property
    def remat(self) -> Optional[Remat]:
        return self._only(Remat)

    @property
    def offload(self) -> Optional[Offload]:
        return self._only(Offload)

    @property
    def raw(self) -> tuple:
        return tuple(f for f in self.fragments
                     if isinstance(f, RawDirectives))

    def for_mesh(self, mesh: Mesh) -> "Strategy":
        """Re-target this strategy to a different mesh and revalidate —
        the elastic-recovery primitive (plan compilation as a *runtime*
        event): the same fragments, lowered for a shrunk world.

        The pipeline stage count is pinned to its value under the OLD
        mesh (``n_stages`` defaults to ``2 * mesh[axis]``), because the
        traced model's per-stage parameter buckets are fixed — a shrunk
        pipeline axis remaps MORE stages per rank, it never changes the
        stage graph.  Raises ``StrategyError`` when the fragments cannot
        be satisfied on the new mesh (e.g. stage count not divisible by
        the new pipeline degree, or dualpipev's S == 2*pp pin)."""
        import dataclasses
        if self.mesh is None:
            raise StrategyError(
                "cannot re-target a mesh-less strategy (legacy "
                "RawDirectives shim) — elastic recovery needs "
                "structured fragments")
        frags = []
        for f in self.fragments:
            if isinstance(f, Pipeline):
                if f.n_stages is None:
                    f = dataclasses.replace(f, n_stages=f.stages(self.mesh))
                if f.mb_split is not None:
                    # a rebalance split names ranks of the OLD world; any
                    # mesh change invalidates it — regrown/shrunk worlds
                    # start from the uniform split again
                    f = dataclasses.replace(f, mb_split=None)
            frags.append(f)
        return Strategy(mesh, tuple(frags)).validate()

    def replacing(self, *frags: Fragment) -> "Strategy":
        """A copy with each given fragment substituted for the
        same-kind fragment (appended when that kind is absent) — e.g.
        swap the Overlap knobs of a cached strategy."""
        out = [f for f in self.fragments
               if not any(isinstance(f, type(n)) for n in frags)]
        return Strategy(self.mesh, tuple(out) + tuple(frags))

    def without(self, cls) -> "Strategy":
        return Strategy(self.mesh, tuple(f for f in self.fragments
                                         if not isinstance(f, cls)))

    # -- validation ---------------------------------------------------------
    def validate(self) -> "Strategy":
        for f in self.fragments:
            if not isinstance(f, Fragment):
                raise StrategyError(f"{f!r} is not a strategy Fragment")
        for cls in _SINGLETON_KINDS:
            self._only(cls)                       # raises on duplicates
        if self.raw and (self.pipeline or self.zero
                         or self.expert_parallel):
            raise StrategyError(
                "RawDirectives cannot compose with structured fragments "
                "— the canonical lowering order cannot be enforced "
                "across an opaque directive list")
        structured = [f for f in self.fragments
                      if isinstance(f, _SINGLETON_KINDS)
                      and not isinstance(f, _COMPILER_KINDS)]
        if structured and self.mesh is None:
            raise StrategyError(
                f"fragment {structured[0]!r}: structured fragments need "
                "a Mesh (Strategy(Mesh(pp=..., dp=...), ...))")
        for f in self.fragments:
            f.validate(self)
        return self

    # -- derived compiler inputs --------------------------------------------
    @property
    def split_backward(self) -> bool:
        pipe = self.pipeline
        if pipe is not None:
            return pipe.resolved_split_backward()
        return any(f.split_backward for f in self.raw)

    def overlap_config(self) -> Optional[OverlapConfig]:
        ov = self.overlap
        return ov.to_overlap_config() if ov else None

    def expert_stages_of(self, dag) -> set:
        """Stages (pipeline-axis coordinates) whose chunks carry the
        expert dim — derived from the traced DAG."""
        pipe = self.pipeline
        ep = self.expert_parallel
        axis = pipe.axis if pipe else "pp"
        dim = ep.dim if ep else "ep"
        return {n.dims[axis] for n in dag.nodes.values()
                if dim in n.dims and axis in n.dims}

    # -- lowering -----------------------------------------------------------
    def lower(self, dag=None,
              expert_stages: Optional[Sequence[int]] = None) -> list:
        """Emit the canonical directive list.  ``expert_stages`` (which
        pipeline stages host expert chunks) is derived from ``dag`` when
        given; pass it explicitly to lower without a DAG (the autotuner
        knows it from the config decomposition)."""
        self.validate()
        if self.raw:
            return [d for f in self.raw for d in f.directives]
        pipe = self.pipeline
        pipe_origin = (f"Pipeline(schedule={pipe.schedule!r}, "
                       f"n_mb={pipe.n_mb})" if pipe is not None else None)
        if pipe is None:
            raise StrategyError(
                "strategy has no Pipeline fragment — nothing defines "
                "stage placement (wrap a hand-built directive list in "
                "RawDirectives if you really want a custom backbone)")
        from .schedules import (build_rank_sequences, emit_directives,
                                rank_of_stage)
        mesh = self.mesh
        pp = mesh[pipe.axis]
        S = pipe.stages(mesh)
        groups = mesh.device_groups(pipe.axis)
        seqs = build_rank_sequences(pipe.schedule, pp, pipe.n_mb, S,
                                    cap_offset=pipe.cap_offset)
        sched = emit_directives(pipe.schedule, seqs, device_groups=groups,
                                n_stages=S, pp_dim=pipe.axis,
                                p2p_stream=pipe.p2p_stream)
        places, split, orders = sched[:S], sched[S], sched[S + 1:]

        zero, ep = self.zero, self.expert_parallel
        ep_dim = ep.dim if ep else "ep"
        if expert_stages is None:
            expert_stages = self.expert_stages_of(dag) if dag is not None \
                else set()
        expert_stages = set(expert_stages)
        if ep is not None and dag is not None and not expert_stages:
            raise StrategyError(
                f"fragment {ep!r}: the traced model has no "
                f"{ep_dim!r}-annotated chunks to shard")

        extra: list = []
        zero_origin = (f"ZeRO(stage={zero.stage}, axis={zero.axis!r})"
                       if zero is not None else None)
        for s in range(S):
            g = list(groups[rank_of_stage(pipe.schedule, s, pp, S)])
            if zero is not None:
                extra.append(Replicate(
                    F(**{pipe.axis: s, ep_dim: "-"}), devices=g,
                    reduce_stream=zero.reduce_stream,
                    gather_stream=zero.gather_stream,
                    shard_grads=zero.stage >= 2,
                    shard_params=zero.stage >= 3,
                    bucket_sz=(zero.bucket_mb << 20) or None))
                extra[-1].origin = zero_origin
            if s in expert_stages:
                if ep is not None:
                    extra.append(Shard(F(**{pipe.axis: s, ep_dim: "*"}),
                                       devices=g, stream=ep.stream))
                    extra[-1].origin = (f"ExpertParallel(axis={ep.axis!r}, "
                                        f"dim={ep.dim!r})")
                elif zero is not None:
                    extra.append(Replicate(
                        F(**{pipe.axis: s, ep_dim: "*"}), devices=g,
                        reduce_stream=zero.reduce_stream,
                        gather_stream=zero.gather_stream,
                        shard_grads=zero.stage >= 2,
                        shard_params=zero.stage >= 3,
                        bucket_sz=(zero.bucket_mb << 20) or None))
                    extra[-1].origin = zero_origin
        # provenance for the static verifier: every emitted directive
        # names its source fragment; the compiler threads the label into
        # Node.meta["origin"] via dag.origin() around directive.apply().
        for d in places + [split] + orders:
            if getattr(d, "origin", None) is None:
                d.origin = pipe_origin
        return places + extra + [split] + orders

    # -- serialization ------------------------------------------------------
    def to_dict(self) -> dict:
        self.validate()
        if self.mesh is None:
            raise StrategyError(
                "cannot serialize a mesh-less strategy (legacy "
                "RawDirectives shim) — use structured fragments")
        return {"schema": SCHEMA_VERSION,
                "mesh": self.mesh.to_dict(),
                "fragments": [f.to_dict() for f in self.fragments]}

    def to_json(self) -> str:
        """Canonical byte-stable JSON: sorted keys, compact separators.
        Equal strategies always serialize to equal bytes — this string
        is the plan-cache identity."""
        return json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":"))

    @staticmethod
    def from_dict(d: dict) -> "Strategy":
        if not isinstance(d, dict):
            raise StrategyError(f"strategy document must be an object, "
                                f"got {type(d).__name__}")
        schema = d.get("schema")
        if schema != SCHEMA_VERSION:
            raise StrategyError(
                f"unknown strategy schema version {schema!r} (this "
                f"build reads version {SCHEMA_VERSION}); re-derive the "
                "strategy instead of migrating the document by hand")
        mesh = Mesh.from_dict(d.get("mesh", {}))
        frags = []
        for fd in d.get("fragments", ()):
            kind = fd.get("kind") if isinstance(fd, dict) else None
            cls = FRAGMENT_KINDS.get(kind)
            if cls is None or cls is RawDirectives:
                raise StrategyError(
                    f"unknown fragment kind {kind!r} (schema "
                    f"{SCHEMA_VERSION} knows "
                    f"{sorted(k for k in FRAGMENT_KINDS if k != 'raw')})")
            frags.append(cls.from_dict(fd))
        return Strategy(mesh, tuple(frags)).validate()

    @staticmethod
    def from_json(s: str) -> "Strategy":
        try:
            d = json.loads(s)
        except json.JSONDecodeError as e:
            raise StrategyError(f"strategy JSON does not parse: {e}") \
                from None
        return Strategy.from_dict(d)

    # -- cosmetics ----------------------------------------------------------
    def label(self) -> str:
        """Compact human label, e.g. ``pp2x dp2 1f1b/mb8/zero3/pf4``."""
        parts = []
        if self.mesh is not None:
            parts.append("x".join(f"{n}{s}" for n, s in
                                  zip(self.mesh.axis_names,
                                      self.mesh.shape)))
        pipe, zero, ep, ov = (self.pipeline, self.zero,
                              self.expert_parallel, self.overlap)
        if pipe:
            parts.append(f"{pipe.schedule}/mb{pipe.n_mb}"
                         + ("/rb" if pipe.mb_split is not None else ""))
        if zero:
            parts.append(f"zero{zero.stage}")
        if ep:
            parts.append(f"ep{ep.degree or self.mesh[ep.axis]}")
        if ov and ov.enabled:
            parts.append(f"pf{ov.prefetch}"
                         + (f"/bkt{ov.bucket_mb}M" if ov.bucket_mb
                            else ""))
        rm, off = self.remat, self.offload
        if rm and rm.policy != "full":
            parts.append(f"rm-{rm.policy}")
        if off:
            parts.append(f"off{off.depth}")
        if self.raw:
            parts.append(f"raw[{sum(len(f.directives) for f in self.raw)}]")
        return " ".join(parts) or "<empty strategy>"

    def __repr__(self) -> str:
        return (f"Strategy({self.mesh!r}, "
                f"[{', '.join(repr(f) for f in self.fragments)}])")
