"""Piper IR: the global training DAG.

Nodes are either Chunks (coarse-grained compute with no interleaved
communication) or Comms (point-to-point or collective communication).
Data flows along edges; temporal edges carry user ordering intent
(``Order`` directive).  Every node has a device placement and a logical
stream.  The compiler (``compiler.py``) builds this DAG from an annotated
model and rewrites it with scheduling directives (``directives.py``).

This mirrors the paper's Section 4.1 IR.  The JAX adaptation notes live in
DESIGN.md section 2.
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Optional


# ---------------------------------------------------------------------------
# Value specs
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "float32": 4, "float64": 8, "float16": 2, "bfloat16": 2,
    "int32": 4, "int64": 8, "int16": 2, "int8": 1, "uint8": 1,
    "bool": 1, "uint32": 4,
}


@dataclass(frozen=True)
class ValueSpec:
    """Shape/dtype stand-in for a tensor flowing along an IR edge."""
    shape: tuple[int, ...]
    dtype: str = "float32"

    @property
    def nbytes(self) -> int:
        n = 1
        for s in self.shape:
            n *= int(s)
        return n * _DTYPE_BYTES.get(str(self.dtype), 4)

    def with_leading(self, dim: int) -> "ValueSpec":
        return ValueSpec((dim,) + tuple(self.shape[1:]), self.dtype)

    @staticmethod
    def of(x) -> "ValueSpec":
        return ValueSpec(tuple(int(s) for s in x.shape), str(x.dtype))


def tree_specs(tree) -> list[ValueSpec]:
    import jax
    return [ValueSpec.of(l) for l in jax.tree_util.tree_leaves(tree)]


def tree_nbytes(tree) -> int:
    return sum(s.nbytes for s in tree_specs(tree))


# ---------------------------------------------------------------------------
# Param buckets
# ---------------------------------------------------------------------------

@dataclass
class Bucket:
    """A bucket of model state (params + grads + optimizer state) tied to
    one or more Chunks.  Placement/replication attributes are filled in by
    the ``Replicate``/``Shard`` directives."""
    name: str
    param_bytes: int = 0
    param_elems: int = 0
    # replication over these devices (DP group); None = single placement
    replica_devices: Optional[tuple[int, ...]] = None
    shard_params: bool = False      # ZeRO-3
    shard_grads: bool = False       # ZeRO-2
    shard_opt: bool = True          # ZeRO-1 (optimizer state dedup)
    expert_devices: Optional[tuple[int, ...]] = None  # EP sharding
    bucket_sz: Optional[int] = None

    def opt_bytes(self, adam_factor: float = 8.0) -> int:
        # AdamW fp32 m+v per param (params counted separately).
        return int(self.param_bytes / 2 * adam_factor)  # bytes are bf16*2


# ---------------------------------------------------------------------------
# Nodes
# ---------------------------------------------------------------------------

PASS_F = "F"
PASS_B = "B"
PASS_BI = "Bi"   # backward-for-inputs (ZeroBubble-style split)
PASS_BW = "Bw"   # backward-for-weights

COMM_OPS = (
    "p2p", "send", "recv", "all_reduce", "all_gather", "reduce_scatter",
    "all_to_all", "broadcast",
    # host offload round-trip (Offload directive): device->host stash and
    # host->device fetch of a residual activation on the offload stream
    "d2h", "h2d",
)


@dataclass
class Node:
    id: int
    kind: str                      # "chunk" | "comm"
    name: str = ""
    # dims: e.g. {"pp": 0, "ep": 1, "MB": 0, "PASS": "F"}.  A dim that was
    # annotated but has no index yet maps to an int index in dataflow order.
    dims: dict[str, Any] = field(default_factory=dict)
    devices: Optional[tuple[int, ...]] = None
    stream: Optional[str] = None   # logical stream name; None = default
    # --- chunk only ---
    fn: Optional[Callable] = None  # exec: (bucket_params, *inputs) -> outputs
    bucket: Optional[str] = None
    n_outputs: int = 1
    out_specs: list[ValueSpec] = field(default_factory=list)
    # --- comm only ---
    op: Optional[str] = None       # one of COMM_OPS
    group: Optional[tuple[int, ...]] = None   # collective participants
    src_device: Optional[int] = None          # p2p
    dst_device: Optional[int] = None          # p2p
    payload: str = ""              # "act" | "grad" | "param"
    # accounting / scheduling metadata
    meta: dict[str, Any] = field(default_factory=dict)

    def total_out_bytes(self) -> int:
        """Payload bytes this node produces (sum over output slots) —
        the fusion budget / wire-size / stream-occupancy unit."""
        return sum(s.nbytes for s in self.out_specs)

    @property
    def is_chunk(self) -> bool:
        return self.kind == "chunk"

    @property
    def is_comm(self) -> bool:
        return self.kind == "comm"

    def short(self) -> str:
        d = ",".join(f"{k}={v}" for k, v in sorted(self.dims.items()))
        tag = self.op if self.is_comm else "chunk"
        return f"[{self.id}]{tag}:{self.name}({d})"


@dataclass(frozen=True)
class Edge:
    """Data dependency: output slot ``src_out`` of node ``src`` feeds input
    slot ``dst_in`` of node ``dst``."""
    src: int
    src_out: int
    dst: int
    dst_in: int
    spec: ValueSpec = ValueSpec(())

    def moved(self, **kw) -> "Edge":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# The DAG
# ---------------------------------------------------------------------------

class TrainingDAG:
    """The global training DAG (paper Fig. 6).

    Holds nodes, data edges, temporal edges, param buckets, graph inputs
    (leaves fed by the data pipeline) and graph outputs (loss)."""

    def __init__(self) -> None:
        self._next_id = itertools.count()
        self.nodes: dict[int, Node] = {}
        self.edges: list[Edge] = []
        self.temporal: set[tuple[int, int]] = set()
        self.buckets: dict[str, Bucket] = {}
        # graph inputs: name -> (spec, list of (node, in_slot)) fed externally
        self.inputs: dict[str, tuple[ValueSpec, list[tuple[int, int]]]] = {}
        # graph outputs: (node, out_slot) tuples (loss values)
        self.outputs: list[tuple[int, int]] = []
        # overlap groups from nested Order filters: list of tuples of node-id
        # frozensets whose execution should be interleaved.
        self.overlap_groups: list[tuple[frozenset[int], ...]] = []
        self.default_devices: tuple[int, ...] = (0,)
        # bucket name -> [(node, out_slot)] values holding final grads
        self.grad_sinks: dict[str, list[tuple[int, int]]] = {}
        self.meta: dict[str, Any] = {}
        # provenance: when set (via the ``origin`` context manager) every
        # node created inside the context records which directive /
        # fragment / pass introduced it in ``Node.meta["origin"]``, and
        # every temporal edge records it in ``temporal_origin``.  The
        # static verifier (``repro.analysis``) reads these so a
        # diagnostic names ``Overlap(bucket_mb=32)`` instead of a bare
        # node id.
        self._origin: Optional[str] = None
        self.temporal_origin: dict[tuple[int, int], str] = {}

    # -- construction -------------------------------------------------------
    @contextlib.contextmanager
    def origin(self, label: Optional[str]):
        """Attribute every node/temporal edge created in this context to
        ``label`` (nested contexts keep the innermost label; a node whose
        meta already carries an origin — e.g. a Split clone copying its
        template's meta — keeps the inherited one)."""
        prev, self._origin = self._origin, (label or self._origin)
        try:
            yield
        finally:
            self._origin = prev

    def new_node(self, **kw) -> Node:
        nid = next(self._next_id)
        node = Node(id=nid, **kw)
        if self._origin is not None:
            node.meta.setdefault("origin", self._origin)
        self.nodes[nid] = node
        return node

    def add_edge(self, src: int, src_out: int, dst: int, dst_in: int,
                 spec: ValueSpec) -> Edge:
        e = Edge(src, src_out, dst, dst_in, spec)
        self.edges.append(e)
        return e

    def add_temporal(self, src: int, dst: int) -> None:
        if src != dst:
            self.temporal.add((src, dst))
            if self._origin is not None:
                self.temporal_origin.setdefault((src, dst), self._origin)

    def bucket_of(self, name: str) -> Bucket:
        if name not in self.buckets:
            self.buckets[name] = Bucket(name=name)
        return self.buckets[name]

    # -- queries ------------------------------------------------------------
    def in_edges(self, nid: int) -> list[Edge]:
        return [e for e in self.edges if e.dst == nid]

    def out_edges(self, nid: int) -> list[Edge]:
        return [e for e in self.edges if e.src == nid]

    def preds(self, nid: int) -> set[int]:
        p = {e.src for e in self.edges if e.dst == nid}
        p |= {u for (u, v) in self.temporal if v == nid}
        return p

    def succs(self, nid: int) -> set[int]:
        s = {e.dst for e in self.edges if e.src == nid}
        s |= {v for (u, v) in self.temporal if u == nid}
        return s

    def chunks(self) -> list[Node]:
        return [n for n in self.nodes.values() if n.is_chunk]

    def comms(self) -> list[Node]:
        return [n for n in self.nodes.values() if n.is_comm]

    def toposort(self) -> list[int]:
        indeg: dict[int, int] = {nid: 0 for nid in self.nodes}
        for e in self.edges:
            indeg[e.dst] += 1
        for (u, v) in self.temporal:
            indeg[v] += 1
        from collections import deque
        q = deque(sorted(nid for nid, d in indeg.items() if d == 0))
        order: list[int] = []
        succs: dict[int, list[int]] = {nid: [] for nid in self.nodes}
        for e in self.edges:
            succs[e.src].append(e.dst)
        for (u, v) in self.temporal:
            succs[u].append(v)
        while q:
            nid = q.popleft()
            order.append(nid)
            for s in succs[nid]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    q.append(s)
        if len(order) != len(self.nodes):
            cyc = sorted(set(self.nodes) - set(order))
            raise ValueError(
                f"training DAG has a cycle involving nodes {cyc[:8]} "
                "(conflicting Order directives?)")
        return order

    def topo_index(self) -> dict[int, int]:
        """node id -> position in one deterministic topological order."""
        return {nid: i for i, nid in enumerate(self.toposort())}

    def descendants_count(self) -> dict[int, int]:
        """#downstream nodes per node — the scheduler's priority metric."""
        order = self.toposort()
        desc: dict[int, set[int]] = {nid: set() for nid in self.nodes}
        for nid in reversed(order):
            for s in self.succs(nid):
                desc[nid].add(s)
                desc[nid] |= desc[s]
        return {nid: len(v) for nid, v in desc.items()}

    # -- rewriting helpers (used by directives) ------------------------------
    def redirect_edge(self, e: Edge, *, new_dst: int, new_dst_in: int) -> Edge:
        self.edges.remove(e)
        ne = e.moved(dst=new_dst, dst_in=new_dst_in)
        self.edges.append(ne)
        return ne

    def splice_comm_on_edge(self, e: Edge, comm: Node) -> None:
        """Replace edge (u -> v) with (u -> comm -> v)."""
        self.edges.remove(e)
        self.add_edge(e.src, e.src_out, comm.id, 0, e.spec)
        self.add_edge(comm.id, 0, e.dst, e.dst_in, e.spec)

    def insert_after(self, nid: int, comm: Node, out_slot: int = 0) -> None:
        """Route all consumers of (nid, out_slot) through comm."""
        consumers = [e for e in self.out_edges(nid) if e.src_out == out_slot]
        spec = consumers[0].spec if consumers else ValueSpec(())
        for e in consumers:
            self.edges.remove(e)
            self.add_edge(comm.id, 0, e.dst, e.dst_in, e.spec)
        self.add_edge(nid, out_slot, comm.id, 0, spec)

    def remove_node(self, nid: int) -> None:
        self.nodes.pop(nid)
        self.edges = [e for e in self.edges if e.src != nid and e.dst != nid]
        self.temporal = {(u, v) for (u, v) in self.temporal
                         if u != nid and v != nid}
        self.temporal_origin = {k: o for k, o in self.temporal_origin.items()
                                if k in self.temporal}

    # -- validation ----------------------------------------------------------
    def validate(self) -> None:
        self.toposort()
        for e in self.edges:
            if e.src not in self.nodes or e.dst not in self.nodes:
                raise ValueError(f"dangling edge {e}")
        for n in self.nodes.values():
            if n.devices is None:
                raise ValueError(f"node {n.short()} has no device placement")
            if n.is_comm and n.op not in COMM_OPS:
                raise ValueError(f"unknown comm op {n.op}")
        # placement coherence: non-p2p nodes share placement with neighbours
        for e in self.edges:
            s, d = self.nodes[e.src], self.nodes[e.dst]
            if s.is_comm and s.op in ("p2p", "send", "recv"):
                continue
            if d.is_comm and d.op in ("p2p", "send", "recv"):
                continue
            if s.devices and d.devices and not (
                    set(s.devices) & set(d.devices)):
                raise ValueError(
                    "placement mismatch without p2p comm between "
                    f"{s.short()} and {d.short()}")

    def stats(self) -> dict[str, int]:
        return {
            "chunks": len(self.chunks()),
            "comms": len(self.comms()),
            "edges": len(self.edges),
            "temporal": len(self.temporal),
            "buckets": len(self.buckets),
        }

    def dump(self) -> str:
        lines = []
        for nid in self.toposort():
            n = self.nodes[nid]
            ins = ",".join(str(e.src) for e in self.in_edges(nid))
            lines.append(
                f"{n.short():<48} dev={n.devices} stream={n.stream} "
                f"<- [{ins}]")
        return "\n".join(lines)
