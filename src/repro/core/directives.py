"""Piper scheduling directives (paper §4.1).

Each directive is a mechanical rewrite of the training DAG:

  Place(filters, devices, stream)          device placement (PP stages, …)
  Replicate(filter, devices, …)            DP / ZeRO-1/2/3
  Shard(filter, devices, stream)           expert parallelism (all-to-all)
  Split(filter, dim, num_microbatches)     microbatching
  Order(filter_list)                       temporal edges / overlap groups

Deviation note (DESIGN.md §2): p2p comm insertion for ``Place`` is deferred
to a compiler finalization pass (``passes.insert_p2p``) so placement can be
declared incrementally; the resulting DAG is identical to eager insertion.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence, Union

from .dag import PASS_B, PASS_BW, Edge, Node, TrainingDAG, ValueSpec
from .filters import (F, as_filter, no_match_report, select_union,
                      sinks_within, sources_within)

FilterLike = Union[F, dict]


class Directive:
    def apply(self, dag: TrainingDAG) -> None:  # pragma: no cover
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Place
# ---------------------------------------------------------------------------

@dataclass
class Place(Directive):
    filters: Union[FilterLike, Sequence[FilterLike]]
    devices: Sequence[int]
    stream: Optional[str] = None

    def apply(self, dag: TrainingDAG) -> None:
        filters = (self.filters if isinstance(self.filters, (list, tuple))
                   else [self.filters])
        matched = select_union(dag, [as_filter(f) for f in filters])
        if not matched:
            raise ValueError(
                f"Place({self.filters}) "
                + no_match_report(dag, list(filters)))
        for nid in matched:
            node = dag.nodes[nid]
            node.devices = tuple(self.devices)
            if self.stream is not None:
                node.meta.setdefault("p2p_stream", self.stream)
        # remember the stream to use for p2p comms inserted at finalize time
        if self.stream is not None:
            dag.meta.setdefault("p2p_streams", {})
            for nid in matched:
                dag.meta["p2p_streams"][nid] = self.stream


# ---------------------------------------------------------------------------
# Replicate — DP / ZeRO
# ---------------------------------------------------------------------------

@dataclass
class Replicate(Directive):
    filter: FilterLike
    devices: Sequence[int]
    gather_stream: Optional[str] = None
    reduce_stream: Optional[str] = None
    shard_params: bool = False     # ZeRO-3
    shard_grads: bool = False      # ZeRO-2
    bucket_sz: Optional[int] = None

    def apply(self, dag: TrainingDAG) -> None:
        f = as_filter(self.filter)
        matched = [nid for nid in f.select(dag) if dag.nodes[nid].is_chunk]
        if not matched:
            raise ValueError(
                f"Replicate({self.filter}) "
                + no_match_report(dag, self.filter, what="chunks"))
        devices = tuple(self.devices)
        touched_buckets: set[str] = set()
        for nid in matched:
            node = dag.nodes[nid]
            node.devices = devices
            node.meta["placement_mode"] = "replicate"
            if node.bucket:
                b = dag.bucket_of(node.bucket)
                b.replica_devices = devices
                b.shard_params = self.shard_params
                b.shard_grads = self.shard_grads
                b.bucket_sz = self.bucket_sz
                touched_buckets.add(node.bucket)

        # (a) grad synchronization after each matched backward chunk
        for nid in matched:
            node = dag.nodes[nid]
            if node.dims.get("PASS") not in (PASS_B, PASS_BW):
                continue
            if not node.bucket:
                continue
            b = dag.bucket_of(node.bucket)
            op = "reduce_scatter" if self.shard_grads else "all_reduce"
            n_parts = 1
            if self.bucket_sz and b.param_bytes > self.bucket_sz:
                n_parts = math.ceil(b.param_bytes / self.bucket_sz)
            grad_spec = ValueSpec((max(b.param_bytes // 4 // n_parts, 1),),
                                  "float32")
            prev_sinks = dag.grad_sinks.get(node.bucket, [])
            prev_sinks = [s for s in prev_sinks if s[0] != nid]
            new_sinks = []
            for part in range(n_parts):
                comm = dag.new_node(
                    kind="comm", op=op, name=f"{op}:{node.bucket}"
                    + (f"#{part}" if n_parts > 1 else ""),
                    dims=dict(node.dims), devices=devices, group=devices,
                    stream=self.reduce_stream, payload="grad",
                    out_specs=[grad_spec],
                    meta={"bucket": node.bucket, "part": part,
                          "n_parts": n_parts,
                          "zero": 2 if self.shard_grads else 1},
                )
                # grads leave the backward chunk at output slot 0
                dag.add_edge(nid, 0, comm.id, 0, grad_spec)
                new_sinks.append((comm.id, 0))
            dag.grad_sinks[node.bucket] = prev_sinks + new_sinks

        # (b) ZeRO-3: all-gather params before every matched chunk
        if self.shard_params:
            for nid in matched:
                node = dag.nodes[nid]
                if not node.bucket:
                    continue
                b = dag.bucket_of(node.bucket)
                spec = ValueSpec((max(b.param_bytes // 2, 1),), "bfloat16")
                comm = dag.new_node(
                    kind="comm", op="all_gather",
                    name=f"all_gather:{node.bucket}",
                    dims=dict(node.dims), devices=devices, group=devices,
                    stream=self.gather_stream, payload="param",
                    out_specs=[spec],
                    meta={"bucket": node.bucket, "zero": 3},
                )
                # param input arrives on the reserved "param" slot (-1)
                dag.add_edge(comm.id, 0, nid, -1, spec)
                node.meta["param_from_comm"] = comm.id


# ---------------------------------------------------------------------------
# Shard — expert parallelism
# ---------------------------------------------------------------------------

@dataclass
class Shard(Directive):
    filter: FilterLike
    devices: Sequence[int]
    stream: Optional[str] = None

    def apply(self, dag: TrainingDAG) -> None:
        f = as_filter(self.filter)
        matched = [nid for nid in f.select(dag) if dag.nodes[nid].is_chunk]
        if not matched:
            raise ValueError(
                f"Shard({self.filter}) "
                + no_match_report(dag, self.filter, what="chunks"))
        devices = tuple(self.devices)
        for nid in matched:
            node = dag.nodes[nid]
            node.devices = devices
            node.meta["placement_mode"] = "shard_expert"
            if node.bucket:
                dag.bucket_of(node.bucket).expert_devices = devices
            # all-to-all on every activation edge in and out of the chunk
            for e in list(dag.in_edges(nid)):
                if e.dst_in < 0:  # param slot
                    continue
                src = dag.nodes[e.src]
                if src.is_comm and src.op == "all_to_all":
                    continue
                a2a = dag.new_node(
                    kind="comm", op="all_to_all",
                    name=f"a2a_in:{node.name}", dims=dict(node.dims),
                    devices=devices, group=devices, stream=self.stream,
                    payload="act", out_specs=[e.spec])
                dag.splice_comm_on_edge(e, a2a)
            for e in list(dag.out_edges(nid)):
                dst = dag.nodes[e.dst]
                if dst.is_comm and dst.op == "all_to_all":
                    continue
                a2a = dag.new_node(
                    kind="comm", op="all_to_all",
                    name=f"a2a_out:{node.name}", dims=dict(node.dims),
                    devices=devices, group=devices, stream=self.stream,
                    payload="act", out_specs=[e.spec])
                dag.splice_comm_on_edge(e, a2a)


# ---------------------------------------------------------------------------
# Split — microbatching
# ---------------------------------------------------------------------------

@dataclass
class Split(Directive):
    filter: FilterLike = field(default_factory=lambda: F())
    dim: str = "MB"
    num_microbatches: int = 2

    def apply(self, dag: TrainingDAG) -> None:
        f = as_filter(self.filter)
        matched = set(f.select(dag))
        if not matched:
            raise ValueError(
                f"Split({self.filter}) " + no_match_report(dag, self.filter))
        k = self.num_microbatches
        if k <= 1:
            return
        # Order-before-Split footgun (the documented one): overlap groups
        # record node-id sets, so cloning their members would silently
        # leave every mb>0 copy un-grouped.  Fail loudly instead.
        stale = {nid for groups in dag.overlap_groups
                 for members in groups for nid in members} & matched
        if stale:
            names = ", ".join(dag.nodes[nid].short()
                              for nid in sorted(stale)[:3])
            raise ValueError(
                "Split would clone nodes already referenced by an "
                "Order overlap group (e.g. " + names + "); issue Order "
                "after Split (paper Listing 2) so the groups see the "
                "per-microbatch clones")
        # check contiguity: boundary input edges must come from graph inputs
        for e in dag.edges:
            if e.dst in matched and e.src not in matched:
                raise ValueError(
                    "Split requires a contiguous sub-DAG; node "
                    f"{dag.nodes[e.dst].short()} consumes from outside")

        old_nodes = {nid: dag.nodes[nid] for nid in matched}
        old_edges = [e for e in dag.edges if e.src in matched]
        old_temporal = [(u, v) for (u, v) in dag.temporal
                        if u in matched and v in matched]
        # mapping: (old_id, mb) -> new node
        clones: dict[tuple[int, int], Node] = {}
        for mb in range(k):
            for nid, old in old_nodes.items():
                if mb == 0:
                    new = old
                else:
                    split_specs = (old.is_chunk or old.payload == "act")
                    new = dag.new_node(
                        kind=old.kind, name=old.name, dims=dict(old.dims),
                        devices=old.devices, stream=old.stream, fn=old.fn,
                        bucket=old.bucket, n_outputs=old.n_outputs,
                        out_specs=self._split_out_specs(old) if split_specs
                        else list(old.out_specs),
                        op=old.op, group=old.group,
                        src_device=old.src_device, dst_device=old.dst_device,
                        payload=old.payload, meta=dict(old.meta),
                    )
                new.dims[self.dim] = mb
                clones[(nid, mb)] = new
        # node-reference metadata must point at the same-microbatch clone
        # (e.g. a chunk's param_from_comm gather, autodiff fwd/bwd links)
        for mb in range(k):
            for nid in matched:
                node = clones[(nid, mb)]
                for key in ("param_from_comm", "fwd_node", "bwd_node",
                            "bw_node"):
                    ref = node.meta.get(key)
                    if ref is not None and ref in matched:
                        node.meta[key] = clones[(ref, mb)].id
            # duplicate internal data edges
            if mb > 0:
                for e in old_edges:
                    if e.dst in matched:
                        dag.add_edge(clones[(e.src, mb)].id, e.src_out,
                                     clones[(e.dst, mb)].id, e.dst_in,
                                     self._split_edge_spec(old_nodes, e))
                    else:
                        # boundary output (e.g. grads flowing out): replicate
                        dag.add_edge(clones[(e.src, mb)].id, e.src_out,
                                     e.dst, e.dst_in, e.spec)
                for (u, v) in old_temporal:
                    dag.add_temporal(clones[(u, mb)].id, clones[(v, mb)].id)
        # shrink copy-0 activation specs too
        for nid in matched:
            n = dag.nodes[nid]
            if n.is_chunk or n.payload == "act":
                n.out_specs = self._split_out_specs(n)
        for e in list(dag.edges):
            if e.src in matched and e.dst in matched:
                dag.edges.remove(e)
                dag.edges.append(e.moved(
                    spec=self._split_edge_spec(dag.nodes, e)))

        # graph inputs: each consumer inside the split region now has k
        # sliced instances
        mb_inputs: dict[str, Any] = {}
        for name, (spec, consumers) in list(dag.inputs.items()):
            inside = [(nid, slot) for (nid, slot) in consumers
                      if nid in matched]
            if not inside:
                continue
            outside = [(nid, slot) for (nid, slot) in consumers
                       if nid not in matched]
            new_spec = self._split_spec(spec)
            names = []
            for mb in range(k):
                sub = f"{name}@{self.dim}{mb}"
                names.append(sub)
                subs = [(clones[(nid, mb)].id, slot) for (nid, slot) in inside]
                dag.inputs[sub] = (new_spec, subs)
            if outside:
                dag.inputs[name] = (spec, outside)
            else:
                del dag.inputs[name]
            mb_inputs[name] = {"dim": self.dim, "k": k, "names": names}
        dag.meta.setdefault("microbatch_inputs", {}).update(mb_inputs)

        # graph outputs (loss): one per microbatch; runtime averages
        new_outputs = []
        for (nid, slot) in dag.outputs:
            if nid in matched:
                for mb in range(k):
                    new_outputs.append((clones[(nid, mb)].id, slot))
            else:
                new_outputs.append((nid, slot))
        dag.outputs = new_outputs

        # grad sinks grow per microbatch
        for bucket, sinks in list(dag.grad_sinks.items()):
            new_sinks = []
            for (nid, slot) in sinks:
                if nid in matched:
                    for mb in range(k):
                        new_sinks.append((clones[(nid, mb)].id, slot))
                else:
                    new_sinks.append((nid, slot))
            dag.grad_sinks[bucket] = new_sinks

        # overlap groups referencing split nodes are rejected at the top
        # of apply(); Order must be issued after Split (paper Listing 2).

    def _split_spec(self, spec: ValueSpec) -> ValueSpec:
        if not spec.shape:
            return spec
        lead = spec.shape[0]
        if lead % self.num_microbatches == 0:
            return spec.with_leading(lead // self.num_microbatches)
        return spec

    def _split_out_specs(self, node: Node) -> list:
        """Per-slot spec shrink; ``static_out_slots`` (remat residual
        leaves that do not scale with the batch, e.g. saved weights)
        keep their spec."""
        static = set(node.meta.get("static_out_slots", ()))
        return [s if i in static else self._split_spec(s)
                for i, s in enumerate(node.out_specs)]

    def _split_edge_spec(self, nodes, e: Edge) -> ValueSpec:
        src = nodes.get(e.src) if hasattr(nodes, "get") else None
        if src is not None and \
                e.src_out in src.meta.get("static_out_slots", ()):
            return e.spec
        return self._split_spec(e.spec)


# ---------------------------------------------------------------------------
# Order — temporal edges and overlap groups
# ---------------------------------------------------------------------------

@dataclass
class Order(Directive):
    """Temporal ordering between matched sub-DAGs.  Nested filter lists
    declare overlap groups (interleaved execution).  By default only
    Chunk nodes are constrained: communication dispatches asynchronously
    in the runtime (paper §4.3.2), so pinning comms into the compute
    order would serialize them onto the critical path (the Fig. 4b
    failure mode).  Pass ``chunks_only=False`` to order comms explicitly.
    """
    filter_list: Sequence[Union[FilterLike, Sequence[FilterLike]]] = ()
    chunks_only: bool = True

    def _select(self, dag: TrainingDAG, f) -> set[int]:
        sel = set(as_filter(f).select(dag))
        if self.chunks_only:
            sel = {nid for nid in sel if dag.nodes[nid].is_chunk}
        return sel

    def apply(self, dag: TrainingDAG) -> None:
        groups: list[set[int]] = []
        overlap_records: list[tuple[frozenset[int], ...]] = []
        for item in self.filter_list:
            if isinstance(item, (list, tuple)):
                members = [self._select(dag, f) for f in item]
                for f, m in zip(item, members):
                    if not m:
                        raise ValueError(
                            f"Order({f}) "
                            + no_match_report(dag, f, what="chunk nodes"
                                              if self.chunks_only
                                              else "nodes"))
                overlap_records.append(tuple(frozenset(m) for m in members))
                groups.append(set().union(*members))
            else:
                sel = self._select(dag, item)
                if not sel:
                    raise ValueError(
                        f"Order({item}) "
                        + no_match_report(dag, item, what="chunk nodes"
                                          if self.chunks_only
                                          else "nodes"))
                groups.append(sel)
        for a, b in zip(groups, groups[1:]):
            for u in sinks_within(dag, a - b):
                for v in sources_within(dag, b - a):
                    dag.add_temporal(u, v)
        dag.overlap_groups.extend(overlap_records)
