"""Backward-chunk construction (paper §4.2 phase 1).

The paper relies on the PyTorch autograd engine to produce opaque backward
graphs per Chunk.  Here each backward Chunk is an explicit JAX callable
built with ``jax.vjp`` over the forward chunk's exec function.

Residual policy (DESIGN.md §2): the default is *per-chunk rematerialization*
— a backward chunk re-runs its forward under ``jax.vjp`` from the chunk's
**inputs** (boundary activations).  Thus the residual edges of the IR are
exactly the chunk-boundary activations, which is what pipeline-parallel
systems stash between forward and backward; intra-chunk activation memory is
a compute/memory tradeoff handled by remat policy, not by the IR.

Backward chunk slot convention for a forward chunk with m inputs, k outputs:
  inputs : [fwd_in_0 … fwd_in_{m-1}, cot_out_0 … cot_out_{k-1}]
  outputs: [bucket_grads, cot_in_0 … cot_in_{m-1}]

Cotangent plumbing:
  - a loss output slot gets its cotangent seeded to 1.0 by the runtime
    (``meta["seed_slots"]``);
  - a forward output with no consumer gets a zero cotangent
    (``meta["zero_cot_slots"]``);
  - a forward output with multiple consumers receives multiple cotangent
    edges on the same slot; the runtime sums them;
  - cotangents produced for graph inputs (data) are discarded.
"""
from __future__ import annotations

import jax

from .dag import PASS_B, PASS_F, TrainingDAG, ValueSpec
from .trace import PASS_DIM


def _make_bwd_fn(fwd_fn, m: int, k: int, has_bucket: bool):
    def bwd(bucket, *args):
        ins, cots = args[:m], args[m:]
        if has_bucket:
            _, vjp = jax.vjp(lambda b, *i: fwd_fn(b, *i), bucket, *ins)
            grads = vjp(tuple(cots))
            bucket_grads, in_cots = grads[0], grads[1:]
        else:
            _, vjp = jax.vjp(lambda *i: fwd_fn(None, *i), *ins)
            in_cots = vjp(tuple(cots))
            bucket_grads = None
        return (bucket_grads,) + tuple(in_cots)
    bwd.__name__ = f"bwd_{getattr(fwd_fn, '__name__', 'chunk')}"
    return bwd


def _make_bi_fn(fwd_fn, m: int):
    """Backward-for-inputs (ZeroBubble 'B'): input cotangents only."""
    def bi(bucket, *args):
        ins, cots = args[:m], args[m:]
        _, vjp = jax.vjp(lambda *i: fwd_fn(bucket, *i), *ins)
        in_cots = vjp(tuple(cots))
        return (None,) + tuple(in_cots)
    bi.__name__ = f"bi_{getattr(fwd_fn, '__name__', 'chunk')}"
    return bi


def _make_bw_fn(fwd_fn, m: int):
    """Backward-for-weights (ZeroBubble 'W'): bucket grads only."""
    def bw(bucket, *args):
        ins, cots = args[:m], args[m:]
        _, vjp = jax.vjp(lambda b: fwd_fn(b, *ins), bucket)
        (bucket_grads,) = vjp(tuple(cots))
        return (bucket_grads,) + (None,) * m
    bw.__name__ = f"bw_{getattr(fwd_fn, '__name__', 'chunk')}"
    return bw


def build_backward(dag: TrainingDAG, split_backward: bool = False) -> None:
    """Append backward chunks (reverse topo order) + cotangent edges.

    ``split_backward=True`` emits ZeroBubble-style Bi (backward-for-
    inputs, PASS="Bi") + Bw (backward-for-weights, PASS="Bw") chunk pairs
    for bucketed chunks instead of a joint B chunk — the mechanism behind
    ZeroBubble and DualPipeV schedules (paper §4.1 PASS dimension).

    Must run on the single-device DAG, before any directives."""
    fwd_ids = [nid for nid in dag.toposort()
               if dag.nodes[nid].is_chunk
               and dag.nodes[nid].dims.get(PASS_DIM) == PASS_F]
    loss_slots = set(dag.outputs)

    # per fwd chunk: slot -> ("edge", Edge) | ("input", name)
    def input_feeds(nid):
        feeds = {}
        for e in dag.in_edges(nid):
            if e.dst_in >= 0:
                feeds[e.dst_in] = ("edge", e)
        for name, (spec, consumers) in dag.inputs.items():
            for (cnid, cslot) in consumers:
                if cnid == nid:
                    feeds[cslot] = ("input", name, spec)
        return feeds

    # (fwd_node, out_slot) -> [(bwd_node, bwd_out_slot)] cotangent producers
    cot_sources: dict[tuple[int, int], list[tuple[int, int]]] = {}
    bwd_of: dict[int, int] = {}

    for nid in reversed(fwd_ids):
        fwd = dag.nodes[nid]
        feeds = input_feeds(nid)
        m = fwd.meta.get("n_inputs", len(feeds))
        if set(feeds) != set(range(m)):
            raise ValueError(
                f"chunk {fwd.short()} has unfed input slots: "
                f"expected {m}, fed {sorted(feeds)}")
        k = fwd.n_outputs
        grads_bytes = dag.bucket_of(fwd.bucket).param_bytes if fwd.bucket else 0
        grad_spec = ValueSpec((max(grads_bytes // 4, 1),), "float32")

        def feed_spec(j):
            f = feeds[j]
            return f[1].spec if f[0] == "edge" else f[2]

        def make_side(pass_tag: str, fn, produce_cots: bool,
                      produce_grads: bool):
            dims = {d: v for d, v in fwd.dims.items() if d != PASS_DIM}
            dims[PASS_DIM] = pass_tag
            node = dag.new_node(
                kind="chunk",
                name=f"{pass_tag.lower()}_{fwd.name}",
                dims=dims,
                fn=fn,
                bucket=fwd.bucket,
                n_outputs=1 + m,
                out_specs=[grad_spec] + [feed_spec(j) for j in range(m)],
                meta={"fwd_node": nid, "n_inputs": m + k, "n_cots": k,
                      "is_backward": True,
                      "origin": f"autodiff({pass_tag} of {fwd.name!r})"},
            )
            # residual edges: forward inputs flow to the backward chunk too
            for j in range(m):
                f = feeds[j]
                if f[0] == "edge":
                    e = f[1]
                    dag.add_edge(e.src, e.src_out, node.id, j, e.spec)
                else:
                    name = f[1]
                    spec, consumers = dag.inputs[name]
                    dag.inputs[name] = (spec, consumers + [(node.id, j)])
                    node.meta.setdefault("discard_out_slots",
                                         []).append(1 + j)
            # cotangent input edges: one per forward output slot
            for out_slot in range(k):
                if (nid, out_slot) in loss_slots:
                    node.meta.setdefault("seed_slots",
                                         []).append(m + out_slot)
                    continue
                srcs = cot_sources.get((nid, out_slot), [])
                if not srcs:
                    node.meta.setdefault("zero_cot_slots",
                                         []).append(m + out_slot)
                    continue
                for (src_node, src_slot) in srcs:
                    dag.add_edge(src_node, src_slot, node.id, m + out_slot,
                                 fwd.out_specs[out_slot])
            if produce_grads and fwd.bucket:
                dag.grad_sinks.setdefault(fwd.bucket,
                                          []).append((node.id, 0))
            return node

        split = split_backward and fwd.bucket is not None
        if split:
            bi = make_side("Bi", _make_bi_fn(fwd.fn, m),
                           produce_cots=True, produce_grads=False)
            bw = make_side("Bw", _make_bw_fn(fwd.fn, m),
                           produce_cots=False, produce_grads=True)
            main_bwd = bi
            fwd.meta["bwd_node"] = bi.id
            fwd.meta["bw_node"] = bw.id
        else:
            main_bwd = make_side(
                PASS_B, _make_bwd_fn(fwd.fn, m, k, fwd.bucket is not None),
                produce_cots=True, produce_grads=True)
            fwd.meta["bwd_node"] = main_bwd.id
        bwd_of[nid] = main_bwd.id

        # register cotangents the Bi/B chunk produces for upstream values
        for j in range(m):
            f = feeds[j]
            if f[0] == "edge":
                e = f[1]
                cot_sources.setdefault((e.src, e.src_out), []).append(
                    (main_bwd.id, 1 + j))

    dag.meta["bwd_of"] = bwd_of
