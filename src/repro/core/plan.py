"""Per-device execution plans — the centralized scheduler's output
(paper §4.3.1).

A ``Task`` is one device's instance of a DAG node: chunks and collectives
instantiate on every device in their placement; ``p2p`` nodes decompose
into a *send* task on the source device and a *recv* task on the
destination (paper: send and recv get separate streams + communicators, so
only per-direction order must match across ranks)."""
from __future__ import annotations

from dataclasses import dataclass, field

TaskKey = tuple[int, int, str]  # (node_id, device, role)

ROLE_COMPUTE = "compute"
ROLE_COLL = "coll"
ROLE_SEND = "send"
ROLE_RECV = "recv"


@dataclass
class Task:
    node: int
    device: int
    role: str
    stream: str
    deps: list[TaskKey] = field(default_factory=list)
    # peer tasks that must rendezvous (collective instances / send<->recv)
    peers: list[TaskKey] = field(default_factory=list)

    @property
    def key(self) -> TaskKey:
        return (self.node, self.device, self.role)


@dataclass
class DevicePlan:
    device: int
    # stream name -> task keys in dispatch order (total order per stream)
    streams: dict[str, list[TaskKey]] = field(default_factory=dict)
    tasks: dict[TaskKey, Task] = field(default_factory=dict)

    def append(self, task: Task) -> None:
        self.tasks[task.key] = task
        self.streams.setdefault(task.stream, []).append(task.key)

    def n_tasks(self) -> int:
        return len(self.tasks)


@dataclass
class GlobalPlan:
    device_plans: dict[int, DevicePlan]
    priorities: dict[int, int]          # node -> #descendants
    devices: list[int]
    # the centralized scheduler's global dispatch order over nodes — one
    # deterministic linear extension that every per-(device, stream)
    # queue is a subsequence of.  ``rank_program`` slices it per rank
    # for inspection/debugging; the SPMD executor's trace order is the
    # *dynamic* analogue (``runtime.interpreter.replay_schedule``),
    # which additionally reflects the gather rate limiter.
    node_order: list[int] = field(default_factory=list)

    def plan_for(self, device: int) -> DevicePlan:
        return self.device_plans[device]

    def all_tasks(self) -> list[Task]:
        out = []
        for p in self.device_plans.values():
            out.extend(p.tasks.values())
        return out

    def rank_program(self, device: int) -> list[Task]:
        """Per-rank program extraction: this device's tasks in the
        scheduler's global dispatch order — the chunk/comm sequence a
        per-rank (MPMD-style) executor would run; every stream queue in
        ``device_plans[device].streams`` is a subsequence of it.
        (tests/test_spmd_executor.py asserts that invariant.)"""
        p = self.device_plans[device]
        if not self.node_order:
            return list(p.tasks.values())
        pos = {nid: i for i, nid in enumerate(self.node_order)}
        role_rank = {ROLE_COLL: 0, ROLE_COMPUTE: 1, ROLE_SEND: 2,
                     ROLE_RECV: 3}
        return sorted(p.tasks.values(),
                      key=lambda t: (pos.get(t.node, len(pos)),
                                     role_rank.get(t.role, 9)))

    def rank_signature(self, device: int, dag) -> dict:
        """The typed communication interface of ``rank_program(device)``
        — per-peer p2p send/recv specs and per-group collective
        dispatch sequences.  Pairwise agreement of these signatures
        across ranks is the MPMD-readiness condition; the analysis
        layer checks it as PIPER025 (``repro.analysis.rank_signature``
        is the implementation, delegated to keep core import-light)."""
        from ..analysis.types import rank_signature
        return rank_signature(dag, self, device)

    def summary(self) -> str:
        lines = []
        for d in sorted(self.device_plans):
            p = self.device_plans[d]
            per = {s: len(v) for s, v in p.streams.items()}
            lines.append(f"device {d}: {p.n_tasks()} tasks {per}")
        return "\n".join(lines)


class ScheduleRejected(Exception):
    """Raised when a schedule violates the p2p/collective ordering rule
    (paper §4.3.2: 'Piper currently rejects schedules that do not meet
    this requirement')."""
