"""Phase-1 tracing: annotated model -> single-device training DAG of
forward Chunks (paper §4.2, Listing 1).

JAX adaptation (DESIGN.md §2): the paper captures chunks with TorchDynamo
bytecode tracing.  JAX has no frame-eval hook, so regions are *staged*:
the model's ``forward(rec, params, x)`` runs once under a ``Recorder``;

  - ``with rec.annotate(dim):`` tags a region; indices are assigned per
    dim in dataflow order (first PP block -> PP=0, …), as in the paper;
  - ``y = rec.region(fn, bucket)(x, …)`` delimits one Chunk whose exec
    function is the pure JAX callable ``fn(bucket_params, *inputs)``.

Values crossing region boundaries are ``TracedValue``s; their avals are
computed with ``jax.eval_shape`` so tracing never allocates device memory.
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass
from typing import Any, Callable, Optional

import math

import jax
import jax.numpy as jnp

from .dag import PASS_F, TrainingDAG, ValueSpec, tree_nbytes


def np_prod(shape) -> int:
    return math.prod(int(s) for s in shape)

PASS_DIM = "PASS"


@dataclass
class TracedValue:
    """A symbolic tensor produced by a chunk or fed as a graph input."""
    producer: Optional[tuple[int, int]]   # (node_id, out_slot)
    spec: ValueSpec
    input_name: Optional[str] = None

    @property
    def shape(self):
        return self.spec.shape

    @property
    def dtype(self):
        return self.spec.dtype

    def aval(self):
        return jax.ShapeDtypeStruct(self.spec.shape, jnp.dtype(self.spec.dtype))


class Recorder:
    """Builds the single-device forward DAG from an annotated model."""

    def __init__(self, params: dict[str, Any]) -> None:
        """``params``: mapping bucket name -> param pytree (arrays or
        ShapeDtypeStructs — only shapes/dtypes are used at trace time)."""
        self.dag = TrainingDAG()
        self.params = params
        self.param_avals = {
            k: jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), v)
            for k, v in params.items()
        }
        self._dim_stack: list[tuple[str, int]] = []
        self._dim_counters: dict[str, int] = {}
        self._finalized = False

    # -- user API ------------------------------------------------------------
    @contextlib.contextmanager
    def annotate(self, dim: str):
        idx = self._dim_counters.get(dim, 0)
        self._dim_counters[dim] = idx + 1
        self._dim_stack.append((dim, idx))
        try:
            yield idx
        finally:
            self._dim_stack.pop()

    def input(self, name: str, shape, dtype="float32") -> TracedValue:
        spec = ValueSpec(tuple(int(s) for s in shape), str(dtype))
        if name in self.dag.inputs:
            raise ValueError(f"duplicate graph input {name!r}")
        self.dag.inputs[name] = (spec, [])
        return TracedValue(producer=None, spec=spec, input_name=name)

    def region(self, fn: Callable, bucket: Optional[str] = None,
               name: Optional[str] = None) -> Callable:
        """Wrap ``fn(bucket_params, *inputs)`` as a Chunk constructor."""

        def run(*args: TracedValue) -> Any:
            for a in args:
                if not isinstance(a, TracedValue):
                    raise TypeError(
                        "region inputs must be TracedValues (graph inputs "
                        f"or prior region outputs); got {type(a)}")
            bkt_aval = self.param_avals.get(bucket) if bucket else None
            in_avals = [a.aval() for a in args]
            if bucket is not None:
                out_aval = jax.eval_shape(fn, bkt_aval, *in_avals)
            else:
                out_aval = jax.eval_shape(lambda _, *i: fn(None, *i),
                                          None, *in_avals)
            single = not isinstance(out_aval, (tuple, list))
            outs = (out_aval,) if single else tuple(out_aval)
            for o in outs:
                if not hasattr(o, "shape"):
                    raise TypeError(
                        "region outputs must be arrays (pytree outputs "
                        "should be split into separate regions)")
            dims = {d: i for (d, i) in self._dim_stack}
            dims[PASS_DIM] = PASS_F
            node = self.dag.new_node(
                kind="chunk",
                name=name or getattr(fn, "__name__", "region"),
                dims=dims,
                fn=_normalize(fn, single),
                bucket=bucket,
                n_outputs=len(outs),
                out_specs=[ValueSpec(tuple(o.shape), str(o.dtype))
                           for o in outs],
                meta={"single_output": single, "n_inputs": len(args),
                      "origin": f"region({name or getattr(fn, '__name__', 'region')!r}"
                                + (f", bucket={bucket!r}" if bucket else "")
                                + ")"},
            )
            if bucket:
                b = self.dag.bucket_of(bucket)
                if b.param_bytes == 0:
                    b.param_bytes = tree_nbytes(self.param_avals[bucket])
                    b.param_elems = sum(
                        int(np_prod(l.shape)) for l in
                        jax.tree_util.tree_leaves(self.param_avals[bucket]))
            for slot, a in enumerate(args):
                if a.producer is not None:
                    self.dag.add_edge(a.producer[0], a.producer[1],
                                      node.id, slot, a.spec)
                else:
                    self.dag.inputs[a.input_name][1].append((node.id, slot))
            tvs = tuple(
                TracedValue(producer=(node.id, i),
                            spec=ValueSpec(tuple(o.shape), str(o.dtype)))
                for i, o in enumerate(outs))
            return tvs[0] if single else tvs

        return run

    def finalize(self, *losses: TracedValue) -> TrainingDAG:
        if self._finalized:
            raise RuntimeError("Recorder already finalized")
        self._finalized = True
        for lv in losses:
            if lv.producer is None:
                raise ValueError("loss must be produced by a region")
            self.dag.outputs.append(lv.producer)
        return self.dag


def _normalize(fn: Callable, single: bool) -> Callable:
    """Chunk exec functions always return a tuple of arrays."""
    if single:
        def wrapped(bucket, *ins):
            return (fn(bucket, *ins),)
        wrapped.__name__ = getattr(fn, "__name__", "region")
        wrapped.inner = fn
        return wrapped
    fn_t = fn

    def wrapped_t(bucket, *ins):
        return tuple(fn_t(bucket, *ins))
    wrapped_t.__name__ = getattr(fn, "__name__", "region")
    wrapped_t.inner = fn
    return wrapped_t


def trace_model(model, params: dict[str, Any], *inputs_spec,
                **named_inputs) -> TrainingDAG:
    """Convenience: run ``model.forward(rec, …)`` under a fresh Recorder.

    ``model`` must expose ``forward(rec, inputs: dict[str, TracedValue])``
    returning the loss TracedValue; ``named_inputs`` maps input name ->
    (shape, dtype)."""
    rec = Recorder(params)
    tvs = {k: rec.input(k, shape, dtype)
           for k, (shape, dtype) in named_inputs.items()}
    loss = model.forward(rec, tvs)
    return rec.finalize(loss)
