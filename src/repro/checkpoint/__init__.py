"""Sharded checkpointing with async writes + elastic restore, and the
ZeRO shard remap codec for data-parallel degree changes."""
from .manager import (CheckpointManager, CorruptCheckpointError,
                      load_manifest, restore_tree, save_tree)
from .reshard import (ReshardError, remap_shards, reshard_tree,
                      shard_leaf, shard_tree, unshard_leaf, unshard_tree)

__all__ = ["CheckpointManager", "CorruptCheckpointError", "ReshardError",
           "load_manifest", "remap_shards", "reshard_tree",
           "restore_tree", "save_tree", "shard_leaf", "shard_tree",
           "unshard_leaf", "unshard_tree"]
