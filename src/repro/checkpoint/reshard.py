"""ZeRO shard remapping across data-parallel degree changes.

Under ZeRO-2/3 every DP rank owns a 1/d flat slice of each gradient /
parameter leaf.  When the elastic planner shrinks (or regrows) the DP
degree, the surviving ranks must *regather* the old shards and re-slice
them for the new degree — this module is that codec, and it is required
to be **bit-exact**: resharding is a placement change, never a numerics
change (tests/test_property.py round-trips it under hypothesis).

Shard layout (the repo-wide convention, matching ``Replicate``'s
flat-bucket sharding): a leaf is flattened C-order, zero-padded up to a
multiple of the degree, and split into ``degree`` equal contiguous
slices — rank ``i`` owns slice ``i``.  The pad bytes are never part of
the restored value (``unshard_leaf`` truncates to the true element
count), so padding cannot leak across a degree change.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


class ReshardError(ValueError):
    """A shard remap failed integrity verification."""


def _check_degree(degree: int) -> None:
    if not isinstance(degree, int) or isinstance(degree, bool) \
            or degree < 1:
        raise ReshardError(f"shard degree must be a positive int, "
                           f"got {degree!r}")


def shard_leaf(arr, degree: int) -> list[np.ndarray]:
    """Flatten ``arr`` and split it into ``degree`` equal contiguous
    shards (last ones zero-padded)."""
    _check_degree(degree)
    a = np.asarray(arr)
    flat = a.reshape(-1)
    chunk = -(-flat.size // degree) if flat.size else 0
    pad = chunk * degree - flat.size
    if pad:
        flat = np.concatenate([flat, np.zeros(pad, a.dtype)])
    return [flat[i * chunk:(i + 1) * chunk].copy()
            for i in range(degree)]


def unshard_leaf(shards: Sequence[np.ndarray], shape, dtype) -> np.ndarray:
    """Reassemble a full leaf from its ordered shards (inverse of
    ``shard_leaf``; drops the pad)."""
    dtype = np.dtype(dtype)
    parts = [np.asarray(s).reshape(-1) for s in shards]
    flat = (np.concatenate(parts) if parts
            else np.zeros((0,), dtype))
    n = int(np.prod(shape, dtype=np.int64)) if len(shape) else 1
    return np.ascontiguousarray(flat[:n]).astype(dtype, copy=False) \
        .reshape(shape)


def remap_shards(shards: Sequence[np.ndarray], new_degree: int,
                 n_elements: int) -> list[np.ndarray]:
    """Regather + re-slice: old-degree shards -> new-degree shards.
    ``n_elements`` is the true (unpadded) leaf size — the old pad is
    stripped before re-padding for the new degree."""
    _check_degree(new_degree)
    parts = [np.asarray(s).reshape(-1) for s in shards]
    flat = np.concatenate(parts) if parts else np.zeros((0,))
    return shard_leaf(flat[:n_elements], new_degree)


def shard_tree(tree, degree: int) -> list:
    """Per-rank pytrees of flat shards: ``shard_tree(t, d)[i]`` is what
    DP rank ``i`` owns (same treedef as ``tree``)."""
    _check_degree(degree)
    return [jax.tree_util.tree_map(
        lambda x, i=i: shard_leaf(x, degree)[i], tree)
        for i in range(degree)]


def unshard_tree(per_rank: Sequence, tree_like):
    """Inverse of ``shard_tree``: reassemble the full tree, taking
    shapes/dtypes from ``tree_like``."""
    flat_like, treedef = jax.tree_util.tree_flatten(tree_like)
    rank_leaves = [jax.tree_util.tree_leaves(t) for t in per_rank]
    out = []
    for k, leaf in enumerate(flat_like):
        shards = [rl[k] for rl in rank_leaves]
        out.append(unshard_leaf(shards, np.shape(leaf),
                                np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def reshard_tree(tree, old_degree: int, new_degree: int, *,
                 verify: bool = True):
    """Remap every leaf of ``tree`` from ``old_degree`` ZeRO shards to
    ``new_degree`` and reassemble — the elastic restore path
    (``ft.elastic.ElasticSupervisor``) runs restored params/opt state
    through this whenever the shrunk mesh changes the DP width.

    With ``verify=True`` (default) every leaf's reassembled bytes are
    checked against the input — a reshard that is not bit-identical is
    corruption, not a rounding question — and ``ReshardError`` names the
    first differing leaf."""
    _check_degree(old_degree)
    _check_degree(new_degree)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        a = np.asarray(leaf)
        shards = remap_shards(shard_leaf(a, old_degree), new_degree,
                              a.size)
        full = unshard_leaf(shards, a.shape, a.dtype)
        if verify and full.tobytes() != a.tobytes():
            raise ReshardError(
                f"ZeRO reshard {old_degree}->{new_degree} corrupted "
                f"leaf {jax.tree_util.keystr(path)} "
                f"(shape {a.shape}, dtype {a.dtype})")
        out.append(jnp.asarray(full))
    return jax.tree_util.tree_unflatten(treedef, out)


__all__ = ["ReshardError", "remap_shards", "reshard_tree", "shard_leaf",
           "shard_tree", "unshard_leaf", "unshard_tree"]
