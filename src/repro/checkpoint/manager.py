"""Checkpointing: per-leaf .npy shards + a JSON manifest with integrity
hashes; optional async background writes; elastic restore (a checkpoint
saved under one mesh restores under any other — arrays are stored
unsharded per leaf and re-placed with the target shardings).

Crash safety: every save builds the full checkpoint under a ``.tmp``
sibling and publishes it with one atomic ``rename``; the manifest itself
is written via temp-file + ``os.replace`` and carries a *content digest*
(sha256 over the canonical per-leaf hash table), so a kill mid-save can
never leave a half-written checkpoint that a later restore picks up, and
a flipped byte anywhere in the data or the manifest is detected
(``CorruptCheckpointError``) rather than silently restored.

At real multi-host scale each host writes only its shard slice; on this
single-host container the full leaves are written, but the manifest
format (leaf path -> file, shape, dtype, sha256) and the restore path
are the production shape of the system.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


class CorruptCheckpointError(IOError):
    """A checkpoint failed integrity verification: a leaf's bytes do not
    match its manifest sha256, the manifest's content digest does not
    match its leaf table, or a leaf file is missing/unreadable.  The
    elastic supervisor treats this as a *skippable* fault — restore
    falls back to the next-older checkpoint (see
    ``ElasticSupervisor``/``CheckpointManager.restore``)."""


def _leaf_name(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "__".join(parts) or "leaf"


def _content_digest(leaves: dict) -> str:
    """sha256 over the canonical JSON of the per-leaf hash table — one
    digest that covers every leaf's bytes, shape and dtype, so manifest
    tampering (or torn writes) is as detectable as data corruption."""
    canon = json.dumps(leaves, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(canon.encode()).hexdigest()


def _write_manifest(directory: pathlib.Path, manifest: dict,
                    fsync: bool = False) -> None:
    # temp + os.replace: readers never observe a torn manifest even if
    # the writer dies mid-write
    tmp = directory / "manifest.json.tmp"
    data = json.dumps(manifest, indent=1)
    with open(tmp, "w") as f:
        f.write(data)
        if fsync:
            f.flush()
            os.fsync(f.fileno())
    os.replace(tmp, directory / "manifest.json")


def save_tree(tree, directory: pathlib.Path, extra: Optional[dict] = None,
              fsync: bool = False) -> dict:
    directory = pathlib.Path(directory)
    tmp = directory.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    manifest: dict[str, Any] = {"leaves": {}, "extra": extra or {},
                                "time": time.time()}
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        name = _leaf_name(path)
        arr = np.asarray(leaf)
        fn = f"{name}.npy"
        np.save(tmp / fn, arr)
        if fsync:
            fd = os.open(tmp / fn, os.O_RDONLY)
            try:
                os.fsync(fd)
            finally:
                os.close(fd)
        manifest["leaves"][name] = {
            "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "sha256": hashlib.sha256(arr.tobytes()).hexdigest(),
        }
    manifest["treedef"] = str(treedef)
    manifest["digest"] = _content_digest(manifest["leaves"])
    _write_manifest(tmp, manifest, fsync=fsync)
    if directory.exists():
        shutil.rmtree(directory)
    tmp.rename(directory)   # atomic publish
    return manifest


def load_manifest(directory: pathlib.Path) -> dict:
    """Read + integrity-check a checkpoint manifest.  Raises
    ``CorruptCheckpointError`` on a missing/torn/tampered manifest."""
    directory = pathlib.Path(directory)
    try:
        manifest = json.loads((directory / "manifest.json").read_text())
    except (OSError, json.JSONDecodeError) as e:
        raise CorruptCheckpointError(
            f"unreadable manifest under {directory}: {e}") from e
    want = manifest.get("digest")
    # pre-digest manifests (older checkpoints) stay restorable: per-leaf
    # sha256 verification below still covers the data
    if want is not None and _content_digest(manifest["leaves"]) != want:
        raise CorruptCheckpointError(
            f"manifest content digest mismatch under {directory}")
    return manifest


def restore_tree(tree_like, directory: pathlib.Path, *,
                 shardings=None, verify: bool = True):
    """Restore into the structure of ``tree_like`` (avals or arrays).
    ``shardings``: optional matching pytree of NamedShardings for elastic
    re-placement under a (possibly different) mesh."""
    directory = pathlib.Path(directory)
    manifest = load_manifest(directory)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    sh_flat = (jax.tree_util.tree_leaves(shardings)
               if shardings is not None else [None] * len(flat))
    out = []
    for (path, leaf), sh in zip(flat, sh_flat):
        name = _leaf_name(path)
        meta = manifest["leaves"][name]
        try:
            arr = np.load(directory / meta["file"])
        except (OSError, ValueError) as e:
            raise CorruptCheckpointError(
                f"unreadable leaf {name} under {directory}: {e}") from e
        want = np.dtype(meta["dtype"])
        if arr.dtype != want and arr.dtype.kind == "V" \
                and arr.dtype.itemsize == want.itemsize:
            # .npy round-trips extension dtypes (bfloat16, float8_*) as
            # raw void records; the manifest keeps the real dtype —
            # reinterpret the bits (same buffer, so sha256 still holds)
            arr = arr.view(want)
        if verify:
            digest = hashlib.sha256(arr.tobytes()).hexdigest()
            if digest != meta["sha256"]:
                raise CorruptCheckpointError(
                    f"checkpoint corruption in {name}")
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {name}: "
                             f"{arr.shape} vs {leaf.shape}")
        val = jax.device_put(arr, sh) if sh is not None else \
            jax.numpy.asarray(arr, dtype=leaf.dtype)
        out.append(val)
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Step-indexed checkpoints under root/step_{n}; keeps the newest
    ``keep`` checkpoints; optional async writer thread; ``fsync=True``
    forces leaf + manifest data to disk before the atomic publish (off
    by default — the tests' faked faults don't power-cycle the host)."""

    def __init__(self, root, keep: int = 3, async_save: bool = True,
                 fsync: bool = False):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self.fsync = fsync
        self._thread: Optional[threading.Thread] = None

    def _dir(self, step: int) -> pathlib.Path:
        return self.root / f"step_{step:08d}"

    def step_dir(self, step: int) -> pathlib.Path:
        """Public path accessor (used by chaos corruption helpers)."""
        return self._dir(step)

    def _steps_on_disk(self) -> list:
        # strict name filter: an in-flight save's "step_N.tmp" directory
        # (atomic-rename protocol in save_tree) must not be picked up by
        # a concurrent latest_step/_gc — only fully renamed checkpoints
        # count
        steps = []
        for p in self.root.glob("step_*"):
            suffix = p.name.split("_", 1)[1]
            if p.is_dir() and suffix.isdigit():
                steps.append(int(suffix))
        return sorted(steps)

    def steps(self) -> list:
        """Published checkpoint steps, oldest first (waits for any
        in-flight async save so the newest step is visible)."""
        self.wait()
        return self._steps_on_disk()

    def latest_step(self) -> Optional[int]:
        steps = self._steps_on_disk()
        return steps[-1] if steps else None

    def verify(self, step: int) -> bool:
        """True iff the checkpoint at ``step`` passes full integrity
        verification (manifest digest + every leaf's sha256)."""
        self.wait()
        d = self._dir(step)
        try:
            manifest = load_manifest(d)
            for meta in manifest["leaves"].values():
                arr = np.load(d / meta["file"])
                want = np.dtype(meta["dtype"])
                if arr.dtype != want and arr.dtype.kind == "V" \
                        and arr.dtype.itemsize == want.itemsize:
                    arr = arr.view(want)
                if hashlib.sha256(arr.tobytes()).hexdigest() \
                        != meta["sha256"]:
                    return False
        except (CorruptCheckpointError, OSError, ValueError, KeyError):
            return False
        return True

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree, extra: Optional[dict] = None) -> None:
        # snapshot to host memory synchronously; write in background
        host_tree = jax.tree_util.tree_map(np.asarray, tree)
        extra = dict(extra or {}, step=step)

        def work():
            save_tree(host_tree, self._dir(step), extra=extra,
                      fsync=self.fsync)
            self._gc()

        self.wait()
        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def restore(self, tree_like, step: Optional[int] = None,
                shardings=None):
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = self._dir(step)
        manifest = load_manifest(d)
        tree = restore_tree(tree_like, d, shardings=shardings)
        return tree, manifest["extra"]

    def _gc(self) -> None:
        steps = self._steps_on_disk()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._dir(s), ignore_errors=True)
