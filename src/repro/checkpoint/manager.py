"""Checkpointing: per-leaf .npy shards + a JSON manifest with integrity
hashes; optional async background writes; elastic restore (a checkpoint
saved under one mesh restores under any other — arrays are stored
unsharded per leaf and re-placed with the target shardings).

At real multi-host scale each host writes only its shard slice; on this
single-host container the full leaves are written, but the manifest
format (leaf path -> file, shape, dtype, sha256) and the restore path
are the production shape of the system.
"""
from __future__ import annotations

import hashlib
import json
import pathlib
import shutil
import threading
import time
from typing import Any, Callable, Optional

import jax
import numpy as np


def _leaf_name(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "__".join(parts) or "leaf"


def save_tree(tree, directory: pathlib.Path, extra: Optional[dict] = None,
              fsync: bool = False) -> dict:
    directory = pathlib.Path(directory)
    tmp = directory.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    manifest: dict[str, Any] = {"leaves": {}, "extra": extra or {},
                                "time": time.time()}
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        name = _leaf_name(path)
        arr = np.asarray(leaf)
        fn = f"{name}.npy"
        np.save(tmp / fn, arr)
        manifest["leaves"][name] = {
            "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "sha256": hashlib.sha256(arr.tobytes()).hexdigest(),
        }
    manifest["treedef"] = str(treedef)
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if directory.exists():
        shutil.rmtree(directory)
    tmp.rename(directory)   # atomic publish
    return manifest


def restore_tree(tree_like, directory: pathlib.Path, *,
                 shardings=None, verify: bool = True):
    """Restore into the structure of ``tree_like`` (avals or arrays).
    ``shardings``: optional matching pytree of NamedShardings for elastic
    re-placement under a (possibly different) mesh."""
    directory = pathlib.Path(directory)
    manifest = json.loads((directory / "manifest.json").read_text())
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    sh_flat = (jax.tree_util.tree_leaves(shardings)
               if shardings is not None else [None] * len(flat))
    out = []
    for (path, leaf), sh in zip(flat, sh_flat):
        name = _leaf_name(path)
        meta = manifest["leaves"][name]
        arr = np.load(directory / meta["file"])
        want = np.dtype(meta["dtype"])
        if arr.dtype != want and arr.dtype.kind == "V" \
                and arr.dtype.itemsize == want.itemsize:
            # .npy round-trips extension dtypes (bfloat16, float8_*) as
            # raw void records; the manifest keeps the real dtype —
            # reinterpret the bits (same buffer, so sha256 still holds)
            arr = arr.view(want)
        if verify:
            digest = hashlib.sha256(arr.tobytes()).hexdigest()
            if digest != meta["sha256"]:
                raise IOError(f"checkpoint corruption in {name}")
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {name}: "
                             f"{arr.shape} vs {leaf.shape}")
        val = jax.device_put(arr, sh) if sh is not None else \
            jax.numpy.asarray(arr, dtype=leaf.dtype)
        out.append(val)
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Step-indexed checkpoints under root/step_{n}; keeps the newest
    ``keep`` checkpoints; optional async writer thread."""

    def __init__(self, root, keep: int = 3, async_save: bool = True):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    def _dir(self, step: int) -> pathlib.Path:
        return self.root / f"step_{step:08d}"

    def _steps_on_disk(self) -> list:
        # strict name filter: an in-flight save's "step_N.tmp" directory
        # (atomic-rename protocol in save_tree) must not be picked up by
        # a concurrent latest_step/_gc — only fully renamed checkpoints
        # count
        steps = []
        for p in self.root.glob("step_*"):
            suffix = p.name.split("_", 1)[1]
            if p.is_dir() and suffix.isdigit():
                steps.append(int(suffix))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self._steps_on_disk()
        return steps[-1] if steps else None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree, extra: Optional[dict] = None) -> None:
        # snapshot to host memory synchronously; write in background
        host_tree = jax.tree_util.tree_map(np.asarray, tree)
        extra = dict(extra or {}, step=step)

        def work():
            save_tree(host_tree, self._dir(step), extra=extra)
            self._gc()

        self.wait()
        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def restore(self, tree_like, step: Optional[int] = None,
                shardings=None):
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = self._dir(step)
        manifest = json.loads((d / "manifest.json").read_text())
        tree = restore_tree(tree_like, d, shardings=shardings)
        return tree, manifest["extra"]

    def _gc(self) -> None:
        steps = self._steps_on_disk()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._dir(s), ignore_errors=True)
