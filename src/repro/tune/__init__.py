"""Simulator-guided strategy autotuner (DESIGN.md §8).

Closes the loop between Piper's strategy language and its performance
models: enumerate directive compositions, score them on the timeline
simulator + cost model, reject over-budget candidates, cache the winner.

    from repro.configs import get_config
    from repro import tune

    plan = tune.search(get_config("qwen3-1b"),
                       tune.MeshSpec(pp=4, dp=2),
                       budget=16 * 2**30)
    print(plan.summary())
    directives = plan.directives()   # feed to compile_training
"""
from .cache import PlanCache, fingerprint
from .measured import (CalibrationResult, MeasuredCell, calibrate,
                       materialize_params, measure_program, synth_batch)
from .proxy import (build_candidate_program, build_strategy_program,
                    candidate_directives, candidate_strategy, decompose,
                    make_chunk_cost)
from .rebalance import rebalance_microbatches
from .search import (DEFAULT_TOKENS, NoFeasiblePlanError, Plan, Score,
                     score_candidate, score_strategy, search)
from .space import (REMAT_POLICIES, SCHEDULE_KINDS, Candidate, MeshSpec,
                    SearchSpace, baseline_candidate)

__all__ = [
    "REMAT_POLICIES", "SCHEDULE_KINDS", "DEFAULT_TOKENS",
    "CalibrationResult", "Candidate", "MeasuredCell", "MeshSpec",
    "NoFeasiblePlanError", "Plan", "PlanCache", "Score", "SearchSpace",
    "baseline_candidate", "build_candidate_program",
    "build_strategy_program", "calibrate", "candidate_directives",
    "candidate_strategy", "decompose", "fingerprint", "make_chunk_cost",
    "materialize_params", "measure_program", "rebalance_microbatches",
    "score_candidate", "score_strategy", "search", "synth_batch",
]
