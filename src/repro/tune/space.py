"""Search space for the strategy autotuner (DESIGN.md §8).

A ``Candidate`` is one point in the strategy space Piper's directives
span — and a *thin constructor over* ``core.strategy.Strategy``: the
compiled artifact, the serialized plan, and the cache entry are all the
Strategy that ``Candidate.to_strategy`` builds; the tuple form exists
only so ``SearchSpace.candidates`` can enumerate the feasible points
for a given config + mesh in a deterministic order (the tuner's
tie-break is "first enumerated wins", so this order is part of the
plan-cache contract).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Iterator, Optional

from ..core.strategy import (REMAT_POLICIES, SCHEDULE_KINDS,
                             ExpertParallel, Mesh, Overlap, Pipeline,
                             Remat, Strategy, StrategyError, ZeRO)

__all__ = ["REMAT_POLICIES", "SCHEDULE_KINDS", "Candidate", "MeshSpec",
           "SearchSpace", "baseline_candidate"]


@dataclass(frozen=True)
class MeshSpec:
    """Logical device mesh for the tuner: ``pp`` pipeline ranks, each
    rank a group of ``dp`` data-parallel replicas.  A thin (pp, dp) view
    over the named-axis ``core.strategy.Mesh`` — device numbering and
    group derivation live there (rank-major)."""
    pp: int
    dp: int = 1

    def mesh(self) -> Mesh:
        return Mesh(pp=self.pp, dp=self.dp)

    @property
    def n_devices(self) -> int:
        return self.pp * self.dp

    @property
    def n_stages(self) -> int:
        # every schedule kind runs the same 2R-stage model so makespans
        # are apples-to-apples (1f1b/gpipe place 2 consecutive stages
        # per rank; interleaved/dualpipev use virtual stages)
        return 2 * self.pp

    def device_groups(self) -> list:
        return self.mesh().device_groups("pp")

    @staticmethod
    def from_mesh(mesh: Mesh) -> "MeshSpec":
        extra = [n for n in mesh.axis_names if n not in ("pp", "dp")]
        if extra:
            raise StrategyError(
                f"the tuner's MeshSpec only models (pp, dp) meshes; "
                f"{mesh!r} has extra axes {extra}")
        return MeshSpec(pp=mesh.axis_size("pp", 1),
                        dp=mesh.axis_size("dp", 1))


@dataclass(frozen=True)
class Candidate:
    kind: str            # one of SCHEDULE_KINDS
    n_mb: int            # microbatch count (Split directive)
    zero: int = 0        # ZeRO stage of Replicate (0 = no DP groups)
    ep: int = 1          # expert-parallel degree (1 = replicate experts)
    # overlap-engine axes (core/overlap.py).  prefetch = 0 keeps the
    # legacy plan (no engine: just-in-time gathers, optimistic
    # simulation); prefetch >= 1 runs the engine with that lookahead
    # depth, and bucket_mb is the fused-collective budget in MiB
    # (0 = no fusion).
    prefetch: int = 0
    bucket_mb: int = 0
    # activation-residual policy (core/passes.apply_remat): "full" is
    # the historical per-chunk rematerialization; "none" stashes the vjp
    # residuals (less backward compute, more activation memory);
    # "selective" alternates per chunk
    remat: str = "full"

    def label(self) -> str:
        return (f"{self.kind}/mb{self.n_mb}"
                + (f"/zero{self.zero}" if self.zero else "")
                + (f"/ep{self.ep}" if self.ep > 1 else "")
                + (f"/pf{self.prefetch}" if self.prefetch else "")
                + (f"/bkt{self.bucket_mb}M" if self.bucket_mb else "")
                + (f"/rm-{self.remat}" if self.remat != "full" else ""))

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "Candidate":
        return Candidate(kind=d["kind"], n_mb=int(d["n_mb"]),
                         zero=int(d.get("zero", 0)), ep=int(d.get("ep", 1)),
                         prefetch=int(d.get("prefetch", 0)),
                         bucket_mb=int(d.get("bucket_mb", 0)),
                         remat=str(d.get("remat", "full")))

    # -- the Strategy bridge: Candidate is a constructor over Strategy --
    def to_strategy(self, mesh) -> Strategy:
        """The declarative strategy this candidate denotes on ``mesh``
        (a ``MeshSpec`` or named-axis ``Mesh``).  This is what the plan
        cache stores and what ``compile_training(strategy=...)``
        consumes — the candidate tuple is just its enumeration key."""
        m = mesh.mesh() if isinstance(mesh, MeshSpec) else mesh
        frags = [Pipeline(self.kind, n_mb=self.n_mb)]
        if m.axis_size("dp", 1) > 1:
            frags.append(ZeRO(stage=self.zero))
        if self.ep > 1:
            frags.append(ExpertParallel())
        if self.prefetch > 0:
            frags.append(Overlap(prefetch=self.prefetch,
                                 bucket_mb=self.bucket_mb))
        if self.remat != "full":
            frags.append(Remat(policy=self.remat))
        return Strategy(m, tuple(frags))

    @staticmethod
    def from_strategy(strategy: Strategy) -> "Candidate":
        """Project a structured Strategy back onto the search-space
        axes (the inverse of ``to_strategy`` for tuner-shaped
        strategies)."""
        pipe = strategy.pipeline
        if pipe is None:
            raise StrategyError(
                "cannot derive a tuner Candidate from a strategy with "
                "no Pipeline fragment")
        zero, ep, ov, rm = (strategy.zero, strategy.expert_parallel,
                            strategy.overlap, strategy.remat)
        return Candidate(
            kind=pipe.schedule, n_mb=pipe.n_mb,
            zero=zero.stage if zero else 0,
            ep=(ep.degree or strategy.mesh[ep.axis]) if ep else 1,
            prefetch=ov.prefetch if ov and ov.enabled else 0,
            bucket_mb=ov.bucket_mb if ov and ov.enabled else 0,
            remat=rm.policy if rm else "full")


@dataclass(frozen=True)
class SearchSpace:
    """Which strategy dimensions to sweep.  ``mb_multipliers`` are
    multiples of the PP degree (n_mb = mult * pp); ZeRO and EP axes only
    open up when the mesh has DP groups / the config has experts."""
    kinds: tuple = SCHEDULE_KINDS
    mb_multipliers: tuple = (2, 4)
    zero_stages: tuple = (1, 3)
    ep_degrees: Optional[tuple] = None   # None -> {1, dp}
    # overlap-engine axes, searched only for ZeRO-3 candidates (the
    # stage with param all-gathers to hide): gather lookahead depth and
    # fused-collective budget in MiB
    prefetch_depths: tuple = (1, 4)
    bucket_mbs: tuple = (0, 16)
    # activation-residual policies; the default keeps the sweep small —
    # open the axis with ("full", "none") or the full three-point set
    # when tuning under --memory-budget
    remat_policies: tuple = ("full",)

    def candidates(self, config, mesh: MeshSpec,
                   tokens: int) -> Iterator[Candidate]:
        has_experts = getattr(config, "moe", None) is not None
        zeros = self.zero_stages if mesh.dp > 1 else (0,)
        if self.ep_degrees is not None:
            eps = self.ep_degrees
        elif has_experts and mesh.dp > 1:
            # the Shard directive requires expert placement to match the
            # neighbouring chunks' device group, so EP is either off
            # (experts replicate with the stage) or the full DP group
            eps = (1, mesh.dp)
        else:
            eps = (1,)
        for rm in self.remat_policies:
            if rm not in REMAT_POLICIES:
                raise StrategyError(
                    f"unknown remat policy {rm!r} in search space "
                    f"(choose from {REMAT_POLICIES})")
        for kind in self.kinds:
            for mult in sorted(set(self.mb_multipliers)):
                n_mb = mult * mesh.pp
                if tokens % n_mb:
                    continue
                if (tokens // n_mb) % max(mesh.dp, 1):
                    continue
                for zero in zeros:
                    for ep in eps:
                        if zero >= 3:
                            pts = [(pf, bk)
                                   for pf in sorted(set(
                                       self.prefetch_depths))
                                   for bk in sorted(set(self.bucket_mbs))]
                        else:
                            pts = [(0, 0)]
                        for (pf, bk) in pts:
                            for rm in self.remat_policies:
                                yield Candidate(kind=kind, n_mb=n_mb,
                                                zero=zero, ep=ep,
                                                prefetch=pf, bucket_mb=bk,
                                                remat=rm)

    def to_dict(self) -> dict:
        return {"kinds": list(self.kinds),
                "mb_multipliers": list(self.mb_multipliers),
                "zero_stages": list(self.zero_stages),
                "ep_degrees": (list(self.ep_degrees)
                               if self.ep_degrees is not None else None),
                "prefetch_depths": list(self.prefetch_depths),
                "bucket_mbs": list(self.bucket_mbs),
                "remat_policies": list(self.remat_policies)}


def baseline_candidate(config, mesh: MeshSpec) -> Candidate:
    """The hand-written default the tuner must beat: canonical 1F1B with
    2·R microbatches, plain DP (ZeRO-1) and no expert parallelism."""
    return Candidate(kind="1f1b", n_mb=2 * mesh.pp,
                     zero=1 if mesh.dp > 1 else 0, ep=1)
