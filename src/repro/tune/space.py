"""Search space for the strategy autotuner (DESIGN.md §8).

A ``Candidate`` is one point in the strategy space Piper's directives
span: a pipeline schedule kind (the five builders in
``core/schedules.py``), a microbatch count, a ZeRO stage for the
``Replicate`` directive, and an expert-parallel degree for MoE configs.
``SearchSpace.candidates`` enumerates the feasible points for a given
config + mesh in a deterministic order (the tuner's tie-break is "first
enumerated wins", so this order is part of the plan-cache contract).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Iterator, Optional

SCHEDULE_KINDS = ("gpipe", "1f1b", "zb1f1b", "interleaved_1f1b",
                  "dualpipev")


@dataclass(frozen=True)
class MeshSpec:
    """Logical device mesh for the tuner: ``pp`` pipeline ranks, each
    rank a group of ``dp`` data-parallel replicas (devices are numbered
    rank-major, as in the schedule benches)."""
    pp: int
    dp: int = 1

    @property
    def n_devices(self) -> int:
        return self.pp * self.dp

    @property
    def n_stages(self) -> int:
        # every schedule kind runs the same 2R-stage model so makespans
        # are apples-to-apples (1f1b/gpipe place 2 consecutive stages
        # per rank; interleaved/dualpipev use virtual stages)
        return 2 * self.pp

    def device_groups(self) -> list:
        return [[r * self.dp + i for i in range(self.dp)]
                for r in range(self.pp)]


@dataclass(frozen=True)
class Candidate:
    kind: str            # one of SCHEDULE_KINDS
    n_mb: int            # microbatch count (Split directive)
    zero: int = 0        # ZeRO stage of Replicate (0 = no DP groups)
    ep: int = 1          # expert-parallel degree (1 = replicate experts)
    # overlap-engine axes (core/overlap.py).  prefetch = 0 keeps the
    # legacy plan (no engine: just-in-time gathers, optimistic
    # simulation); prefetch >= 1 runs the engine with that lookahead
    # depth, and bucket_mb is the fused-collective budget in MiB
    # (0 = no fusion).
    prefetch: int = 0
    bucket_mb: int = 0

    def label(self) -> str:
        return (f"{self.kind}/mb{self.n_mb}"
                + (f"/zero{self.zero}" if self.zero else "")
                + (f"/ep{self.ep}" if self.ep > 1 else "")
                + (f"/pf{self.prefetch}" if self.prefetch else "")
                + (f"/bkt{self.bucket_mb}M" if self.bucket_mb else ""))

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "Candidate":
        return Candidate(kind=d["kind"], n_mb=int(d["n_mb"]),
                         zero=int(d.get("zero", 0)), ep=int(d.get("ep", 1)),
                         prefetch=int(d.get("prefetch", 0)),
                         bucket_mb=int(d.get("bucket_mb", 0)))


@dataclass(frozen=True)
class SearchSpace:
    """Which strategy dimensions to sweep.  ``mb_multipliers`` are
    multiples of the PP degree (n_mb = mult * pp); ZeRO and EP axes only
    open up when the mesh has DP groups / the config has experts."""
    kinds: tuple = SCHEDULE_KINDS
    mb_multipliers: tuple = (2, 4)
    zero_stages: tuple = (1, 3)
    ep_degrees: Optional[tuple] = None   # None -> {1, dp}
    # overlap-engine axes, searched only for ZeRO-3 candidates (the
    # stage with param all-gathers to hide): gather lookahead depth and
    # fused-collective budget in MiB
    prefetch_depths: tuple = (1, 4)
    bucket_mbs: tuple = (0, 16)

    def candidates(self, config, mesh: MeshSpec,
                   tokens: int) -> Iterator[Candidate]:
        has_experts = getattr(config, "moe", None) is not None
        zeros = self.zero_stages if mesh.dp > 1 else (0,)
        if self.ep_degrees is not None:
            eps = self.ep_degrees
        elif has_experts and mesh.dp > 1:
            # the Shard directive requires expert placement to match the
            # neighbouring chunks' device group, so EP is either off
            # (experts replicate with the stage) or the full DP group
            eps = (1, mesh.dp)
        else:
            eps = (1,)
        for kind in self.kinds:
            for mult in sorted(set(self.mb_multipliers)):
                n_mb = mult * mesh.pp
                if tokens % n_mb:
                    continue
                if (tokens // n_mb) % max(mesh.dp, 1):
                    continue
                for zero in zeros:
                    for ep in eps:
                        if zero >= 3:
                            pts = [(pf, bk)
                                   for pf in sorted(set(
                                       self.prefetch_depths))
                                   for bk in sorted(set(self.bucket_mbs))]
                        else:
                            pts = [(0, 0)]
                        for (pf, bk) in pts:
                            yield Candidate(kind=kind, n_mb=n_mb,
                                            zero=zero, ep=ep,
                                            prefetch=pf, bucket_mb=bk)

    def to_dict(self) -> dict:
        return {"kinds": list(self.kinds),
                "mb_multipliers": list(self.mb_multipliers),
                "zero_stages": list(self.zero_stages),
                "ep_degrees": (list(self.ep_degrees)
                               if self.ep_degrees is not None else None),
                "prefetch_depths": list(self.prefetch_depths),
                "bucket_mbs": list(self.bucket_mbs)}


def baseline_candidate(config, mesh: MeshSpec) -> Candidate:
    """The hand-written default the tuner must beat: canonical 1F1B with
    2·R microbatches, plain DP (ZeRO-1) and no expert parallelism."""
    return Candidate(kind="1f1b", n_mb=2 * mesh.pp,
                     zero=1 if mesh.dp > 1 else 0, ep=1)
