"""Straggler-aware microbatch rebalancing.

The ft watchdog's per-rank slowdown EMAs (``StragglerWatchdog.
slowdowns()``: EMA / fleet-median, 1.0 = on-pace) feed this hook; the
tuner turns them into a per-replica microbatch share so a persistently
slow data-parallel replica gets less work instead of gating every
pipeline flush.
"""
from __future__ import annotations


def rebalance_microbatches(n_mb: int, slowdowns: dict[int, float], *,
                           threshold: float = 1.25) -> dict[int, int]:
    """Split ``n_mb`` microbatches across the ranks in ``slowdowns``
    proportionally to their speed.

    Greedy water-filling: each microbatch goes to the rank whose
    *marginal* finish time ``(count + 1) * slowdown`` is lowest (ties to
    the lowest rank id), which minimizes the makespan for unit-cost
    microbatches.  Every rank is guaranteed at least 0 — a rank slow
    enough to deserve nothing gets nothing.

    Uniform guard: when the spread ``max/min`` of the slowdowns is
    within ``threshold``, the trace is considered uniform noise and the
    split is exactly uniform (remainder to the fastest, then lowest
    rank id) — no-false-positive on a healthy fleet.
    """
    if n_mb < 0:
        raise ValueError(f"n_mb must be >= 0, got {n_mb}")
    ranks = sorted(slowdowns)
    if not ranks:
        raise ValueError("rebalance_microbatches needs at least one rank")
    slow = {r: float(slowdowns[r]) for r in ranks}
    if any(v <= 0 for v in slow.values()):
        raise ValueError(f"slowdowns must be positive: {slow}")

    if max(slow.values()) / min(slow.values()) <= threshold:
        base, rem = divmod(n_mb, len(ranks))
        counts = {r: base for r in ranks}
        for r in sorted(ranks, key=lambda r: (slow[r], r))[:rem]:
            counts[r] += 1
        return counts

    counts = {r: 0 for r in ranks}
    for _ in range(n_mb):
        r = min(ranks, key=lambda r: ((counts[r] + 1) * slow[r], r))
        counts[r] += 1
    return counts


__all__ = ["rebalance_microbatches"]
