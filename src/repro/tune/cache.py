"""JSON plan cache: repeated ``tune.search`` launches skip the sweep.

Keyed by a fingerprint of everything that determines the result —
config fields, the canonical mesh/strategy-space JSON, memory budget,
token count, and the cost-model constants — so a stale plan can never
be served for changed inputs.  One file per key under the cache
directory (default ``~/.cache/repro-tune``, override with
``$REPRO_TUNE_CACHE`` or the ``cache_dir`` argument).

Stored entries carry strategies (``core.strategy`` JSON documents), not
candidate field tuples.  Two version gates apply:

- ``CACHE_VERSION`` — part of the fingerprint AND checked on read: bump
  it whenever the *scoring semantics* change (proxy decomposition,
  chunk cost formula, peak-memory estimator rules), since those are not
  visible in the fingerprinted inputs but invalidate every prediction.
- ``strategy.SCHEMA_VERSION`` — also fingerprinted and checked on read:
  an entry written under a different strategy schema is ignored with a
  logged warning (its stored plan would not deserialize faithfully).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import logging
import os
import pathlib
from typing import Any, Optional

from ..core.strategy import SCHEMA_VERSION as STRATEGY_SCHEMA_VERSION

log = logging.getLogger(__name__)

CACHE_VERSION = 3  # v3: entries store Strategy JSON, not Candidate tuples


def _jsonable(obj: Any) -> Any:
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return _jsonable(dataclasses.asdict(obj))
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in sorted(obj.items())}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return repr(obj)


def fingerprint(**parts: Any) -> str:
    blob = json.dumps({"version": CACHE_VERSION,
                       "strategy_schema": STRATEGY_SCHEMA_VERSION,
                       **_jsonable(parts)},
                      sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:32]


class PlanCache:
    def __init__(self, cache_dir: Optional[str] = None) -> None:
        self.dir = pathlib.Path(
            cache_dir
            or os.environ.get("REPRO_TUNE_CACHE")
            or pathlib.Path.home() / ".cache" / "repro-tune")

    def _path(self, key: str) -> pathlib.Path:
        return self.dir / f"{key}.json"

    def get(self, key: str) -> Optional[dict]:
        p = self._path(key)
        if not p.exists():
            return None
        try:
            data = json.loads(p.read_text())
        except (OSError, json.JSONDecodeError):
            return None
        if data.get("cache_version") != CACHE_VERSION:
            return None
        if data.get("strategy_schema") != STRATEGY_SCHEMA_VERSION:
            log.warning(
                "ignoring stale plan-cache entry %s: strategy schema %r "
                "!= current %r (re-searching)", p.name,
                data.get("strategy_schema"), STRATEGY_SCHEMA_VERSION)
            return None
        return data

    def put(self, key: str, value: dict) -> pathlib.Path:
        self.dir.mkdir(parents=True, exist_ok=True)
        p = self._path(key)
        tmp = p.with_suffix(".tmp")
        tmp.write_text(json.dumps(
            {"cache_version": CACHE_VERSION,
             "strategy_schema": STRATEGY_SCHEMA_VERSION,
             **value}, indent=1, sort_keys=True))
        tmp.replace(p)
        return p

    def clear(self) -> int:
        n = 0
        if self.dir.exists():
            for p in self.dir.glob("*.json"):
                p.unlink()
                n += 1
        return n
