"""Simulator-guided strategy search (DESIGN.md §8).

``search(config, mesh, budget)`` closes the loop the paper leaves to the
user: it enumerates directive compositions (schedule × microbatches ×
ZeRO × EP), scores every candidate on the timeline simulator with the
analytic cost model, rejects candidates whose estimated per-device peak
memory exceeds the budget, and returns the fastest feasible ``Plan``.
Results are cached as JSON keyed by (config, mesh, budget, space, cost)
so repeated launches skip the sweep.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

from ..core.strategy import Strategy
from ..runtime.costmodel import CostModel
from ..runtime.memory import timeline_peak_bytes
from ..runtime.simulator import TimelineSimulator
from .cache import PlanCache, fingerprint
from .proxy import (build_candidate_program, build_strategy_program,
                    candidate_directives, decompose, make_chunk_cost)
from .space import Candidate, MeshSpec, SearchSpace, baseline_candidate

# default global batch: 128k tokens per step (divisible by every mb/dp
# combination the default space enumerates)
DEFAULT_TOKENS = 131072


class NoFeasiblePlanError(RuntimeError):
    """Every candidate exceeded the per-device memory budget."""


@dataclass(frozen=True)
class Score:
    candidate: Candidate
    step_seconds: float        # simulator-predicted step time
    peak_bytes: int            # max over devices, estimated
    feasible: bool

    def to_dict(self, mesh: Optional[MeshSpec] = None) -> dict:
        """With ``mesh``, serialize the candidate as its canonical
        Strategy document (what the plan cache stores); without, fall
        back to the bare candidate axes."""
        cand = (self.candidate.to_strategy(mesh).to_dict() if mesh
                else self.candidate.to_dict())
        key = "strategy" if mesh else "candidate"
        return {key: cand,
                "step_seconds": self.step_seconds,
                "peak_bytes": self.peak_bytes,
                "feasible": self.feasible}

    @staticmethod
    def from_dict(d: dict) -> "Score":
        if "strategy" in d:
            cand = Candidate.from_strategy(
                Strategy.from_dict(d["strategy"]))
        else:
            cand = Candidate.from_dict(d["candidate"])
        return Score(candidate=cand,
                     step_seconds=float(d["step_seconds"]),
                     peak_bytes=int(d["peak_bytes"]),
                     feasible=bool(d["feasible"]))


@dataclass
class Plan:
    """The autotuner's output: the winning strategy plus enough metadata
    to reproduce the decision (and to rebuild the directive list)."""
    config_name: str
    mesh: MeshSpec
    tokens: int
    budget_bytes: Optional[int]
    candidate: Candidate
    predicted_step_seconds: float
    predicted_peak_bytes: int
    baseline: Score
    leaderboard: list = field(default_factory=list)   # top Scores
    n_evaluated: int = 0
    n_rejected: int = 0
    from_cache: bool = False
    _config: object = field(default=None, repr=False, compare=False)

    def speedup_vs_baseline(self) -> float:
        return self.baseline.step_seconds / self.predicted_step_seconds

    def strategy(self) -> Strategy:
        """The winning strategy as a declarative, serializable
        ``core.strategy.Strategy`` — feed it straight to
        ``compile_training(strategy=...)`` or write ``.to_json()`` to a
        file for ``launch/train.py --strategy``."""
        return self.candidate.to_strategy(self.mesh)

    def directives(self, config=None) -> list:
        """Re-emit the winning Piper directive list (Place/Replicate/
        Shard/Split/Order) — the winning ``strategy()`` lowered against
        the config's stage decomposition.  The Overlap fragment is NOT
        directives; prefer ``compile_training(strategy=
        plan.strategy())`` which applies both."""
        cfg = config if config is not None else self._config
        if cfg is None:
            raise ValueError("pass the ArchConfig to rebuild directives "
                             "from a deserialized Plan")
        sm = decompose(cfg, self.mesh.n_stages)
        return candidate_directives(cfg, self.mesh, self.candidate, sm)

    def summary(self) -> str:
        gb = self.predicted_peak_bytes / 2**30
        lines = [
            f"plan[{self.config_name}] pp={self.mesh.pp} dp={self.mesh.dp}"
            f" tokens={self.tokens}"
            + (" (cached)" if self.from_cache else ""),
            f"  winner   : {self.candidate.label()}  "
            f"step={self.predicted_step_seconds*1e3:.2f}ms  peak={gb:.2f}GiB",
            f"  baseline : {self.baseline.candidate.label()}  "
            f"step={self.baseline.step_seconds*1e3:.2f}ms  "
            f"(speedup {self.speedup_vs_baseline():.3f}x)",
            f"  searched : {self.n_evaluated} candidates, "
            f"{self.n_rejected} over budget",
        ]
        return "\n".join(lines)

    def to_dict(self) -> dict:
        return {
            "config_name": self.config_name,
            "mesh": self.mesh.mesh().to_dict(),
            "tokens": self.tokens,
            "budget_bytes": self.budget_bytes,
            "strategy": self.strategy().to_dict(),
            "predicted_step_seconds": self.predicted_step_seconds,
            "predicted_peak_bytes": self.predicted_peak_bytes,
            "baseline": self.baseline.to_dict(self.mesh),
            "leaderboard": [s.to_dict(self.mesh)
                            for s in self.leaderboard],
            "n_evaluated": self.n_evaluated,
            "n_rejected": self.n_rejected,
        }

    @staticmethod
    def from_dict(d: dict, *, from_cache: bool = False,
                  config=None) -> "Plan":
        if "mesh" in d and "axes" in d["mesh"]:
            from ..core.strategy import Mesh
            mesh = MeshSpec.from_mesh(Mesh.from_dict(d["mesh"]))
        else:   # pre-schema dicts (not served from cache: version-gated)
            mesh = MeshSpec(pp=int(d["mesh"]["pp"]),
                            dp=int(d["mesh"]["dp"]))
        if "strategy" in d:
            cand = Candidate.from_strategy(Strategy.from_dict(
                d["strategy"]))
        else:
            cand = Candidate.from_dict(d["candidate"])
        return Plan(
            config_name=d["config_name"],
            mesh=mesh,
            tokens=int(d["tokens"]),
            budget_bytes=(int(d["budget_bytes"])
                          if d.get("budget_bytes") is not None else None),
            candidate=cand,
            predicted_step_seconds=float(d["predicted_step_seconds"]),
            predicted_peak_bytes=int(d["predicted_peak_bytes"]),
            baseline=Score.from_dict(d["baseline"]),
            leaderboard=[Score.from_dict(s)
                         for s in d.get("leaderboard", [])],
            n_evaluated=int(d.get("n_evaluated", 0)),
            n_rejected=int(d.get("n_rejected", 0)),
            from_cache=from_cache,
            _config=config,
        )


# ---------------------------------------------------------------------------
# scoring
# ---------------------------------------------------------------------------

def score_candidate(config, mesh: MeshSpec, cand: Candidate, *,
                    tokens: int = DEFAULT_TOKENS,
                    budget_bytes: Optional[int] = None,
                    cost: Optional[CostModel] = None,
                    use_xla_cost: bool = False) -> Score:
    """Compile the candidate's proxy program and predict (step time,
    peak memory).  ``use_xla_cost=True`` swaps the analytic chunk
    roofline for XLA's own ``cost_analysis`` of the proxy exec functions
    (slower; used by bench_autotune's predicted-vs-measured column)."""
    cost = cost or CostModel()
    prog, sm = build_candidate_program(config, mesh, cand, tokens)
    override = (None if use_xla_cost
                else make_chunk_cost(sm, tokens, cand.n_mb, cost))
    sim = TimelineSimulator(prog, cost, chunk_seconds_override=override)
    res = sim.run()
    peaks = timeline_peak_bytes(prog, res.records)
    peak = max(peaks.values())
    feasible = budget_bytes is None or peak <= budget_bytes
    return Score(candidate=cand, step_seconds=res.makespan,
                 peak_bytes=peak, feasible=feasible)


def score_strategy(config, strategy: Strategy, *,
                   tokens: int = DEFAULT_TOKENS,
                   budget_bytes: Optional[int] = None,
                   cost: Optional[CostModel] = None,
                   program=None) -> Score:
    """Score a declarative ``Strategy`` (e.g. one replayed from JSON by
    ``launch/train.py --strategy``) on the timeline simulator with the
    analytic chunk roofline.  ``program`` takes an already-compiled
    ``(CompiledProgram, StageModel)`` pair to avoid recompiling when the
    caller also needs the program."""
    cost = cost or CostModel()
    prog, sm = (program if program is not None
                else build_strategy_program(config, strategy, tokens))
    pipe = strategy.pipeline
    override = make_chunk_cost(sm, tokens, pipe.n_mb, cost)
    res = TimelineSimulator(prog, cost,
                            chunk_seconds_override=override).run()
    peaks = timeline_peak_bytes(prog, res.records)
    peak = max(peaks.values())
    return Score(candidate=Candidate.from_strategy(strategy),
                 step_seconds=res.makespan, peak_bytes=peak,
                 feasible=budget_bytes is None or peak <= budget_bytes)


# ---------------------------------------------------------------------------
# search
# ---------------------------------------------------------------------------

def search(config, mesh: MeshSpec, budget: Optional[float] = None, *,
           tokens: int = DEFAULT_TOKENS,
           space: Optional[SearchSpace] = None,
           cost: Optional[CostModel] = None,
           cache_dir: Optional[str] = None,
           use_cache: bool = True,
           top_k: int = 5,
           progress: Optional[Callable[[Score], None]] = None) -> Plan:
    """Pick the fastest feasible strategy for ``config`` on ``mesh``.

    config : ArchConfig (from ``repro.configs.get_config``)
    mesh   : MeshSpec(pp, dp)
    budget : per-device memory budget in bytes (None = unlimited)
    tokens : global batch size in tokens per step

    Returns a ``Plan``; raises ``NoFeasiblePlanError`` when every
    candidate exceeds the budget.  Identical inputs are served from the
    JSON plan cache (``plan.from_cache`` is True)."""
    space = space or SearchSpace()
    cost = cost or CostModel()
    budget_bytes = int(budget) if budget is not None else None

    cache = PlanCache(cache_dir) if use_cache else None
    # keyed on the canonical strategy-layer JSON forms (mesh axes doc,
    # space dict), never on Candidate field tuples
    key = fingerprint(config=config, mesh=mesh.mesh().to_dict(),
                      budget=budget_bytes, tokens=tokens,
                      space=space.to_dict(), cost=cost)
    if cache is not None:
        hit = cache.get(key)
        if hit is not None:
            return Plan.from_dict(hit, from_cache=True, config=config)

    base = score_candidate(config, mesh, baseline_candidate(config, mesh),
                           tokens=tokens, budget_bytes=budget_bytes,
                           cost=cost)
    scores: list[Score] = []
    seen = set()
    for cand in space.candidates(config, mesh, tokens):
        if cand in seen:
            continue
        seen.add(cand)
        s = (base if cand == base.candidate else
             score_candidate(config, mesh, cand, tokens=tokens,
                             budget_bytes=budget_bytes, cost=cost))
        scores.append(s)
        if progress is not None:
            progress(s)

    if not scores:
        raise NoFeasiblePlanError(
            f"search space is empty for {config.name} on pp={mesh.pp} "
            f"dp={mesh.dp}: no candidate microbatch count divides "
            f"tokens={tokens} evenly across dp={mesh.dp} (try a tokens "
            f"value divisible by {4 * mesh.pp * max(mesh.dp, 1)})")
    feasible = [s for s in scores if s.feasible]
    if not feasible:
        mn = min(scores, key=lambda s: s.peak_bytes) if scores else None
        raise NoFeasiblePlanError(
            f"no candidate fits {budget_bytes} bytes/device for "
            f"{config.name} on pp={mesh.pp} dp={mesh.dp}"
            + (f" (smallest footprint: {mn.candidate.label()} at "
               f"{mn.peak_bytes} bytes)" if mn else ""))
    # deterministic: ties break by enumeration order (stable sort)
    ranked = sorted(feasible, key=lambda s: (s.step_seconds, s.peak_bytes))
    best = ranked[0]
    plan = Plan(
        config_name=config.name, mesh=mesh, tokens=tokens,
        budget_bytes=budget_bytes, candidate=best.candidate,
        predicted_step_seconds=best.step_seconds,
        predicted_peak_bytes=best.peak_bytes,
        baseline=base, leaderboard=ranked[:top_k],
        n_evaluated=len(scores),
        n_rejected=len(scores) - len(feasible),
        _config=config,
    )
    if cache is not None:
        cache.put(key, plan.to_dict())
    return plan
