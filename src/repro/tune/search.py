"""Simulator-guided strategy search (DESIGN.md §8).

``search(config, mesh, budget)`` closes the loop the paper leaves to the
user: it enumerates directive compositions (schedule × microbatches ×
ZeRO × EP), scores every candidate on the timeline simulator with the
analytic cost model, rejects candidates whose estimated per-device peak
memory exceeds the budget, and returns the fastest feasible ``Plan``.
Results are cached as JSON keyed by (config, mesh, budget, space, cost)
so repeated launches skip the sweep.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

from ..runtime.costmodel import CostModel
from ..runtime.memory import timeline_peak_bytes
from ..runtime.simulator import TimelineSimulator
from .cache import PlanCache, fingerprint
from .proxy import (build_candidate_program, candidate_directives,
                    decompose, make_chunk_cost)
from .space import Candidate, MeshSpec, SearchSpace, baseline_candidate

# default global batch: 128k tokens per step (divisible by every mb/dp
# combination the default space enumerates)
DEFAULT_TOKENS = 131072


class NoFeasiblePlanError(RuntimeError):
    """Every candidate exceeded the per-device memory budget."""


@dataclass(frozen=True)
class Score:
    candidate: Candidate
    step_seconds: float        # simulator-predicted step time
    peak_bytes: int            # max over devices, estimated
    feasible: bool

    def to_dict(self) -> dict:
        return {"candidate": self.candidate.to_dict(),
                "step_seconds": self.step_seconds,
                "peak_bytes": self.peak_bytes,
                "feasible": self.feasible}

    @staticmethod
    def from_dict(d: dict) -> "Score":
        return Score(candidate=Candidate.from_dict(d["candidate"]),
                     step_seconds=float(d["step_seconds"]),
                     peak_bytes=int(d["peak_bytes"]),
                     feasible=bool(d["feasible"]))


@dataclass
class Plan:
    """The autotuner's output: the winning strategy plus enough metadata
    to reproduce the decision (and to rebuild the directive list)."""
    config_name: str
    mesh: MeshSpec
    tokens: int
    budget_bytes: Optional[int]
    candidate: Candidate
    predicted_step_seconds: float
    predicted_peak_bytes: int
    baseline: Score
    leaderboard: list = field(default_factory=list)   # top Scores
    n_evaluated: int = 0
    n_rejected: int = 0
    from_cache: bool = False
    _config: object = field(default=None, repr=False, compare=False)

    def speedup_vs_baseline(self) -> float:
        return self.baseline.step_seconds / self.predicted_step_seconds

    def directives(self, config=None) -> list:
        """Re-emit the winning Piper directive list (Place/Replicate/
        Shard/Split/Order) — deterministic given the candidate.  The
        candidate's overlap axes are NOT directives: pass
        ``proxy.candidate_overlap(plan.candidate)`` as
        ``compile_training(..., overlap=...)`` to re-apply the overlap
        engine the winner was scored with."""
        cfg = config if config is not None else self._config
        if cfg is None:
            raise ValueError("pass the ArchConfig to rebuild directives "
                             "from a deserialized Plan")
        sm = decompose(cfg, self.mesh.n_stages)
        return candidate_directives(cfg, self.mesh, self.candidate, sm)

    def summary(self) -> str:
        gb = self.predicted_peak_bytes / 2**30
        lines = [
            f"plan[{self.config_name}] pp={self.mesh.pp} dp={self.mesh.dp}"
            f" tokens={self.tokens}"
            + (" (cached)" if self.from_cache else ""),
            f"  winner   : {self.candidate.label()}  "
            f"step={self.predicted_step_seconds*1e3:.2f}ms  peak={gb:.2f}GiB",
            f"  baseline : {self.baseline.candidate.label()}  "
            f"step={self.baseline.step_seconds*1e3:.2f}ms  "
            f"(speedup {self.speedup_vs_baseline():.3f}x)",
            f"  searched : {self.n_evaluated} candidates, "
            f"{self.n_rejected} over budget",
        ]
        return "\n".join(lines)

    def to_dict(self) -> dict:
        return {
            "config_name": self.config_name,
            "mesh": {"pp": self.mesh.pp, "dp": self.mesh.dp},
            "tokens": self.tokens,
            "budget_bytes": self.budget_bytes,
            "candidate": self.candidate.to_dict(),
            "predicted_step_seconds": self.predicted_step_seconds,
            "predicted_peak_bytes": self.predicted_peak_bytes,
            "baseline": self.baseline.to_dict(),
            "leaderboard": [s.to_dict() for s in self.leaderboard],
            "n_evaluated": self.n_evaluated,
            "n_rejected": self.n_rejected,
        }

    @staticmethod
    def from_dict(d: dict, *, from_cache: bool = False,
                  config=None) -> "Plan":
        return Plan(
            config_name=d["config_name"],
            mesh=MeshSpec(pp=int(d["mesh"]["pp"]), dp=int(d["mesh"]["dp"])),
            tokens=int(d["tokens"]),
            budget_bytes=(int(d["budget_bytes"])
                          if d.get("budget_bytes") is not None else None),
            candidate=Candidate.from_dict(d["candidate"]),
            predicted_step_seconds=float(d["predicted_step_seconds"]),
            predicted_peak_bytes=int(d["predicted_peak_bytes"]),
            baseline=Score.from_dict(d["baseline"]),
            leaderboard=[Score.from_dict(s)
                         for s in d.get("leaderboard", [])],
            n_evaluated=int(d.get("n_evaluated", 0)),
            n_rejected=int(d.get("n_rejected", 0)),
            from_cache=from_cache,
            _config=config,
        )


# ---------------------------------------------------------------------------
# scoring
# ---------------------------------------------------------------------------

def score_candidate(config, mesh: MeshSpec, cand: Candidate, *,
                    tokens: int = DEFAULT_TOKENS,
                    budget_bytes: Optional[int] = None,
                    cost: Optional[CostModel] = None,
                    use_xla_cost: bool = False) -> Score:
    """Compile the candidate's proxy program and predict (step time,
    peak memory).  ``use_xla_cost=True`` swaps the analytic chunk
    roofline for XLA's own ``cost_analysis`` of the proxy exec functions
    (slower; used by bench_autotune's predicted-vs-measured column)."""
    cost = cost or CostModel()
    prog, sm = build_candidate_program(config, mesh, cand, tokens)
    override = (None if use_xla_cost
                else make_chunk_cost(sm, tokens, cand.n_mb, cost))
    sim = TimelineSimulator(prog, cost, chunk_seconds_override=override)
    res = sim.run()
    peaks = timeline_peak_bytes(prog, res.records)
    peak = max(peaks.values())
    feasible = budget_bytes is None or peak <= budget_bytes
    return Score(candidate=cand, step_seconds=res.makespan,
                 peak_bytes=peak, feasible=feasible)


# ---------------------------------------------------------------------------
# search
# ---------------------------------------------------------------------------

def search(config, mesh: MeshSpec, budget: Optional[float] = None, *,
           tokens: int = DEFAULT_TOKENS,
           space: Optional[SearchSpace] = None,
           cost: Optional[CostModel] = None,
           cache_dir: Optional[str] = None,
           use_cache: bool = True,
           top_k: int = 5,
           progress: Optional[Callable[[Score], None]] = None) -> Plan:
    """Pick the fastest feasible strategy for ``config`` on ``mesh``.

    config : ArchConfig (from ``repro.configs.get_config``)
    mesh   : MeshSpec(pp, dp)
    budget : per-device memory budget in bytes (None = unlimited)
    tokens : global batch size in tokens per step

    Returns a ``Plan``; raises ``NoFeasiblePlanError`` when every
    candidate exceeds the budget.  Identical inputs are served from the
    JSON plan cache (``plan.from_cache`` is True)."""
    space = space or SearchSpace()
    cost = cost or CostModel()
    budget_bytes = int(budget) if budget is not None else None

    cache = PlanCache(cache_dir) if use_cache else None
    key = fingerprint(config=config, mesh=mesh, budget=budget_bytes,
                      tokens=tokens, space=space.to_dict(), cost=cost)
    if cache is not None:
        hit = cache.get(key)
        if hit is not None:
            return Plan.from_dict(hit, from_cache=True, config=config)

    base = score_candidate(config, mesh, baseline_candidate(config, mesh),
                           tokens=tokens, budget_bytes=budget_bytes,
                           cost=cost)
    scores: list[Score] = []
    seen = set()
    for cand in space.candidates(config, mesh, tokens):
        if cand in seen:
            continue
        seen.add(cand)
        s = (base if cand == base.candidate else
             score_candidate(config, mesh, cand, tokens=tokens,
                             budget_bytes=budget_bytes, cost=cost))
        scores.append(s)
        if progress is not None:
            progress(s)

    if not scores:
        raise NoFeasiblePlanError(
            f"search space is empty for {config.name} on pp={mesh.pp} "
            f"dp={mesh.dp}: no candidate microbatch count divides "
            f"tokens={tokens} evenly across dp={mesh.dp} (try a tokens "
            f"value divisible by {4 * mesh.pp * max(mesh.dp, 1)})")
    feasible = [s for s in scores if s.feasible]
    if not feasible:
        mn = min(scores, key=lambda s: s.peak_bytes) if scores else None
        raise NoFeasiblePlanError(
            f"no candidate fits {budget_bytes} bytes/device for "
            f"{config.name} on pp={mesh.pp} dp={mesh.dp}"
            + (f" (smallest footprint: {mn.candidate.label()} at "
               f"{mn.peak_bytes} bytes)" if mn else ""))
    # deterministic: ties break by enumeration order (stable sort)
    ranked = sorted(feasible, key=lambda s: (s.step_seconds, s.peak_bytes))
    best = ranked[0]
    plan = Plan(
        config_name=config.name, mesh=mesh, tokens=tokens,
        budget_bytes=budget_bytes, candidate=best.candidate,
        predicted_step_seconds=best.step_seconds,
        predicted_peak_bytes=best.peak_bytes,
        baseline=base, leaderboard=ranked[:top_k],
        n_evaluated=len(scores),
        n_rejected=len(scores) - len(feasible),
        _config=config,
    )
    if cache is not None:
        cache.put(key, plan.to_dict())
    return plan
