"""The ``measured`` proxy column: real SPMD wall-clock next to the
simulator's prediction, and a CostModel calibration from the ratio.

The tuner scores candidates on the timeline simulator with TPU-v5e
constants; nothing so far checked those predictions against *any* real
execution.  This module runs a candidate's compiled proxy program on
real XLA devices via the SPMD executor (``runtime.spmd``) and reports,
per cell,

    ratio = measured_seconds / predicted_seconds

On the CI host harness the absolute ratio is meaningless (host cores
are not v5e chips) — what matters is that the ratio is STABLE across
cells: a schedule the simulator ranks 1.3x faster should measure ~1.3x
faster too.  ``calibrate`` folds the median ratio into the cost model's
``mfu`` so predicted step times land on the measured scale; the spread
(``CalibrationResult.dispersion``) is the honest error bar of the
simulator on this hardware.  ``benchmarks/bench_spmd_parity.py``
records the per-cell table into ``benchmarks/results/spmd/``.
"""
from __future__ import annotations

import dataclasses
import statistics
from dataclasses import dataclass
from typing import Any, Optional, Sequence

import jax

from ..runtime.costmodel import CostModel


def materialize_params(params, seed: int = 0, scale: float = 0.02):
    """Real arrays for a (possibly ShapeDtypeStruct-valued) param tree —
    the proxy programs compile against avals; real execution needs
    bits."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    key = jax.random.PRNGKey(seed)
    out = []
    for i, l in enumerate(leaves):
        if not isinstance(l, jax.ShapeDtypeStruct):
            out.append(l)               # already a real array
            continue
        out.append((jax.random.normal(jax.random.fold_in(key, i),
                                      l.shape) * scale).astype(l.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def synth_batch(prog, seed: int = 1) -> dict[str, Any]:
    """A random batch matching ``prog.input_shapes()``."""
    key = jax.random.PRNGKey(seed)
    return {name: jax.random.normal(
        jax.random.fold_in(key, i), shape).astype(dtype)
        for i, (name, (shape, dtype))
        in enumerate(sorted(prog.input_shapes().items()))}


def measure_program(prog, batch: Optional[dict] = None,
                    params: Optional[dict] = None, reps: int = 3) -> float:
    """Measured wall-clock seconds/step of ``prog`` on the SPMD
    executor (requires >= ``len(plan.devices)`` XLA devices — see
    ``launch.hostdevices.ensure_host_devices``)."""
    from ..runtime.executor import make_executor
    if params is None:
        params = materialize_params(prog.params)
    if batch is None:
        batch = synth_batch(prog)
    return make_executor("spmd", prog, params=params).measure(batch,
                                                             reps=reps)


@dataclass(frozen=True)
class MeasuredCell:
    label: str
    predicted_seconds: float
    measured_seconds: float

    @property
    def ratio(self) -> float:
        return self.measured_seconds / max(self.predicted_seconds, 1e-12)

    def to_dict(self) -> dict:
        return {"label": self.label,
                "predicted_seconds": self.predicted_seconds,
                "measured_seconds": self.measured_seconds,
                "ratio": self.ratio}


@dataclass(frozen=True)
class CalibrationResult:
    cells: tuple
    scale: float               # median measured/predicted ratio
    dispersion: float          # max/min cell ratio (1.0 = perfect model)
    cost: CostModel            # calibrated copy

    def to_dict(self) -> dict:
        # summary only — the per-cell table is the caller's to record
        # (bench_spmd_parity keeps ONE copy of the rows; duplicating
        # them here would leave two sources of truth in the artifact)
        return {"scale": self.scale, "dispersion": self.dispersion,
                "mfu": self.cost.mfu, "n_cells": len(self.cells)}


def calibrate(cost: CostModel,
              cells: Sequence[MeasuredCell]) -> CalibrationResult:
    """Fold the measured/predicted ratio into the cost model.

    Chunk time scales as ``1/(peak_flops * mfu)``; dividing ``mfu`` by
    the median ratio rescales every compute-bound prediction onto the
    measured clock without touching the comm constants (host 'links'
    are memcpy — calibrating ``ici_bw`` against them would be
    fiction).  ``mfu`` is clamped to (1e-4, 1.0]."""
    if not cells:
        raise ValueError("calibrate needs at least one measured cell")
    ratios = [c.ratio for c in cells]
    scale = statistics.median(ratios)
    mfu = min(max(cost.mfu / max(scale, 1e-12), 1e-4), 1.0)
    return CalibrationResult(
        cells=tuple(cells), scale=scale,
        dispersion=max(ratios) / max(min(ratios), 1e-12),
        cost=dataclasses.replace(cost, mfu=mfu))
