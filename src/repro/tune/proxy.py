"""Candidate strategy -> compiled proxy program (DESIGN.md §8).

The tuner never traces the real model per candidate — that would lower
every architecture at full size for every point in the search space.
Instead each ``ArchConfig`` is *decomposed* into a stage-granular proxy:

  - ``n_stages`` equal slices of the layer stack, each a Chunk whose
    params are ShapeDtypeStructs sized to the slice's true parameter
    count (tracing is ``jax.eval_shape``-only, so nothing allocates);
  - per stage, a two-matmul exec function ``tanh(x @ W1) @ W2`` with
    ``W1: (d, k)``, ``k = P_stage / 2d`` — its FLOP count is exactly the
    dense-transformer rule 2·P·tokens, so XLA's own ``cost_analysis``
    agrees with the closed form (benchmarks/bench_autotune.py checks
    this);
  - MoE configs add an expert Chunk per stage whose matmul dims carry
    the *active* (top-k) parameters and whose bucket carries the full
    resident expert parameters (a ``bank`` leaf the exec fn ignores), so
    FLOPs follow activation and memory follows residency.

Boundary activations are (tokens, d_model) bf16, so the p2p / all-to-all
wire bytes the simulator charges are the real ones.  Chunk compute cost
comes from the analytic roofline in ``make_chunk_cost`` (the XLA-lowered
path stays available by simply not passing the override).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..core import OverlapConfig, compile_training
from ..core.strategy import Overlap, Strategy
from ..models.model import params_count
from ..runtime.costmodel import CostModel
from .space import Candidate, MeshSpec

PROXY_DTYPE = "bfloat16"
# floor on a chunk's modelled runtime (dispatch / kernel-launch overhead)
MIN_CHUNK_SECONDS = 1e-6


@dataclass(frozen=True)
class StageModel:
    """Per-stage parameter decomposition of an ArchConfig."""
    n_stages: int
    d_model: int
    dense_resident: tuple     # params resident per stage (dense path)
    dense_active: tuple       # params multiplied per token per stage
    expert_resident: tuple    # routable expert params resident per stage
    expert_active: tuple      # top-k expert params active per token


def decompose(cfg, n_stages: int) -> StageModel:
    """Split a config's parameters into ``n_stages`` equal layer slices.

    Embedding weights sit on stage 0; the unembedding matrix is counted
    resident on the last stage even for tied embeddings (a PP placement
    must materialize it there) and active only there (the lm-head
    matmul; the stage-0 lookup is a gather with ~0 FLOPs)."""
    d, v = cfg.d_model, cfg.vocab
    embed_in = v * d
    embed_out = v * d + d
    if cfg.moe:
        e = cfg.moe
        n_mlp = 3 if cfg.act == "swiglu" else 2
        per_expert = n_mlp * d * e.d_expert
        expert_layer = e.n_experts * per_expert
        active_layer = max(e.top_k, 1) * per_expert
    else:
        expert_layer = active_layer = 0
    total = params_count(cfg)
    tied_extra = embed_in if cfg.tie_embeddings else 0
    dense_total = max(total + tied_extra - embed_in - embed_out
                      - cfg.n_layers * expert_layer, 0)
    per_stage = dense_total / n_stages
    resident = [per_stage] * n_stages
    active = [per_stage] * n_stages
    resident[0] += embed_in
    resident[-1] += embed_out
    active[-1] += embed_out
    exp_res = [0.0] * n_stages
    exp_act = [0.0] * n_stages
    if expert_layer:
        per_stage_layers = cfg.n_layers / n_stages
        for s in range(n_stages - 1):      # head stage stays dense
            exp_res[s] = expert_layer * per_stage_layers
            exp_act[s] = active_layer * per_stage_layers
    return StageModel(
        n_stages=n_stages, d_model=d,
        dense_resident=tuple(int(x) for x in resident),
        dense_active=tuple(int(x) for x in active),
        expert_resident=tuple(int(x) for x in exp_res),
        expert_active=tuple(int(x) for x in exp_act))


# ---------------------------------------------------------------------------
# proxy params + exec functions
# ---------------------------------------------------------------------------

def _stage_fn(p, x):
    return jnp.tanh(x @ p["w1"]) @ p["w2"]


def _loss_fn(p, x, y):
    h = jnp.tanh(x @ p["w1"]) @ p["w2"]
    return jnp.mean((h - y).astype(jnp.float32) ** 2)


def _mat_avals(n_params: int, d: int, bank: int = 0) -> dict:
    """Two matmul weights holding ``n_params`` total (k = P/2d), plus an
    optional inert ``bank`` of additional resident parameters."""
    dt = jnp.dtype(PROXY_DTYPE)
    k = max(1, int(round(n_params / (2 * d))))
    avals = {"w1": jax.ShapeDtypeStruct((d, k), dt),
             "w2": jax.ShapeDtypeStruct((k, d), dt)}
    if bank > 0:
        avals["bank"] = jax.ShapeDtypeStruct((int(bank),), dt)
    return avals


def make_proxy_params(sm: StageModel) -> dict:
    params = {}
    for s in range(sm.n_stages):
        params[f"stage{s}"] = _mat_avals(sm.dense_active[s], sm.d_model,
                                         bank=max(sm.dense_resident[s]
                                                  - sm.dense_active[s], 0))
        if sm.expert_resident[s]:
            params[f"exp{s}"] = _mat_avals(
                sm.expert_active[s], sm.d_model,
                bank=max(sm.expert_resident[s] - sm.expert_active[s], 0))
    return params


def make_proxy_forward(sm: StageModel):
    S = sm.n_stages

    def forward(rec, tvs):
        h = tvs["x"]
        for i in range(S - 1):
            with rec.annotate("pp"):
                h = rec.region(_stage_fn, f"stage{i}", name=f"s{i}")(h)
                if sm.expert_resident[i]:
                    with rec.annotate("ep"):
                        h = rec.region(_stage_fn, f"exp{i}",
                                       name=f"e{i}")(h)
        with rec.annotate("pp"):
            loss = rec.region(_loss_fn, f"stage{S-1}",
                              name="head")(h, tvs["y"])
        return loss

    return forward


# ---------------------------------------------------------------------------
# strategy + compile
# ---------------------------------------------------------------------------

def candidate_strategy(cfg, mesh: MeshSpec, cand: Candidate) -> Strategy:
    """The declarative Strategy a candidate denotes (the serialized /
    cached artifact).  ``cfg`` is accepted for signature symmetry —
    expert placement is derived from the traced proxy DAG at compile
    time, not from the config here."""
    return cand.to_strategy(mesh)


def candidate_directives(cfg, mesh: MeshSpec, cand: Candidate,
                         sm: StageModel) -> list:
    """The full directive list (Place/Replicate/Shard/Split/Order) a
    candidate compiles to — ``candidate_strategy`` lowered with the
    expert stages the config decomposition places."""
    expert_stages = {s for s in range(sm.n_stages)
                     if sm.expert_resident[s]}
    return candidate_strategy(cfg, mesh, cand).lower(
        expert_stages=expert_stages)


def candidate_overlap(cand: Candidate):
    """The overlap-engine config a candidate's axes select (None keeps
    the legacy just-in-time plan)."""
    if cand.prefetch <= 0:
        return None
    return OverlapConfig(enabled=True, prefetch=cand.prefetch,
                         bucket_bytes=cand.bucket_mb << 20)


_UNSET = object()


def build_strategy_program(cfg, strategy: Strategy, tokens: int):
    """Compile the stage-granular proxy program for a declarative
    ``Strategy`` (the ``--strategy strategy.json`` replay path).
    Returns (CompiledProgram, StageModel)."""
    strategy.validate()
    pipe = strategy.pipeline
    if pipe is None:
        raise ValueError("strategy has no Pipeline fragment; the proxy "
                         "decomposition needs a stage count")
    sm = decompose(cfg, pipe.stages(strategy.mesh))
    params = make_proxy_params(sm)
    fwd = make_proxy_forward(sm)
    inputs = {"x": ((tokens, sm.d_model), PROXY_DTYPE),
              "y": ((tokens, sm.d_model), PROXY_DTYPE)}
    prog = compile_training(fwd, params, inputs, strategy=strategy)
    return prog, sm


def build_candidate_program(cfg, mesh: MeshSpec, cand: Candidate,
                            tokens: int, overlap=_UNSET):
    """Compile the proxy program for one candidate through the Strategy
    front door.  Returns (CompiledProgram, StageModel).  ``overlap``
    overrides the candidate's own overlap axes with an explicit
    ``OverlapConfig`` or None (used by bench_overlap's on/off/legacy
    comparison)."""
    strat = candidate_strategy(cfg, mesh, cand)
    if overlap is not _UNSET:
        strat = (strat.without(Overlap) if overlap is None
                 else strat.replacing(Overlap.from_config(overlap)))
    return build_strategy_program(cfg, strat, tokens)


# ---------------------------------------------------------------------------
# analytic chunk cost
# ---------------------------------------------------------------------------

def make_chunk_cost(sm: StageModel, tokens: int, n_mb: int,
                    cost: CostModel):
    """Closed-form roofline for proxy chunks: FLOPs = 2 · P_active ·
    local_tokens, scaled per pass to match the chunk's residual policy
    (DESIGN.md §2/§11).  Under ``Remat(policy="full")`` — the historical
    default — a joint backward re-runs the forward under ``jax.vjp``
    then computes both grads (3×F), and the ZeroBubble Bi/Bw halves each
    redo the remat (2×F apiece — the split's price is one extra
    forward).  A remat-stashed chunk (``policy="none"``, marked
    ``meta["remat"]``) skips the re-run: B = 2×F, Bi/Bw = 1×F each.
    HBM bytes = weights once + ~3 boundary-sized activation tensors."""
    active = {}
    for s in range(sm.n_stages):
        active[f"stage{s}"] = sm.dense_active[s]
        if sm.expert_resident[s]:
            active[f"exp{s}"] = sm.expert_active[s]
    pass_mult = {"F": 1.0, "B": 3.0, "Bi": 2.0, "Bw": 2.0}
    stash_mult = {"F": 1.0, "B": 2.0, "Bi": 1.0, "Bw": 1.0}

    def chunk_seconds(node) -> float:
        p_active = active.get(node.bucket, 0)
        t = tokens / max(n_mb, 1)
        k = len(node.devices or ()) or 1
        if k > 1 and node.meta.get("placement_mode") in (
                "replicate", "shard_expert"):
            t /= k
        table = (stash_mult if node.meta.get("remat") == "none"
                 else pass_mult)
        mult = table.get(node.dims.get("PASS", "F"), 1.0)
        flops = 2.0 * p_active * t * mult
        t_c = flops / (cost.peak_flops * cost.mfu)
        bytes_ = 2.0 * p_active + 3 * 2.0 * t * sm.d_model
        t_m = bytes_ / cost.hbm_bw
        return max(t_c, t_m, MIN_CHUNK_SECONDS)

    return chunk_seconds
