"""Input shape specs for every (architecture x shape) dry-run cell.

LM transformer shapes (task spec):
  train_4k     seq 4,096  global_batch 256   -> train_step
  prefill_32k  seq 32,768 global_batch 32    -> prefill (serve)
  decode_32k   seq 32,768 global_batch 128   -> decode_step (serve)
  long_500k    seq 524,288 global_batch 1    -> decode_step, only for
               sub-quadratic archs (SSM/hybrid); full-attention archs are
               recorded as skipped(full-attention) per the task rule.

Everything returns ShapeDtypeStructs — no device allocation.  Modality
frontends are stubs: whisper gets precomputed frame embeddings, qwen2-vl
gets token embeddings + 3-stream M-RoPE position ids.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from ..models import ArchConfig, init, init_cache

SHAPES = {
    "train_4k": {"seq": 4096, "batch": 256, "kind": "train"},
    "prefill_32k": {"seq": 32768, "batch": 32, "kind": "prefill"},
    "decode_32k": {"seq": 32768, "batch": 128, "kind": "decode"},
    "long_500k": {"seq": 524288, "batch": 1, "kind": "decode"},
}


def cell_status(cfg: ArchConfig, shape_name: str) -> str:
    """'ok' or the skip reason for this (arch, shape) cell."""
    if shape_name == "long_500k" and not cfg.subquadratic:
        return "skipped(full-attention)"
    return "ok"


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def batch_specs(cfg: ArchConfig, shape_name: str) -> dict:
    """Graph inputs for the cell (the data-pipeline contract)."""
    info = SHAPES[shape_name]
    b, s = info["batch"], info["seq"]
    if info["kind"] == "train":
        batch = {"tokens": sds((b, s), "int32"),
                 "labels": sds((b, s), "int32")}
        if cfg.n_enc_layers:
            batch["frames"] = sds((b, cfg.enc_seq, cfg.d_model), cfg.dtype)
        if cfg.mrope:
            batch["mrope_positions"] = sds((3, b, s), "int32")
        return batch
    if info["kind"] == "prefill":
        batch = {"tokens": sds((b, s), "int32")}
        if cfg.n_enc_layers:
            batch["frames"] = sds((b, cfg.enc_seq, cfg.d_model), cfg.dtype)
        if cfg.mrope:
            batch["mrope_positions"] = sds((3, b, s), "int32")
        return batch
    # decode: one new token against a seq-long cache
    return {"token": sds((b, 1), "int32")}


def state_specs(cfg: ArchConfig) -> dict:
    """Training state avals (params + AdamW moments) with no allocation."""
    from ..optim import adamw_init
    params = jax.eval_shape(partial(init, cfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    opt = jax.eval_shape(adamw_init, params)
    return {"params": params, "opt": opt,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def params_specs(cfg: ArchConfig) -> Any:
    return jax.eval_shape(partial(init, cfg),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def cache_specs(cfg: ArchConfig, shape_name: str) -> Any:
    info = SHAPES[shape_name]
    return jax.eval_shape(partial(init_cache, cfg, info["batch"],
                                  info["seq"]))


def dryrun_config(cfg: ArchConfig) -> ArchConfig:
    """Full config adjusted for the production run: bf16, remat, chunked
    cross-entropy."""
    return dataclasses.replace(cfg, dtype="bfloat16", remat="full",
                               loss_chunk=2048)
