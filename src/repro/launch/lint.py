"""Static plan linter (DESIGN.md §15–16, docs/lint.md).

Runs the ``repro.analysis`` verifier on compiled plans without executing
anything — deadlock, buffer-lifetime, stream-race and interface checks,
plus (by default) the semantic layer: the shape/dtype/shard typechecker
and the pairwise per-rank interface signatures.  ``lint --types`` is the
MPMD-readiness gate: a plan whose per-rank interfaces typecheck pairwise
can be split into per-rank programs with no global trace to cross-check.
Everything is reported as stable ``PIPER`` codes with directive/pass
provenance.

Lint one strategy (the ``strategy.json`` artifact the autotuner and the
train driver exchange) against a config's proxy model:

  PYTHONPATH=src python -m repro.launch.lint \
      --strategy strategy.json --config qwen1.5-0.5b

Lint the whole config x schedule x ZeRO grid — now including the remat
and offload memory-pass cells the translation validator certifies (the
CI ``tier1-lint`` surface):

  PYTHONPATH=src python -m repro.launch.lint --grid --json --out lint.json

Exit status: 0 all plans clean, 1 any error diagnostic, 2 a plan failed
to compile at all.  Configs are linted at their ``reduced()`` size —
the analyses are structural, so plan shape (not parameter count) is
what matters.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

from repro.analysis import PlanVerificationError, analyze
from repro.configs import ARCHS, get_config
from repro.core.plan import ScheduleRejected
from repro.core.strategy import (Mesh, Offload, Pipeline, Remat, Strategy,
                                 StrategyError, ZeRO)
from repro.tune import build_strategy_program

GRID_SCHEDULES = ("1f1b", "gpipe", "dualpipev")
GRID_ZERO = (0, 3)
# the memory-pass cells: remat residual stashing and host offload are
# exactly the rewrites the PIPER026 translation validator certifies, so
# the lint grid must exercise them (ISSUE 9 satellite)
GRID_MEMORY = (
    {"schedule": "1f1b", "zero": 3, "remat": "none", "offload": False},
    {"schedule": "dualpipev", "zero": 3, "remat": "none", "offload": False},
    {"schedule": "1f1b", "zero": 3, "remat": "none", "offload": True},
)


def lint_cell(cfg, strategy: Strategy, tokens: int, depth: str,
              types: bool = True) -> dict:
    """Compile one (config, strategy) cell and analyze it.  A plan the
    compiler's own embedded quick verification rejects still yields a
    structured report (the exception carries it); only strategy/schedule
    errors upstream of a finished plan count as compile errors."""
    t0 = time.time()
    try:
        prog, _sm = build_strategy_program(cfg, strategy, tokens)
    except PlanVerificationError as exc:
        report = exc.report
        prog = None
    except (StrategyError, ScheduleRejected, ValueError) as exc:
        return {"ok": False, "compile_error": str(exc),
                "codes": [], "diagnostics": [],
                "seconds": round(time.time() - t0, 3)}
    if prog is not None:
        report = analyze(prog, depth=depth, types=types)
    return {"ok": report.ok,
            "codes": sorted(set(report.codes())),
            "diagnostics": [d.to_dict() for d in report.diagnostics],
            "meta": report.meta,
            "seconds": round(time.time() - t0, 3)}


def _grid_strategy(sched: str, zero: int, n_mb: int,
                   remat: str = "full", offload: bool = False) -> Strategy:
    frags = Pipeline(sched, n_mb=n_mb) | ZeRO(stage=zero)
    if remat != "full":
        frags = frags | Remat(remat)
    if offload:
        frags = frags | Offload(depth=2)
    return Strategy(Mesh(pp=2, dp=2), frags)


def run_grid(depth: str, tokens: int, n_mb: int,
             archs=None, types: bool = True) -> dict:
    cells = []
    for name in (archs or ARCHS):
        cfg = get_config(name).reduced()
        for sched in GRID_SCHEDULES:
            for zero in GRID_ZERO:
                cell = lint_cell(cfg, _grid_strategy(sched, zero, n_mb),
                                 tokens, depth, types=types)
                cell.update(config=name, schedule=sched, zero=zero,
                            remat="full", offload=False)
                cells.append(cell)
        for mem in GRID_MEMORY:
            cell = lint_cell(
                cfg, _grid_strategy(mem["schedule"], mem["zero"], n_mb,
                                    remat=mem["remat"],
                                    offload=mem["offload"]),
                tokens, depth, types=types)
            cell.update(config=name, **mem)
            cells.append(cell)
    return {"depth": depth,
            "types": types,
            "ok": all(c["ok"] for c in cells),
            "compile_errors": sum(1 for c in cells
                                  if c.get("compile_error")),
            "cells": cells}


def _format_cell_text(cell: dict) -> str:
    keys = ("config", "schedule", "zero", "remat", "offload")
    tag = " ".join(f"{k}={cell[k]}" for k in keys if k in cell)
    if cell.get("compile_error"):
        return f"COMPILE-ERROR [{tag}] {cell['compile_error']}"
    if cell["ok"] and not cell["diagnostics"]:
        return f"ok [{tag}] ({cell['seconds']}s)"
    lines = [("ok" if cell["ok"] else "FAIL") + f" [{tag}]"]
    for d in cell["diagnostics"]:
        lines.append(f"  {d['code']} {d['severity']}: {d['message']}")
        for p in d["provenance"]:
            lines.append(f"      at {p}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.launch.lint",
        description="static verifier for compiled Piper plans")
    ap.add_argument("--strategy", type=pathlib.Path,
                    help="strategy.json to lint (Strategy.to_json format)")
    ap.add_argument("--config", default="qwen1.5-0.5b",
                    help="architecture the strategy compiles against "
                         f"(one of {', '.join(ARCHS)})")
    ap.add_argument("--grid", action="store_true",
                    help="lint the full config x schedule x ZeRO grid "
                         "plus the remat/offload memory cells")
    ap.add_argument("--arch", action="append", dest="archs",
                    help="restrict --grid to these configs (repeatable)")
    ap.add_argument("--depth", choices=("quick", "deep"), default="deep",
                    help="verifier depth (default: deep — the abstract "
                         "executor replay)")
    ap.add_argument("--types", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="run the semantic layer: shape/dtype/shard "
                         "typechecker + pairwise per-rank interface "
                         "signatures, the MPMD-readiness gate "
                         "(default: on; --no-types disables)")
    ap.add_argument("--tokens", type=int, default=64,
                    help="proxy tokens per microbatch batch dim")
    ap.add_argument("--n-mb", type=int, default=4,
                    help="microbatches for --grid strategies")
    ap.add_argument("--json", action="store_true",
                    help="emit the JSON report to stdout")
    ap.add_argument("--out", type=pathlib.Path,
                    help="also write the JSON report to this file")
    args = ap.parse_args(argv)

    if bool(args.grid) == (args.strategy is not None):
        ap.error("pass exactly one of --strategy or --grid")

    if args.grid:
        result = run_grid(args.depth, args.tokens, args.n_mb,
                          archs=args.archs, types=args.types)
        cells = result["cells"]
    else:
        try:
            strategy = Strategy.from_json(args.strategy.read_text())
        except (OSError, StrategyError, ValueError, KeyError) as exc:
            print(f"COMPILE-ERROR [strategy={args.strategy}] {exc}")
            return 2
        cfg = get_config(args.config).reduced()
        cell = lint_cell(cfg, strategy, args.tokens, args.depth,
                         types=args.types)
        cell.update(config=args.config,
                    strategy=str(args.strategy))
        result = {"depth": args.depth, "types": args.types,
                  "ok": cell["ok"],
                  "compile_errors": int(bool(cell.get("compile_error"))),
                  "cells": [cell]}
        cells = [cell]

    if args.out:
        args.out.write_text(json.dumps(result, indent=2))
    if args.json:
        print(json.dumps(result, indent=2))
    else:
        for cell in cells:
            print(_format_cell_text(cell))
        n_bad = sum(1 for c in cells if not c["ok"])
        print(f"{len(cells)} plan(s) linted at depth={args.depth}, "
              f"{n_bad} with errors")
    if result["compile_errors"]:
        return 2
    return 0 if result["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
