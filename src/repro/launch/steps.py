"""SPMD step builders: train_step / prefill_step / decode_step wired to
the mesh with the sharding rules (the Piper strategy lowered to pjit —
DESIGN.md §2, 'logical streams -> XLA scheduling lanes')."""
from __future__ import annotations


import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

import numpy as np

from ..models import ArchConfig, decode_step, prefill, train_loss
from ..optim import adamw_update
from ..parallel.sharding import (ShardingRules, batch_shardings,
                                 cache_shardings, opt_state_shardings,
                                 params_shardings)
from .specs import batch_specs, cache_specs, params_specs, state_specs


def _logits_sharding(mesh: Mesh, strat: ShardingRules, batch: int):
    ax = strat.dp_axes if len(strat.dp_axes) > 1 else strat.dp_axes[0]
    size = int(np.prod([mesh.shape[a] for a in
                        (ax if isinstance(ax, tuple) else (ax,))]))
    if batch % size:
        return NamedSharding(mesh, P())
    return NamedSharding(mesh, P(ax, None, None))


def strategy_for(mesh: Mesh, zero_stage: int = 3, core=None,
                 **kw) -> ShardingRules:
    """The pjit step builders' sharding rules, derived from ONE source
    of truth: a first-class ``core.strategy.Strategy``.  Pass ``core=``
    to drive the lowering from a declarative strategy document (the
    same JSON ``--strategy`` replays through the Piper-IR backends);
    the legacy ``zero_stage=`` spelling builds the equivalent ZeRO
    fragment and routes through the same derivation.  ``kw`` overrides
    pass through (``attn_mode``, ``seq_axis``, ``moe_impl``, ...)."""
    if core is None:
        from ..core.strategy import Strategy as CoreStrategy
        from ..core.strategy import ZeRO
        core = CoreStrategy(None, (ZeRO(stage=zero_stage),))
    elif core.zero is None:
        # a doc WITH a ZeRO fragment overrides the CLI; a doc without
        # one leaves the caller's zero_stage in force (the pre-unified
        # behavior dryrun's --zero help documents)
        kw.setdefault("zero_stage", zero_stage)
    return ShardingRules.from_core(core, mesh, **kw)


def make_train_fn(cfg: ArchConfig, lr: float = 3e-4):
    def step(state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: train_loss(cfg, p, batch))(state["params"])
        new_params, new_opt, gnorm = adamw_update(
            state["params"], grads, state["opt"], lr)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, {"loss": loss, "gnorm": gnorm}
    return step


def make_prefill_fn(cfg: ArchConfig, max_seq: int):
    def step(params, batch):
        return prefill(cfg, params, batch, max_seq)
    return step


def make_decode_fn(cfg: ArchConfig):
    def step(params, cache, batch):
        logits, new_cache = decode_step(cfg, params, batch["token"], cache)
        return logits, new_cache
    return step


def jit_train_step(cfg: ArchConfig, mesh: Mesh, strat: ShardingRules,
                   shape_name: str = "train_4k"):
    """Returns (jitted_fn, (state_avals, batch_avals))."""
    state_avals = state_specs(cfg)
    batch_avals = batch_specs(cfg, shape_name)
    p_sh = params_shardings(state_avals["params"], mesh, strat)
    o_sh = {"m": opt_state_shardings(state_avals["opt"]["m"], mesh, strat),
            "v": opt_state_shardings(state_avals["opt"]["v"], mesh, strat),
            "step": NamedSharding(mesh, P())}
    state_sh = {"params": p_sh, "opt": o_sh,
                "step": NamedSharding(mesh, P())}
    b_sh = batch_shardings(batch_avals, mesh, strat)
    metric_sh = {"loss": NamedSharding(mesh, P()),
                 "gnorm": NamedSharding(mesh, P())}
    fn = jax.jit(make_train_fn(cfg),
                 in_shardings=(state_sh, b_sh),
                 out_shardings=(state_sh, metric_sh),
                 donate_argnums=(0,))
    return fn, (state_avals, batch_avals)


def jit_prefill_step(cfg: ArchConfig, mesh: Mesh, strat: ShardingRules,
                     shape_name: str = "prefill_32k"):
    from .specs import SHAPES
    seq = SHAPES[shape_name]["seq"]
    p_avals = params_specs(cfg)
    batch_avals = batch_specs(cfg, shape_name)
    cache_avals = jax.eval_shape(
        lambda p, b: prefill(cfg, p, b, seq)[1], p_avals, batch_avals)
    p_sh = params_shardings(p_avals, mesh, strat)
    b_sh = batch_shardings(batch_avals, mesh, strat)
    c_sh = cache_shardings(cache_avals, mesh, strat)
    logits_sh = _logits_sharding(mesh, strat,
                                 batch_avals["tokens"].shape[0])
    fn = jax.jit(make_prefill_fn(cfg, seq),
                 in_shardings=(p_sh, b_sh),
                 out_shardings=(logits_sh, c_sh))
    return fn, (p_avals, batch_avals)


def jit_decode_step(cfg: ArchConfig, mesh: Mesh, strat: ShardingRules,
                    shape_name: str = "decode_32k"):
    p_avals = params_specs(cfg)
    cache_avals = cache_specs(cfg, shape_name)
    batch_avals = batch_specs(cfg, shape_name)
    p_sh = params_shardings(p_avals, mesh, strat)
    c_sh = cache_shardings(cache_avals, mesh, strat)
    b_sh = batch_shardings(batch_avals, mesh, strat)
    logits_sh = _logits_sharding(mesh, strat,
                                 batch_avals["token"].shape[0])
    fn = jax.jit(make_decode_fn(cfg),
                 in_shardings=(p_sh, c_sh, b_sh),
                 out_shardings=(logits_sh, c_sh),
                 donate_argnums=(1,))
    return fn, (p_avals, cache_avals, batch_avals)


def axis_map_for(strat: ShardingRules) -> dict:
    dp = strat.dp_axes if len(strat.dp_axes) > 1 else strat.dp_axes[0]
    dpt = tuple(strat.dp_axes) + (strat.tp_axis,)
    return {"dp": dp, "tp": strat.tp_axis, "sp": strat.seq_axis,
            "dpt": dpt, "attn_tp": strat.attn_mode == "tp",
            "moe_a2a": strat.moe_impl == "a2a"}


def lower_cell(cfg: ArchConfig, mesh: Mesh, strat: ShardingRules,
               shape_name: str):
    """Lower (not compile) the right step for this cell."""
    from ..models import layers as L
    kind = {"train_4k": "train", "prefill_32k": "prefill",
            "decode_32k": "decode", "long_500k": "decode"}[shape_name]
    amap = axis_map_for(strat)
    amap["mesh"] = mesh
    L.set_axis_map(amap)
    # jax < 0.6 has no jax.set_mesh; entering the Mesh context manager
    # provides the same ambient mesh for the lowering
    set_mesh = getattr(jax, "set_mesh", None)
    try:
        with (set_mesh(mesh) if set_mesh is not None else mesh):
            if kind == "train":
                fn, avals = jit_train_step(cfg, mesh, strat, shape_name)
            elif kind == "prefill":
                fn, avals = jit_prefill_step(cfg, mesh, strat, shape_name)
            else:
                fn, avals = jit_decode_step(cfg, mesh, strat, shape_name)
            return fn.lower(*avals)
    finally:
        L.set_axis_map(None)
