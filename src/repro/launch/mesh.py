"""Production mesh builders (task spec: single-pod 16x16, multi-pod
2x16x16).  Functions, not module constants — importing this module never
touches jax device state."""
from __future__ import annotations

import jax

try:  # jax >= 0.5 exposes explicit axis types; older versions are Auto-only
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _mk(shape, axes):
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_pp_mesh(*, pipe: int = 4):
    """Extra lane (beyond the required meshes) for the Piper pipeline
    executor: ("pipe", "data", "model")."""
    return _mk((pipe, 256 // pipe // 16, 16), ("pipe", "data", "model"))


def dp_axes_for(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
