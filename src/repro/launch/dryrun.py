"""Multi-pod dry-run (task spec: MULTI-POD DRY-RUN).

For each (architecture x input shape x mesh) cell:
  lower  -> jax.jit(step, in_shardings, out_shardings).lower(*avals)
  compile-> lowered.compile()
  report -> memory_analysis(), cost_analysis(), collective bytes from the
            per-device HLO, and the derived roofline terms.

Run a single cell:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-32b \
      --shape train_4k --mesh pod1
Run everything (sequentially, caching into benchmarks/results/dryrun):
  PYTHONPATH=src python -m repro.launch.dryrun --all

Device-count note: the 512 faked host devices the pod meshes need are
requested via ``hostdevices.ensure_host_devices`` — ONLY when this
module runs as ``__main__`` (the guard below executes before the jax
import, which is what locks the count at first backend init).
Importing ``dryrun`` for its roofline helpers no longer mutates
``XLA_FLAGS`` in the importing process (smoke tests / benches keep
their own device count).
"""
import argparse
import json
import pathlib
import sys
import time
import traceback

from repro.launch.hostdevices import ensure_host_devices

if __name__ == "__main__":  # before the jax import locks device count
    ensure_host_devices(512, verify=False)

import jax  # noqa: F401  (must import after ensure_host_devices)

from repro.configs import ASSIGNED, get_config
from repro.launch import hlo_stats
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import SHAPES, cell_status, dryrun_config
from repro.launch.steps import lower_cell, strategy_for

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / \
    "benchmarks" / "results" / "dryrun"

# TPU v5e constants (task spec)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def roofline_terms(cell: dict, chips: int) -> dict:
    flops = cell.get("flops", 0.0)
    nbytes = cell.get("bytes_accessed", 0.0)
    coll = cell.get("collective", {}).get("total_bytes", 0)
    # cost_analysis on the partitioned module is per-device already;
    # guard with per_device flag
    t_compute = flops / PEAK_FLOPS
    t_memory = nbytes / HBM_BW
    t_collective = coll / LINK_BW
    dom = max(("compute", t_compute), ("memory", t_memory),
              ("collective", t_collective), key=lambda kv: kv[1])[0]
    return {"t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_collective, "dominant": dom}


def _cell_metrics(cfg, mesh, strat, shape) -> dict:
    """lower+compile one variant and extract cost/collective stats."""
    import dataclasses

    lowered = lower_cell(cfg, mesh, strat, shape)
    compiled = lowered.compile()
    m: dict = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        m["flops"] = float(ca.get("flops", 0.0))
        m["bytes_accessed"] = float(ca.get("bytes accessed", 0.0))
    except Exception as e:
        m["cost_error"] = str(e)
    try:
        m["collective"] = hlo_stats.collective_bytes(compiled.as_text())
    except Exception as e:
        m["hlo_error"] = str(e)
    return m


def probe_metrics(cfg, mesh, strat, shape) -> dict:
    """Trip-count-corrected FLOPs/bytes/collective bytes.

    XLA cost_analysis counts a while-loop body ONCE; our stacks are
    rolled scans, so the full compile undercounts by ~n_layers.  Two
    probe compiles at (k, 2k) layers with every scan UNROLLED give
    metric(L) = base + L*per_layer exactly (homogeneous stacks).  The
    SSM per-step elementwise recurrence inside a chunk stays rolled
    (unrolling 4096 steps is uncompilable) — a documented, small
    undercount of non-matmul FLOPs."""
    import dataclasses

    k = cfg.hybrid_every if cfg.hybrid_every else 2
    L = cfg.n_layers

    def probe_cfg(n):
        kw = {"n_layers": n, "unroll_scans": True}
        if cfg.n_enc_layers:
            kw["n_enc_layers"] = n
        return dataclasses.replace(cfg, **kw)

    mA = _cell_metrics(probe_cfg(k), mesh, strat, shape)
    mB = _cell_metrics(probe_cfg(2 * k), mesh, strat, shape)
    if "flops" not in mA or "flops" not in mB:
        return {"probe_error": mA.get("cost_error", "")
                or mB.get("cost_error", "")}

    def extrapolate(a, b):
        per = (b - a) / k
        return max(a + (L - k) * per, 0.0)

    out = {
        "flops": extrapolate(mA["flops"], mB["flops"]),
        "bytes_accessed": extrapolate(mA["bytes_accessed"],
                                      mB["bytes_accessed"]),
        "probe_layers": [k, 2 * k],
    }
    ca = mA.get("collective", {})
    cb = mB.get("collective", {})
    if ca and cb:
        per_kind = {}
        for kind in set(ca["per_kind_bytes"]) | set(cb["per_kind_bytes"]):
            per_kind[kind] = int(extrapolate(
                ca["per_kind_bytes"].get(kind, 0),
                cb["per_kind_bytes"].get(kind, 0)))
        out["collective"] = {
            "total_bytes": sum(per_kind.values()),
            "per_kind_bytes": per_kind,
            "per_kind_count": cb.get("per_kind_count", {}),
        }
    return out


def run_cell(arch: str, shape: str, mesh_name: str,
             zero_stage: int = 3, strategy_kw=None, cfg_kw=None,
             probe: bool = True, core_strategy=None) -> dict:
    """``core_strategy``: a first-class ``core.strategy.Strategy``
    driving the SPMD sharding derivation (ZeRO stage, EP dispatch,
    remat) — the same document the Piper-IR backends replay; the bare
    ``zero_stage`` spelling remains for CLI sweeps."""
    import dataclasses
    cfg0 = get_config(arch)
    status = cell_status(cfg0, shape)
    out = {"arch": arch, "shape": shape, "mesh": mesh_name,
           "status": status, "zero_stage": zero_stage,
           "strategy": dict(strategy_kw or {}), "cfg_kw": dict(cfg_kw or {})}
    if status != "ok":
        return out
    cfg = dryrun_config(cfg0)
    if cfg_kw:
        cfg = dataclasses.replace(cfg, **cfg_kw)
    mesh = make_production_mesh(multi_pod=(mesh_name == "pod2"))
    chips = mesh.devices.size
    strat = strategy_for(mesh, zero_stage=zero_stage, core=core_strategy,
                         **(strategy_kw or {}))
    out["zero_stage"] = strat.zero_stage
    t0 = time.time()
    lowered = lower_cell(cfg, mesh, strat, shape)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    out.update({"lower_s": round(t1 - t0, 2),
                "compile_s": round(t2 - t1, 2), "chips": chips})

    try:
        ma = compiled.memory_analysis()
        out["memory"] = {
            k: int(getattr(ma, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(ma, k)}
        args_b = out["memory"].get("argument_size_in_bytes", 0)
        temp_b = out["memory"].get("temp_size_in_bytes", 0)
        out["memory"]["per_device_total_gb"] = round(
            (args_b + temp_b) / 2**30, 3)
    except Exception as e:  # pragma: no cover
        out["memory_error"] = str(e)

    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        out["flops_rolled"] = float(ca.get("flops", 0.0))
        out["bytes_rolled"] = float(ca.get("bytes accessed", 0.0))
    except Exception as e:  # pragma: no cover
        out["cost_error"] = str(e)

    try:
        hlo = compiled.as_text()
        out["collective_rolled"] = hlo_stats.collective_bytes(hlo)
        out["hlo_ops"] = hlo_stats.hlo_op_histogram(hlo)
        out["hlo_lines"] = hlo.count("\n")
    except Exception as e:  # pragma: no cover
        out["hlo_error"] = str(e)

    # trip-count-corrected metrics from unrolled probe compiles
    if probe:
        t3 = time.time()
        try:
            pm = probe_metrics(cfg, mesh, strat, shape)
            out.update(pm)
            out["probe_s"] = round(time.time() - t3, 2)
        except Exception as e:  # pragma: no cover
            out["probe_error"] = f"{type(e).__name__}: {e}"
    if "flops" not in out:
        out["flops"] = out.get("flops_rolled", 0.0)
        out["bytes_accessed"] = out.get("bytes_rolled", 0.0)
        out["collective"] = out.get("collective_rolled", {})

    out["roofline"] = roofline_terms(out, chips)

    # model-flops ratio (6*N*D for dense, 6*N_active*D for MoE)
    if shape == "train_4k":
        n = (cfg.active_param_count() if cfg.moe
             else cfg.param_count())
        tokens = SHAPES[shape]["batch"] * SHAPES[shape]["seq"]
        model_flops = 6.0 * n * tokens / chips  # per device
        out["model_flops_per_device"] = model_flops
        if out.get("flops"):
            out["useful_flops_ratio"] = round(
                model_flops / out["flops"], 3)
    return out


def save(result: dict) -> pathlib.Path:
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    key = f"{result['arch']}__{result['shape']}__{result['mesh']}"
    if result.get("tag"):
        key += f"__{result['tag']}"
    path = RESULTS_DIR / f"{key}.json"
    path.write_text(json.dumps(result, indent=1, default=str))
    return path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2"])
    ap.add_argument("--zero", type=int, default=3)
    ap.add_argument("--strategy", default=None, metavar="JSON",
                    help="Strategy JSON document; its ZeRO fragment "
                    "overrides --zero for the SPMD lowering and the "
                    "document is recorded in the cell result")
    ap.add_argument("--attn-mode", default="cp", choices=["cp", "tp"])
    ap.add_argument("--seq-axis", default="model",
                    choices=["model", "none"])
    ap.add_argument("--remat", default="full", choices=["full", "none"])
    ap.add_argument("--loss-chunk", type=int, default=2048)
    ap.add_argument("--ssm-chunk", type=int, default=128)
    ap.add_argument("--moe", default="grouped", choices=["grouped", "a2a"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-probe", action="store_true")
    args = ap.parse_args(argv)

    strategy_doc = None
    core_strategy = None
    if args.strategy:
        from repro.core.strategy import Strategy, StrategyError
        try:
            core_strategy = Strategy.from_json(
                pathlib.Path(args.strategy).read_text())
        except (StrategyError, OSError) as e:
            print(f"strategy: {e}")
            return 2
        strategy_doc = core_strategy.to_dict()
        print(f"strategy: {core_strategy.label()} (drives ZeRO/EP/remat; "
              "CLI flags cover attn/seq)")

    cells = []
    if args.all:
        for arch in ASSIGNED:
            for shape in SHAPES:
                for mesh in ("pod1", "pod2"):
                    cells.append((arch, shape, mesh))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required without --all")
        cells = [(args.arch, args.shape, args.mesh)]

    failures = 0
    for (arch, shape, mesh) in cells:
        key = f"{arch}__{shape}__{mesh}"
        path = RESULTS_DIR / (key + (f"__{args.tag}" if args.tag else "")
                              + ".json")
        if path.exists() and not args.force:
            print(f"[cached] {key}")
            continue
        print(f"[run] {key} ...", flush=True)
        try:
            strategy_kw = {"attn_mode": args.attn_mode,
                           "seq_axis": (None if args.seq_axis == "none"
                                        else args.seq_axis)}
            if core_strategy is None:
                # --moe only applies without a strategy doc (the doc's
                # ExpertParallel fragment decides the dispatch impl)
                strategy_kw["moe_impl"] = args.moe
            cfg_kw = {"remat": args.remat, "loss_chunk": args.loss_chunk,
                      "ssm_chunk": args.ssm_chunk}
            res = run_cell(arch, shape, mesh, zero_stage=args.zero,
                           strategy_kw=strategy_kw, cfg_kw=cfg_kw,
                           probe=not args.no_probe,
                           core_strategy=core_strategy)
            if strategy_doc is not None:
                res["strategy_doc"] = strategy_doc
            if args.tag:
                res["tag"] = args.tag
            p = save(res)
            rf = res.get("roofline", {})
            print(f"  status={res['status']} compile={res.get('compile_s')}s"
                  f" mem/dev={res.get('memory', {}).get('per_device_total_gb')}GB"
                  f" dominant={rf.get('dominant')}  -> {p.name}", flush=True)
            if res.get("memory"):
                print(f"  memory_analysis: {res['memory']}")
            if res.get("flops") is not None:
                print(f"  cost_analysis: flops={res.get('flops'):.3e} "
                      f"bytes={res.get('bytes_accessed'):.3e}")
        except Exception as e:
            failures += 1
            print(f"  FAILED: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
