"""Faked host XLA devices, without import-time side effects.

jax locks the host-platform device count when its backend first
initializes, controlled by ``XLA_FLAGS=--xla_force_host_platform_device_
count=N``.  Historically ``launch/dryrun.py`` mutated ``os.environ`` at
import time to get 512 devices — which silently poisoned the device
count of ANY process that imported it for its roofline helpers.  This
module is the explicit replacement: callers that need N devices (the
``--backend spmd`` executor, dryrun's ``__main__``, the spmd test
subprocesses) request them deliberately, and library imports never touch
jax state.

This module must stay importable before jax: it only touches
``os.environ`` until a caller asks for verification.
"""
from __future__ import annotations

import os

_FLAG = "--xla_force_host_platform_device_count="


def requested_host_devices() -> int | None:
    """The count currently requested via XLA_FLAGS, if any."""
    for part in os.environ.get("XLA_FLAGS", "").split():
        if part.startswith(_FLAG):
            try:
                return int(part[len(_FLAG):])
            except ValueError:
                return None
    return None


def ensure_host_devices(n: int, *, verify: bool = True) -> int:
    """Request at least ``n`` faked host-platform devices.

    Sets ``XLA_FLAGS`` (idempotently; an existing larger request is
    kept) and, with ``verify=True``, initializes jax and checks the
    request took effect.  Must be called before jax's backend first
    initializes — importing jax is fine, calling ``jax.devices()`` is
    not.  Raises ``RuntimeError`` with subprocess advice when the
    backend is already locked to fewer devices.

    Returns the number of devices available (``n`` unverified)."""
    if n < 1:
        raise ValueError(f"need a positive device count, got {n}")
    cur = requested_host_devices()
    if cur is None or cur < n:
        parts = [p for p in os.environ.get("XLA_FLAGS", "").split()
                 if not p.startswith(_FLAG)]
        parts.append(_FLAG + str(n))
        os.environ["XLA_FLAGS"] = " ".join(parts)
    if not verify:
        return n
    import jax
    have = len(jax.devices())
    if have < n:
        raise RuntimeError(
            f"jax initialized with {have} device(s) before "
            f"ensure_host_devices({n}) could take effect — the host "
            "device count locks at first backend use.  Call "
            "ensure_host_devices earlier (before anything touches jax "
            "devices), or run in a subprocess with "
            f"XLA_FLAGS={_FLAG}{n} set in its environment (see "
            "tests/test_spmd_executor.py)")
    return have
