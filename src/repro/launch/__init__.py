"""Launch layer: production mesh, input specs, SPMD steps, dry-run."""
