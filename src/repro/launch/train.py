"""End-to-end training driver (deliverable b): trains a reduced (or
~100M-parameter) model for a few hundred steps on whatever devices are
available, with the full substrate — data pipeline, AdamW + schedule,
checkpoint/restart via the FT supervisor.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --steps 200 --d-model 256 --layers 4

A mid-run injected failure (--fail-at) demonstrates checkpoint-restart;
the run resumes from the last checkpoint with the exact data stream.
"""
from __future__ import annotations

import argparse
import pathlib
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import SyntheticTokenSource, TokenLoader
from repro.ft import FailureInjector, Supervisor
from repro.models import init, train_loss
from repro.optim import adamw_init, adamw_update, cosine_schedule, \
    wsd_schedule


def build_step(cfg, lr_fn):
    @jax.jit
    def step(state, batch):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        loss, grads = jax.value_and_grad(
            lambda p: train_loss(cfg, p, batch))(state["params"])
        lr = lr_fn(state["opt"]["step"])
        params, opt, gnorm = adamw_update(state["params"], grads,
                                          state["opt"], lr)
        return ({"params": params, "opt": opt,
                 "step": state["step"] + 1},
                {"loss": loss, "gnorm": gnorm, "lr": lr})
    return step


class _ProgramLoader:
    """Deterministic, exactly-resumable batch stream for an arbitrary
    compiled program: batches are a pure function of (seed, step) over
    ``CompiledProgram.input_shapes()`` — the elastic demo's stand-in for
    the token pipeline (same ``state_dict`` contract)."""

    def __init__(self, shapes: dict, vocab: int, seed: int = 0) -> None:
        import numpy as np
        from repro.data import DataState
        self._np = np
        self.shapes = dict(sorted(shapes.items()))
        self.vocab = vocab
        self.state = DataState(seed=seed)

    def next_batch(self) -> dict:
        np = self._np
        rng = np.random.Generator(np.random.Philox(
            key=self.state.seed, counter=[0, 0, 2, self.state.step]))
        batch = {}
        for name, (shape, dtype) in self.shapes.items():
            dt = np.dtype(dtype)
            if np.issubdtype(dt, np.integer):
                batch[name] = rng.integers(
                    0, self.vocab, size=shape).astype(dt)
            else:
                batch[name] = rng.standard_normal(shape).astype(dt)
        self.state.step += 1
        return batch

    def state_dict(self) -> dict:
        return self.state.to_dict()

    def load_state_dict(self, d: dict) -> None:
        from repro.data import DataState
        self.state = DataState.from_dict(d)


def run_elastic(prog, params, vocab: int, args, schedule=None) -> int:
    """The --elastic demo: train, lose a rank, shrink, resume.  With a
    --chaos schedule, the scripted faults replace the single kill and
    the supervisor additionally regrows on arrivals, rewinds on NaN
    spikes, skips corrupted checkpoints and rebalances microbatches."""
    import shutil
    import tempfile

    from repro.checkpoint import CheckpointManager
    from repro.ft import (ChaosInjector, ElasticError, ElasticSupervisor,
                          RankFailureInjector)

    world = prog.strategy.mesh.n_devices
    n_steps = args.elastic_steps
    loader = _ProgramLoader(prog.input_shapes(), vocab, seed=17)

    if schedule is not None:
        injector = ChaosInjector(schedule)
        what = (f"chaos schedule: {len(schedule.events)} events "
                f"{schedule.kinds()} seed={schedule.seed}")
    else:
        fail_at = (args.elastic_fail_at
                   if args.elastic_fail_at is not None
                   else max(1, n_steps // 2))
        rank = (args.elastic_kill_rank
                if args.elastic_kill_rank is not None else world - 1)
        injector = RankFailureInjector({fail_at: rank})
        what = f"rank {rank} dies at step {fail_at}"

    # the registry's runner-factory shape IS the supervisor's contract:
    # factory(prog, params, physical_devices) -> executor
    from repro.runtime.executor import executor_factory, get_backend_spec
    caps = get_backend_spec(args.backend).capabilities
    opts = {"track_memory": False} if caps.memory_ledgers else {}
    runner_factory = executor_factory(args.backend, **opts)

    ckpt_dir = tempfile.mkdtemp(prefix="repro_elastic_")
    try:
        sup = ElasticSupervisor(
            prog, CheckpointManager(ckpt_dir, keep=4, async_save=False),
            loader, runner_factory=runner_factory,
            checkpoint_every=args.elastic_ckpt_every,
            injector=injector, rebalance=schedule is not None)
        print(f"elastic[{args.backend}] world={world} steps={n_steps} "
              f"({what}, checkpoint every {args.elastic_ckpt_every})")
        t0 = time.time()
        try:
            sup.run(params, n_steps, log_every=1)
        except ElasticError as e:
            print(f"elastic: {e}")
            return 2
        wall = time.time() - t0
        for r in sup.reports:
            if r.shrunk_axis:
                print(f"elastic: recovered from rank {r.failed_rank} "
                      f"loss — world {r.old_world}->{r.new_world} "
                      f"(shrunk {r.shrunk_axis}), {r.steps_lost} steps "
                      f"lost, recovery {r.recovery_seconds:.2f}s "
                      f"(compile {r.compile_seconds:.2f}s, "
                      f"cache_hit={r.cache_hit})")
            else:
                print(f"elastic: numerical rewind at step "
                      f"{r.step_failed} — {r.steps_lost} steps lost")
        for g in sup.growths:
            print(f"elastic: regrew world {g.old_world}->{g.new_world} "
                  f"(grew {g.grown_axis}) at step {g.step}, "
                  f"{g.steps_lost} steps lost")
        for b in sup.rebalances:
            print(f"elastic: rebalanced microbatches at step {b.step}: "
                  f"{b.split}")
        if schedule is not None:
            report = sup.chaos_report(n_steps, wall_seconds=wall)
            if args.chaos_report:
                out = pathlib.Path(args.chaos_report)
                out.parent.mkdir(parents=True, exist_ok=True)
                out.write_text(report.to_json())
                print(f"elastic: chaos report written to {out}")
            print(f"elastic: chaos summary — "
                  f"{len(report.recoveries)} recoveries, "
                  f"{len(report.growths)} regrowths, "
                  f"{len(report.rebalances)} rebalances, "
                  f"{report.numeric_rewinds} NaN rewinds, "
                  f"{report.corrupt_detected} corrupt checkpoints "
                  f"skipped, {report.steps_lost_total} total steps "
                  f"lost, final world {report.final_world}")
            return 0
        if not sup.reports:
            print("elastic: no failure fired (check --elastic-fail-at)")
            return 2
        return 0
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--resume", action="store_true")
    # declarative Strategy API (repro.core.strategy): replay a saved
    # strategy JSON — validate it, compile the full config's proxy
    # program through compile_training(strategy=...), and report the
    # simulator-predicted step time / peak memory before training
    ap.add_argument("--strategy", default=None, metavar="JSON",
                    help="path to a Strategy JSON document "
                    "(e.g. the strategy.json --autotune saves)")
    from repro.runtime.executor import backends_help, list_backends
    ap.add_argument("--backend", default=None,
                    choices=list(list_backends()),
                    help="execute one real training step of the "
                    "replayed --strategy on the reduced config's proxy "
                    "program on the named runtime backend — "
                    + backends_help())
    # elastic fault tolerance (repro.ft.elastic): run a short training
    # loop on the replayed --strategy, kill a rank mid-run, and let the
    # supervisor shrink the mesh, recompile, restore and resume
    ap.add_argument("--elastic", action="store_true",
                    help="with --strategy and --backend: train a few "
                    "steps, kill one rank mid-run, and recover by "
                    "recompiling the same strategy for the shrunk mesh "
                    "(docs/elasticity.md has a quickstart)")
    ap.add_argument("--chaos", default=None, metavar="JSON",
                    help="path to a FaultSchedule JSON document "
                    "(docs/elasticity.md) scripting kills, arrivals, "
                    "stragglers, checkpoint corruption and NaN spikes; "
                    "implies --elastic (needs --strategy and --backend)")
    ap.add_argument("--chaos-report", default=None, metavar="PATH",
                    help="with --chaos: write the run's ChaosReport "
                    "JSON here")
    ap.add_argument("--elastic-steps", type=int, default=8)
    ap.add_argument("--elastic-fail-at", type=int, default=None,
                    help="step at which the rank dies "
                    "(default: elastic-steps // 2)")
    ap.add_argument("--elastic-kill-rank", type=int, default=None,
                    help="which logical rank dies (default: last)")
    ap.add_argument("--elastic-ckpt-every", type=int, default=3)
    # strategy autotuner (repro.tune): pick PP schedule / microbatches /
    # ZeRO / EP for the FULL config before training the reduced one
    ap.add_argument("--autotune", action="store_true",
                    help="search the strategy space for the full config "
                    "and print/save the winning plan before training")
    ap.add_argument("--tune-pp", type=int, default=4)
    ap.add_argument("--tune-dp", type=int, default=2)
    ap.add_argument("--tune-budget-gb", type=float, default=None,
                    help="per-device HBM budget in GiB (default: none)")
    ap.add_argument("--memory-budget", type=float, default=None,
                    metavar="GIB",
                    help="per-device memory budget in GiB, enforced on "
                    "both paths: a --strategy whose estimated peak "
                    "exceeds it is rejected, and --autotune only "
                    "considers candidates that fit (supersedes "
                    "--tune-budget-gb; sweep Remat policies via "
                    "tune.SearchSpace(remat_policies=...))")
    ap.add_argument("--tune-tokens", type=int, default=None,
                    help="global tokens/step for the tuner (default: "
                    "repro.tune.DEFAULT_TOKENS)")
    args = ap.parse_args(argv)

    base = get_config(args.arch)
    budget_bytes = None
    if args.memory_budget is not None:
        budget_bytes = int(args.memory_budget * 2**30)
    elif args.tune_budget_gb is not None:
        budget_bytes = int(args.tune_budget_gb * 2**30)

    if args.backend and not args.strategy:
        ap.error("--backend needs a --strategy document to execute")
    chaos_schedule = None
    if args.chaos:
        from repro.ft import ChaosScheduleError, FaultSchedule
        try:
            chaos_schedule = FaultSchedule.from_json(
                pathlib.Path(args.chaos).read_text())
        except (ChaosScheduleError, OSError) as e:
            print(f"chaos: {e}")
            return 2
        args.elastic = True
    if args.elastic and not (args.strategy and args.backend):
        ap.error("--elastic needs --strategy and --backend "
                 f"(one of: {', '.join(list_backends())})")

    if args.strategy:
        from repro import tune
        from repro.core.strategy import Strategy, StrategyError
        # parse before anything touches jax devices: --backend spmd must
        # fake the mesh's host device count before the backend locks it
        try:
            strat = Strategy.from_json(
                pathlib.Path(args.strategy).read_text())
        except (StrategyError, OSError) as e:
            print(f"strategy: {e}")
            return 2
        from repro.runtime.executor import get_backend_spec
        backend_caps = (get_backend_spec(args.backend).capabilities
                        if args.backend else None)
        if backend_caps is not None and backend_caps.real_xla:
            # a real-XLA backend must fake the mesh's host device count
            # BEFORE anything touches jax devices (capability flag, not
            # a backend-name compare)
            if strat.mesh is None:
                print(f"strategy: --backend {args.backend} needs a "
                      "structured strategy with a Mesh (mesh-less "
                      "documents have no device count to fake)")
                return 2
            from repro.launch.hostdevices import ensure_host_devices
            if backend_caps.multi_controller:
                # multi-controller transports block inside host
                # callbacks; async CPU dispatch would let parked ranks
                # starve their peers' programs (runtime/mpmd.py,
                # _ensure_sync_cpu_dispatch).  Cheapest here, before
                # the client exists — the executor rebuilds the client
                # otherwise
                jax.config.update("jax_cpu_enable_async_dispatch",
                                  False)
            n_dev = strat.mesh.n_devices
            if chaos_schedule is not None:
                # arrivals name physical device indices beyond the
                # original world — fake enough host devices for them
                for ev in chaos_schedule.events:
                    for d in ev.devices:
                        n_dev = max(n_dev, int(d) + 1)
            ensure_host_devices(n_dev)
        tokens = args.tune_tokens or tune.DEFAULT_TOKENS
        try:
            prog, sm = tune.build_strategy_program(base, strat, tokens)
        except (StrategyError, ValueError, OSError) as e:
            print(f"strategy: {e}")
            return 2
        score = tune.score_strategy(base, strat, tokens=tokens,
                                    budget_bytes=budget_bytes,
                                    program=(prog, sm))
        print(f"strategy[{base.name}] {strat.label()}  "
              f"step={score.step_seconds*1e3:.2f}ms  "
              f"peak={score.peak_bytes/2**30:.2f}GiB  "
              f"({prog.stats['chunks']} chunks, "
              f"{prog.stats['comms']} comms, "
              f"{prog.stats['devices']} devices)")
        if not score.feasible:
            print(f"strategy: estimated peak {score.peak_bytes/2**30:.2f}"
                  f"GiB exceeds --memory-budget "
                  f"{budget_bytes/2**30:.2f}GiB — pick a higher-Remat/"
                  "lower-mb strategy or raise the budget")
            return 2

        if args.backend:
            # one REAL training step of the same strategy document, on
            # the reduced config's proxy program (the full-size proxy
            # would be untractable on host devices)
            exec_cfg = base.reduced(
                n_layers=args.layers, d_model=args.d_model,
                d_ff=args.d_model * 4, vocab=args.vocab,
                n_heads=max(4, args.d_model // 64))
            pipe = strat.pipeline
            # per-microbatch tokens must shard over each stage's
            # replicate group — its width is every non-pipeline axis,
            # whatever the data axis is named
            group = (strat.mesh.n_devices
                     // strat.mesh.axis_size(pipe.axis)
                     if strat.mesh else 1)
            tokens_exec = pipe.n_mb * max(group, 1) * 8
            prog2, _ = tune.build_strategy_program(exec_cfg, strat,
                                                   tokens_exec)
            # the proxy compiles against ShapeDtypeStructs; real
            # execution materializes them (small: the REDUCED config)
            batch = tune.synth_batch(prog2)
            params_real = tune.materialize_params(prog2.params)
            if args.elastic:
                return run_elastic(prog2, params_real,
                                   exec_cfg.vocab, args,
                                   schedule=chaos_schedule)
            from repro.runtime.executor import make_executor
            ex = make_executor(args.backend, prog2, params=params_real)
            res = ex.run(batch)
            if backend_caps.measured_time:
                ms = ex.measure(batch, reps=3) * 1e3
                print(f"backend[{args.backend}] loss={res.loss:.6f}  "
                      f"measured_step={ms:.2f}ms on "
                      f"{res.stats['devices']} host devices "
                      f"({res.stats['tasks']} plan tasks)")
            else:
                print(f"backend[{args.backend}] loss={res.loss:.6f}  "
                      f"peak={res.max_peak()/2**20:.2f}MiB "
                      f"({res.stats['tasks']} plan tasks)")
            return 0

    if args.autotune:
        from repro import tune
        mesh = tune.MeshSpec(pp=args.tune_pp, dp=args.tune_dp)
        budget = budget_bytes
        tokens = args.tune_tokens or tune.DEFAULT_TOKENS
        try:
            plan = tune.search(base, mesh, budget, tokens=tokens)
        except tune.NoFeasiblePlanError as e:
            print(f"autotune: {e}")
            print("autotune: raise --tune-budget-gb, --tune-pp/--tune-dp,"
                  " or shrink the model")
            return 2
        print(plan.summary())
        plan_path = pathlib.Path(args.ckpt_dir) / base.name / "plan.json"
        plan_path.parent.mkdir(parents=True, exist_ok=True)
        import json
        plan_path.write_text(json.dumps(plan.to_dict(), indent=1))
        strat_path = plan_path.with_name("strategy.json")
        strat_path.write_text(plan.strategy().to_json())
        print(f"plan saved to {plan_path} "
              f"({len(plan.directives())} directives); winning strategy "
              f"saved to {strat_path} (replay with --strategy)")
    cfg = base.reduced(n_layers=args.layers, d_model=args.d_model,
                       d_ff=args.d_model * 4, vocab=args.vocab,
                       n_heads=max(4, args.d_model // 64))
    n_params = cfg.param_count()
    print(f"arch={cfg.name} ({cfg.family}) reduced to "
          f"{n_params/1e6:.1f}M params, {args.steps} steps "
          f"batch={args.batch} seq={args.seq}")

    params = init(cfg, jax.random.PRNGKey(0))
    state = {"params": params, "opt": adamw_init(params),
             "step": jnp.zeros((), jnp.int32)}
    lr_fn = (wsd_schedule(args.lr, args.steps)
             if "minicpm" in args.arch else
             cosine_schedule(args.lr, args.steps))
    step_fn = build_step(cfg, lr_fn)

    loader = TokenLoader(SyntheticTokenSource(cfg.vocab, seed=17),
                         batch=args.batch, seq=args.seq)
    ckpt = CheckpointManager(pathlib.Path(args.ckpt_dir) / cfg.name,
                             keep=2)
    sup = Supervisor(ckpt, loader, checkpoint_every=args.ckpt_every,
                     injector=FailureInjector(tuple(args.fail_at)))

    if args.resume and ckpt.latest_step() is not None:
        state, extra = ckpt.restore(state)
        loader.load_state_dict(extra["data"])
        print(f"resumed from step {extra['step']}")

    t0 = time.time()
    state = sup.run(state, step_fn, args.steps)
    wall = time.time() - t0
    losses = [h["loss"] for h in sup.history]
    print(f"done: {len(sup.history)} steps in {wall:.1f}s "
          f"({args.batch*args.seq*len(sup.history)/wall:.0f} tok/s) — "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}, "
          f"restarts={sup.restarts}, stragglers={len(sup.watchdog.events)}")
    assert losses[-1] < losses[0], "loss did not decrease"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
