"""Collective-traffic extraction from compiled (post-SPMD-partitioning)
HLO text.  cost_analysis() gives FLOPs and bytes accessed but not
collective bytes, so the roofline's third term comes from the collective
ops in the per-device module (task spec: ROOFLINE ANALYSIS).

Optimized HLO prints operands untyped, so per-op bytes come from the
LHS output type, adjusted per kind to operand ('payload') bytes:
  all-gather      operand = output / group   (output is the gathered buf)
  reduce-scatter  operand = output * group
  all-reduce / all-to-all / collective-permute: operand = output

NOTE: ops inside while loops appear once in the text; the dry-run
corrects trip counts via unrolled probe compiles (launch/dryrun.py).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute")
OP_RE = re.compile(
    r"=\s+(\([^)]*\)|\S+)\s+(all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)(-start)?\(")
SHAPE_RE = re.compile(r"\b(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|s32|s16|"
                      r"s8|u64|u32|u16|u8|pred|c64|c128)\[([0-9,]*)\]")
GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes per collective kind over the per-device module.
    '-done' ops are skipped ('-start' carries the shape)."""
    per_kind: dict[str, int] = defaultdict(int)
    counts: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = OP_RE.search(line)
        if m is None or "-done" in line.split("=")[0]:
            continue
        out_types, kind = m.group(1), m.group(2)
        out_bytes = sum(_shape_bytes(d, dims)
                        for d, dims in SHAPE_RE.findall(out_types))
        if out_bytes == 0:
            continue
        g = GROUPS_RE.search(line)
        group = int(g.group(2)) if g else 1
        if kind == "all-gather":
            nbytes = out_bytes // max(group, 1)
        elif kind == "reduce-scatter":
            nbytes = out_bytes * max(group, 1)
        else:
            nbytes = out_bytes
        per_kind[kind] += nbytes
        counts[kind] += 1
    total = sum(per_kind.values())
    return {"total_bytes": total,
            "per_kind_bytes": dict(per_kind),
            "per_kind_count": dict(counts)}


def hlo_op_histogram(hlo_text: str, top: int = 12) -> list[tuple[str, int]]:
    ops: dict[str, int] = defaultdict(int)
    for m in re.finditer(r"=\s+\S+\s+([a-z0-9-]+)\(", hlo_text):
        ops[m.group(1)] += 1
    return sorted(ops.items(), key=lambda kv: -kv[1])[:top]
