"""Pure-jnp oracles for every Pallas kernel (single source of truth —
re-exported from the model layers, where the same functions serve as
the CPU/compile-anywhere implementations)."""
from ..models.attention import naive_attention  # noqa: F401
from ..models.attention import _flash_fwd_impl, flash_attention_ref  # noqa: F401
from ..models.layers import moe_gmm_ref  # noqa: F401
from ..models.layers import rmsnorm_ref, ssm_scan_ref  # noqa: F401
