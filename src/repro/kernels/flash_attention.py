"""FlashAttention-2 forward Pallas kernel (TPU target, validated with
interpret=True on CPU).

Canonical TPU structure: grid (batch*q_heads, q_blocks, kv_blocks) with
the KV dimension innermost — TPU grids execute sequentially over the
last axis, so the online-softmax state (m, l, acc) lives in VMEM scratch
and carries across kv steps; the output tile is written on the last kv
step.  Q/K/V tiles are MXU-aligned (block sizes multiples of 128 at
production shapes; tests sweep smaller blocks in interpret mode).

The backward pass reuses the pure-jnp flash backward from
``repro.models.attention`` (same math as the FA2 paper); a dedicated
backward kernel is a further optimization the wrapper can swap in.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                      *, sm_scale: float, causal: bool, block_q: int,
                      block_kv: int, n_kv: int, skv: int, q_offset: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * sm_scale          # (bq, d)
    k = k_ref[0].astype(jnp.float32)                     # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = q @ k.T                                          # (bq, bk)

    qpos = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0) + q_offset
    kpos = ik * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)
    mask = kpos < skv
    if causal:
        mask &= kpos <= qpos
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + p @ v
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention_fwd_pallas(q, k, v, *, causal: bool = True,
                               q_offset: int = 0,
                               block_q: int = 128, block_kv: int = 128,
                               interpret: bool = True) -> jax.Array:
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D) with Hq % Hkv == 0.
    GQA is handled by flattening (B, Hq) and indexing kv heads."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    n_rep = hq // hkv
    bq = min(block_q, sq)
    while sq % bq:
        bq //= 2
    bq = max(bq, 1)
    nk = -(-skv // block_kv)
    pad = nk * block_kv - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hkv, nk * block_kv, d)
    vf = v.reshape(b * hkv, nk * block_kv, d)

    kernel = functools.partial(
        _flash_fwd_kernel, sm_scale=d ** -0.5, causal=causal,
        block_q=bq, block_kv=block_kv, n_kv=nk, skv=skv,
        q_offset=q_offset)
    out = pl.pallas_call(
        kernel,
        grid=(b * hq, sq // bq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_kv, d),
                         lambda h, i, j, n_rep=n_rep: (h // n_rep, j, 0)),
            pl.BlockSpec((1, block_kv, d),
                         lambda h, i, j, n_rep=n_rep: (h // n_rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # running max m
            pltpu.VMEM((bq,), jnp.float32),       # running denom l
            pltpu.VMEM((bq, d), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, sq, d)
