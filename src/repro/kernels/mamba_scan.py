"""Selective-scan (Mamba1) Pallas kernel.

The recurrence h_t = exp(dt_t*A) * h_{t-1} + dt_t*B_t*x_t is independent
per channel, so the grid tiles (batch, channel-blocks); each kernel
instance keeps its (BLOCK_C, N) state in VMEM and runs a fori_loop over
the sequence.  The decay terms are built per-step in registers — the
(S, C, N) tensor the naive lowering materializes never exists.

TPU adaptation note (DESIGN.md §6): CUDA Mamba kernels parallelize the
scan across warps with shuffles; the TPU-native structure is
channel-block parallelism over the grid with a sequential VMEM-resident
inner loop (the VPU pipelines the elementwise recurrence), plus the
chunked formulation at the JAX level for sequence-level parallelism.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_C = 128


def mamba_scan_pallas(xz, dt, A, B, C, D, h0=None,
                      block_c: int = BLOCK_C,
                      interpret: bool = True):
    """Same contract as models.layers.ssm_scan_ref:
    xz/dt: (B,S,C); A: (C,N); B,C: (B,S,N); D: (C,).
    Returns (y (B,S,C), hT (B,C,N))."""
    b, s, c = xz.shape
    n = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((b, c, n), jnp.float32)
    bc = min(block_c, c)
    while c % bc:
        bc //= 2
    bc = max(bc, 1)
    # channel-major layout for clean (bc,) slices per step
    xt = xz.swapaxes(1, 2)        # (B, C, S)
    dtt = dt.swapaxes(1, 2)

    def kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, h0_ref, y_ref, hT_ref):
        A_blk = A_ref[...].astype(jnp.float32)
        h = h0_ref[0].astype(jnp.float32)

        def step(t, h):
            x_t = x_ref[0, :, t].astype(jnp.float32)
            dt_t = dt_ref[0, :, t].astype(jnp.float32)
            B_t = B_ref[0, t].astype(jnp.float32)
            C_t = C_ref[0, t].astype(jnp.float32)
            dA = jnp.exp(dt_t[:, None] * A_blk)
            h = h * dA + (dt_t * x_t)[:, None] * B_t[None, :]
            y_ref[0, :, t] = (h @ C_t).astype(y_ref.dtype)
            return h

        hT = jax.lax.fori_loop(0, s, step, h)
        hT_ref[0] = hT.astype(hT_ref.dtype)

    y_cm, hT = pl.pallas_call(
        kernel,
        grid=(b, c // bc),
        in_specs=[
            pl.BlockSpec((1, bc, s), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bc, s), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bc, n), lambda i, j: (j, 0)),
            pl.BlockSpec((1, s, n), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, s, n), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, bc, n), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bc, s), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bc, n), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, c, s), xz.dtype),
            jax.ShapeDtypeStruct((b, c, n), jnp.float32),
        ],
        interpret=interpret,
    )(xt, dtt, A, B, C, h0)
    y = y_cm.swapaxes(1, 2) + xz * D.astype(xz.dtype)
    return y, hT
