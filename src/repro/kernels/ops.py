"""Jitted wrappers for the Pallas kernels + impl-registry hookup.

On TPU the kernels compile natively; everywhere else they run with
``interpret=True`` (the kernel body executes step-by-step on CPU), which
is how correctness is validated in this container.  ``register_kernels``
swaps them into the model layers' impl registry.
"""
from __future__ import annotations

import functools

import jax

from ..models import layers as L
from ..models.attention import _flash_bwd
from .flash_attention import flash_attention_fwd_pallas
from .mamba_scan import mamba_scan_pallas
from .moe_gmm import moe_gmm_pallas
from .rmsnorm import rmsnorm_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---- flash attention: Pallas forward + jnp flash backward (custom VJP)

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash(q, k, v, causal, q_offset, block_kv):
    return flash_attention_fwd_pallas(q, k, v, causal=causal,
                                      q_offset=q_offset,
                                      block_kv=block_kv,
                                      interpret=_interpret())


def _flash_fwd_rule(q, k, v, causal, q_offset, block_kv):
    out = flash_attention_fwd_pallas(q, k, v, causal=causal,
                                     q_offset=q_offset,
                                     block_kv=block_kv,
                                     interpret=_interpret())
    # recompute lse in the backward (flash bwd needs it); cheap relative
    # to storing per-block probabilities
    from ..models.attention import _flash_fwd_impl
    _, lse = _flash_fwd_impl(q, k, v, causal, q_offset, None, block_kv)
    return out, (q, k, v, out, lse)


def _flash_bwd_rule(causal, q_offset, block_kv, res, dout):
    return _flash_bwd(causal, q_offset, None, block_kv, False, res, dout)


_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def flash_attention(q, k, v, *, causal=True, q_offset=0, sm_scale=None,
                    window=None, block_kv=128):
    if window is not None:
        # windowed variant not kernelized yet -> jnp flash path
        from ..models.attention import flash_attention_ref
        return flash_attention_ref(q, k, v, causal=causal,
                                   q_offset=q_offset, window=window,
                                   block_kv=block_kv)
    return _flash(q, k, v, causal, q_offset, block_kv)


def rmsnorm(x, w, eps=1e-6):
    # eps must stay a python float (the kernel closes over it)
    return rmsnorm_pallas(x, w, float(eps), interpret=_interpret())


def moe_gmm(x, w):
    return moe_gmm_pallas(x, w, interpret=_interpret())


def mamba_scan(xz, dt, A, B, C, D, h0=None, chunk=None):
    return mamba_scan_pallas(xz, dt, A, B, C, D, h0=h0,
                             interpret=_interpret())


def register_kernels(attention=True, norm=True, gmm=True,
                     scan=True) -> None:
    """Install the Pallas kernels as the model-layer implementations."""
    if attention:
        L.register_impl("attention", flash_attention)
    if norm:
        L.register_impl("rmsnorm", lambda x, w, eps=1e-6:
                        rmsnorm(x, w, eps))
    if gmm:
        L.register_impl("moe_gmm", moe_gmm)
    if scan:
        L.register_impl("mamba_scan", mamba_scan)


def unregister_kernels() -> None:
    for k in ("attention", "rmsnorm", "moe_gmm", "mamba_scan"):
        L._IMPLS.pop(k, None)
