"""Grouped (per-expert) matmul Pallas kernel — the MoE compute hot-spot.

y[e] = x[e] @ w[e] for e in experts, tiled (BLOCK_M rows x BLOCK_N cols)
per grid step with the full contraction dim in VMEM (d_model up to 8k:
a 128 x 8192 bf16 tile is 2 MiB — comfortably inside the ~16 MiB VMEM
budget, and MXU-aligned).  Grid: (E, cap/BLOCK_M, f/BLOCK_N).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_M = 128
BLOCK_N = 128


def _gmm_kernel(x_ref, w_ref, o_ref):
    x = x_ref[0].astype(jnp.float32)          # (bm, d)
    w = w_ref[0].astype(jnp.float32)          # (d, bn)
    o_ref[0] = (x @ w).astype(o_ref.dtype)


def moe_gmm_pallas(x: jax.Array, w: jax.Array,
                   block_m: int = BLOCK_M, block_n: int = BLOCK_N,
                   interpret: bool = True) -> jax.Array:
    """x: (E, cap, d), w: (E, d, f) -> (E, cap, f)."""
    e, cap, d = x.shape
    f = w.shape[-1]
    bm = min(block_m, cap)
    while cap % bm:
        bm //= 2
    bm = max(bm, 1)
    bn = min(block_n, f)
    while f % bn:
        bn //= 2
    bn = max(bn, 1)
    return pl.pallas_call(
        _gmm_kernel,
        grid=(e, cap // bm, f // bn),
        in_specs=[
            pl.BlockSpec((1, bm, d), lambda ei, i, j: (ei, i, 0)),
            pl.BlockSpec((1, d, bn), lambda ei, i, j: (ei, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda ei, i, j: (ei, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, cap, f), x.dtype),
        interpret=interpret,
    )(x, w)
