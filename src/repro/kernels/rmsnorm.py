"""Fused RMSNorm Pallas kernel (TPU target, validated interpret=True).

Memory-bound op: fusing the mean-square reduction, rsqrt and scale into
one VMEM pass saves two HBM round-trips vs the unfused lowering.
Rows are tiled (BLOCK_ROWS, D) into VMEM; D stays whole (lane dim,
multiples of 128 for the VPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 128


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)).astype(o_ref.dtype) \
        * w_ref[...]


def rmsnorm_pallas(x: jax.Array, w: jax.Array, eps: float = 1e-6,
                   block_rows: int = BLOCK_ROWS,
                   interpret: bool = True) -> jax.Array:
    """x: (..., D), w: (D,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    n = x.size // d
    x2 = x.reshape(n, d)
    br = min(block_rows, n)
    while n % br:
        br //= 2
    br = max(br, 1)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(x2, w)
    return out.reshape(orig_shape)
