"""Architecture config registry: one module per assigned architecture
(+ the paper's own Qwen3 models).  ``get_config(name)`` returns the full
ArchConfig; ``get_config(name).reduced()`` is the CPU smoke-test config.
"""
from __future__ import annotations

import importlib

ARCHS = [
    "minicpm-2b", "qwen1.5-0.5b", "qwen2.5-32b", "granite-20b",
    "dbrx-132b", "deepseek-moe-16b", "falcon-mamba-7b",
    "whisper-large-v3", "qwen2-vl-7b", "zamba2-2.7b",
    # the paper's own evaluation models
    "qwen3-1b", "qwen3-9b",
]

# the ten assigned-architecture cells for the dry-run table
ASSIGNED = ARCHS[:10]


def get_config(name: str):
    mod = importlib.import_module(
        f"repro.configs.{name.replace('-', '_').replace('.', '_')}")
    return mod.CONFIG


def all_configs():
    return {name: get_config(name) for name in ARCHS}
