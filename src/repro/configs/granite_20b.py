"""Granite-20B code model [arXiv:2405.04324; hf] — llama-arch with MQA.
52L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152."""
from repro.models import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab=49152,
    qkv_bias=False, tie_embeddings=False,
    act="swiglu", norm="rmsnorm", rope=True,
    source="arXiv:2405.04324; hf",
)
