"""Zamba2-2.7B [arXiv:2411.15242; hf] — hybrid: Mamba2 backbone with a
single SHARED attention+MLP block applied every 6 layers (weight tied
across applications — the paper's tied-bucket case, DESIGN.md §4).
54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000 ssm_state=64.
Sub-quadratic decode (SSM states + sliding-window shared attention) ->
runs the long_500k shape."""
from repro.models import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000,
    act="swiglu", norm="rmsnorm", rope=True,
    ssm=SSMCfg(state=64, version=2, d_conv=4, expand=2, headdim=64),
    hybrid_every=6, sliding_window=4096,
    subquadratic=True,
    source="arXiv:2411.15242; hf",
)
