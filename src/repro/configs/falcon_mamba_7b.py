"""Falcon-Mamba-7B [arXiv:2410.05355] — pure Mamba1, attention-free.
64L d_model=4096 vocab=65024 ssm_state=16.  Sub-quadratic decode ->
runs the long_500k shape."""
from repro.models import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=65024,
    act="swiglu", norm="rmsnorm", rope=False,
    ssm=SSMCfg(state=16, version=1, d_conv=4, expand=2),
    subquadratic=True,
    source="arXiv:2410.05355 (unverified)",
)
