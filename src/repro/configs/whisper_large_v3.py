"""Whisper-large-v3 [arXiv:2212.04356] — encoder-decoder; the conv/mel
frontend is a STUB: ``input_specs`` feeds precomputed frame embeddings
(B, enc_seq, d_model).  32 enc + 32 dec layers, d_model=1280 20H
d_ff=5120 vocab=51866.  (Deviation noted in DESIGN.md: rope+rmsnorm
instead of learned-pos+layernorm — backbone compute is unchanged.)"""
from repro.models import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, n_enc_layers=32, enc_seq=1500,
    d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab=51866,
    qkv_bias=False, tie_embeddings=False,
    act="gelu", norm="rmsnorm", rope=True,
    source="arXiv:2212.04356 (unverified)",
)
