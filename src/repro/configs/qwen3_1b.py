"""Qwen3 ~1B — the paper's own evaluation model (§6.1).
Dimensions follow Qwen3-1.7B: 28L d_model=2048 16H (GQA kv=8)
d_ff=6144 vocab=151936."""
from repro.models import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-1b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=6144, vocab=151936,
    qkv_bias=False, tie_embeddings=True,
    act="swiglu", norm="rmsnorm", rope=True, rope_theta=1e6,
    source="hf:Qwen/Qwen3-1.7B (paper evaluation model)",
)
