"""DBRX-132B [hf:databricks/dbrx-base] — fine-grained MoE, 16e top-4.
40L d_model=6144 48H (GQA kv=8) d_ff=10752(per-expert) vocab=100352."""
from repro.models import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab=100352,
    qkv_bias=False, tie_embeddings=False,
    act="swiglu", norm="rmsnorm", rope=True,
    moe=MoECfg(n_experts=16, top_k=4, n_shared=0, d_expert=10752),
    source="hf:databricks/dbrx-base (unverified)",
)
