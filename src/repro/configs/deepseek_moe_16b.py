"""DeepSeekMoE-16B [arXiv:2401.06066; hf] — 2 shared + 64 routed top-6,
fine-grained experts.  28L d_model=2048 16H (kv=16) d_ff=1408(per-expert)
vocab=102400.  (The real model's first layer is a dense FFN; we keep all
layers MoE for uniform stacking — noted in DESIGN.md.)"""
from repro.models import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400,
    qkv_bias=False, tie_embeddings=False,
    act="swiglu", norm="rmsnorm", rope=True,
    moe=MoECfg(n_experts=64, top_k=6, n_shared=2, d_expert=1408),
    source="arXiv:2401.06066; hf",
)
