"""MiniCPM-2B [arXiv:2404.06395; hf] — dense llama-like, WSD schedule.
40L d_model=2304 36H (GQA kv=36) d_ff=5760 vocab=122753."""
from repro.models import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
    d_ff=5760, vocab=122753,
    qkv_bias=False, tie_embeddings=True,
    act="swiglu", norm="rmsnorm", rope=True,
    source="arXiv:2404.06395; hf",
)

# WSD (warmup-stable-decay) learning-rate schedule is this arch's
# training-specific knob; wired up in repro.optim.schedules.
OPTIM = {"schedule": "wsd", "peak_lr": 1e-2, "warmup_frac": 0.01,
         "decay_frac": 0.1}
