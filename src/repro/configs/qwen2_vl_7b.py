"""Qwen2-VL-7B [arXiv:2409.12191; hf] — VLM backbone with M-RoPE.
28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.  The vision
frontend is a STUB: the backbone consumes token embeddings and
3-stream (t,h,w) M-RoPE position ids from ``input_specs``."""
from repro.models import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab=152064,
    qkv_bias=True, tie_embeddings=False,
    act="swiglu", norm="rmsnorm", rope=True, rope_theta=1e6,
    mrope=True, mrope_sections=(16, 24, 24),
    source="arXiv:2409.12191; hf",
)
