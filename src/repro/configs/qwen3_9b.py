"""Qwen3 ~9B — the paper's own evaluation model (§6.1).
Dimensions follow Qwen3-8B: 36L d_model=4096 32H (GQA kv=8)
d_ff=12288 vocab=151936."""
from repro.models import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-9b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12288, vocab=151936,
    qkv_bias=False, tie_embeddings=False,
    act="swiglu", norm="rmsnorm", rope=True, rope_theta=1e6,
    source="hf:Qwen/Qwen3-8B (paper evaluation model)",
)
