"""Optimizers + LR schedules."""
from .adamw import adamw_init, adamw_update, global_norm
from .schedules import cosine_schedule, wsd_schedule

__all__ = ["adamw_init", "adamw_update", "global_norm",
           "cosine_schedule", "wsd_schedule"]
