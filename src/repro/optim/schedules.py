"""LR schedules.  WSD (warmup-stable-decay) is MiniCPM's schedule
(arXiv:2404.06395); cosine is the default elsewhere."""
from __future__ import annotations

import jax.numpy as jnp


def wsd_schedule(peak_lr: float, total_steps: int,
                 warmup_frac: float = 0.01, decay_frac: float = 0.1,
                 floor: float = 0.1):
    warm = max(1, int(total_steps * warmup_frac))
    decay_start = int(total_steps * (1 - decay_frac))

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm_lr = peak_lr * step / warm
        decay_t = (step - decay_start) / max(1, total_steps - decay_start)
        decay_lr = peak_lr * jnp.exp(jnp.log(floor) *
                                     jnp.clip(decay_t, 0.0, 1.0))
        return jnp.where(step < warm, warm_lr,
                         jnp.where(step < decay_start, peak_lr, decay_lr))
    return lr


def cosine_schedule(peak_lr: float, total_steps: int,
                    warmup_frac: float = 0.01, floor_frac: float = 0.1):
    warm = max(1, int(total_steps * warmup_frac))

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm_lr = peak_lr * step / warm
        t = jnp.clip((step - warm) / max(1, total_steps - warm), 0.0, 1.0)
        cos = floor_frac + (1 - floor_frac) * 0.5 * (
            1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warm, warm_lr, peak_lr * cos)
    return lr
