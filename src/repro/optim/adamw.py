"""AdamW with fp32 moments (m, v), decoupled weight decay and global-norm
clipping.  Pure functions over pytrees so pjit shards the moments with
the ZeRO rules in parallel/sharding.py."""
from __future__ import annotations


import jax
import jax.numpy as jnp


def adamw_init(params) -> dict:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree_util.tree_map(zeros32, params),
            "v": jax.tree_util.tree_map(zeros32, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree_util.tree_leaves(tree)))


def adamw_update(params, grads, opt, lr, *, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1, clip_norm=1.0):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    step = opt["step"] + 1
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        new_p = p.astype(jnp.float32) - lr * (
            mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt["m"])
    flat_v = jax.tree_util.tree_leaves(opt["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}, gnorm
