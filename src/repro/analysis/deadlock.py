"""Deadlock analysis: turn a stuck abstract execution into a wait-for
graph and name the cycle (PIPER001/PIPER002) or the unsatisfiable wait
(PIPER003).

Nodes of the wait-for graph are task keys; edges are the four ways a
task can be blocked in the interpreter's dispatch model:

  ``dep``         an unmet task dependency;
  ``stream``      not at the head of its in-order (device, stream) queue
                  — waits on the current head;
  ``rendezvous``  a collective at its head with deps met, waiting for a
                  group peer;
  ``limiter``     a ZeRO-3 param all-gather blocked by the FSDP-style
                  rate limiter — modeled as a counting semaphore of
                  ``gather_limit`` permits, where the holders are the
                  remaining consumer chunks of the live full-param
                  buffers on the gather's devices.

A cycle through a ``limiter`` edge is PIPER002 (the gather semaphore can
never be released); any other cycle is PIPER001; a wait on a task that
exists in no device plan is PIPER003.
"""
from __future__ import annotations

from typing import Optional

from ..core.plan import ROLE_COLL, GlobalPlan, Task, TaskKey
from .abstract import StuckState
from .diagnostics import Diagnostic, node_provenance


def _task(plan: GlobalPlan, key: TaskKey) -> Optional[Task]:
    dp = plan.device_plans.get(key[1])
    return dp.tasks.get(key) if dp is not None else None


def _fmt_task(dag, key: TaskKey) -> str:
    nid, dev, role = key
    return f"dev{dev}/{role} {node_provenance(dag, nid)}"


def diagnose_stuck(dag, plan: GlobalPlan,
                   stuck: StuckState) -> list[Diagnostic]:
    heads_map = {(d, s): key for (d, s, key) in stuck.heads}

    def at_head(t: Task) -> bool:
        return heads_map.get((t.device, t.stream)) == t.key

    def blocking(key: TaskKey):
        """(wait-for edges, missing dep/peer keys) of one blocked task."""
        t = _task(plan, key)
        if t is None:
            return [], []
        edges: list[tuple[str, TaskKey]] = []
        missing: list[TaskKey] = []
        unmet = [k for k in t.deps if k not in stuck.done]
        for k in unmet:
            if _task(plan, k) is None:
                missing.append(k)
            else:
                edges.append(("dep", k))
        if not at_head(t):
            head = heads_map.get((t.device, t.stream))
            if head is not None and head != key:
                edges.append(("stream", head))
        elif not unmet and t.role == ROLE_COLL:
            for pk in t.peers:
                p = _task(plan, pk)
                if p is None:
                    missing.append(pk)
                elif pk not in stuck.done:
                    # a peer that is itself ready dispatches together
                    # with us — only an *unready* peer is a real wait
                    p_unmet = any(k not in stuck.done for k in p.deps)
                    if p_unmet or not at_head(p):
                        edges.append(("rendezvous", pk))
            for holder in stuck.limiter_blocked.get(key, ()):
                edges.append(("limiter", holder))
        return edges, missing

    # ---- DFS for a cycle over the lazy wait-for graph ---------------------
    all_missing: dict[TaskKey, TaskKey] = {}   # missing key -> waiter
    cycle: Optional[list[tuple[str, TaskKey]]] = None
    visited: set[TaskKey] = set()
    for (_d, _s, root) in stuck.heads:
        if cycle is not None:
            break
        if root in visited:
            continue
        # path holds (edge-kind-into-task, task); iterative DFS
        stack: list[tuple[str, TaskKey, int]] = [("", root, 0)]
        path: list[tuple[str, TaskKey]] = []
        on_path: dict[TaskKey, int] = {}
        frames: list = []
        while stack and cycle is None:
            kind, key, depth = stack.pop()
            del path[depth:]
            for k in list(on_path):
                if on_path[k] >= depth:
                    del on_path[k]
            if key in on_path:
                i = on_path[key]
                cycle = path[i:] + [(kind, key)]
                break
            if key in visited:
                continue
            visited.add(key)
            path.append((kind, key))
            on_path[key] = depth
            edges, missing = blocking(key)
            for mk in missing:
                all_missing.setdefault(mk, key)
            for (ek, tk) in edges:
                if tk in on_path:
                    i = on_path[tk]
                    cycle = path[i + 1:] + [(ek, tk)]
                    break
                if tk not in visited:
                    stack.append((ek, tk, depth + 1))
        del frames

    diags: list[Diagnostic] = []
    if cycle is not None:
        kinds = [k for (k, _) in cycle if k]
        nodes = tuple(dict.fromkeys(key[0] for (_, key) in cycle))
        prov = tuple(node_provenance(dag, n) for n in nodes)
        desc = " -> ".join(
            (f"[{k}] " if k else "") + _fmt_task(dag, key)
            for (k, key) in cycle)
        details = {"cycle": [list(key) for (_, key) in cycle],
                   "edge_kinds": kinds,
                   "executed": stuck.executed, "total": stuck.total,
                   "blocked_heads": [[d, s, list(key)]
                                     for (d, s, key) in stuck.heads]}
        if "limiter" in kinds:
            diags.append(Diagnostic(
                code="PIPER002",
                message=(
                    "gather rate-limiter semaphore cycle: with "
                    f"gather_limit={stuck.gather_limit} in-flight "
                    "full-param buffers, a param all-gather waits on "
                    "consumers of live buffers that transitively wait "
                    f"on it — {desc}"),
                nodes=nodes, provenance=prov,
                details={**details,
                         "gather_limit": stuck.gather_limit}))
        else:
            diags.append(Diagnostic(
                code="PIPER001",
                message=f"cyclic cross-rank wait-for dependency: {desc}",
                nodes=nodes, provenance=prov, details=details))
    for mk, waiter in sorted(all_missing.items()):
        diags.append(Diagnostic(
            code="PIPER003",
            message=(
                f"unsatisfiable wait: {_fmt_task(dag, waiter)} waits on "
                f"task (node={mk[0]}, dev={mk[1]}, role={mk[2]!r}) that "
                "exists in no device plan"),
            nodes=(waiter[0], mk[0]), device=waiter[1],
            provenance=(node_provenance(dag, waiter[0]),
                        node_provenance(dag, mk[0])),
            details={"missing": list(mk), "waiter": list(waiter)}))
    if not diags:
        heads = [f"dev{d}/{s}: {_fmt_task(dag, key)}"
                 for (d, s, key) in stuck.heads[:8]]
        diags.append(Diagnostic(
            code="PIPER001",
            message=("no stream head can make progress "
                     f"({stuck.executed}/{stuck.total} tasks executed); "
                     "blocked heads: " + "; ".join(heads)),
            nodes=tuple(key[0] for (_, _, key) in stuck.heads[:8]),
            details={"blocked_heads": [[d, s, list(key)]
                                       for (d, s, key) in stuck.heads]}))
    return diags
