"""The pass pipeline: ``analyze(prog, depth)`` -> ``AnalysisReport``.

``depth="quick"`` runs the pure graph passes — interface consistency,
communication ordering, stream races, and (unless ``types=False``) the
semantic layer: the shape/dtype/shard typechecker plus the pairwise
per-rank interface-signature check (PIPER020–025, ``types.py``).  They
are a few linear scans of the DAG and plan (no abstract execution),
cheap enough to run on every ``compile_training`` call.

``depth="deep"`` adds the abstract executor: the whole ``GlobalPlan`` is
replayed under the interpreter's dispatch rules (including the gather
rate limiter's counting semaphore).  A stuck replay feeds the deadlock
pass (PIPER001/002/003); a completed one feeds the buffer-lifetime pass
(PIPER006/007/008) plus a PIPER009 cross-check of the abstract ledger's
transient peak against the static timeline estimator.
"""
from __future__ import annotations

from typing import Optional

from .abstract import AbstractExecutor, Execution, StuckState
from .commorder import comm_order_diagnostics
from .deadlock import diagnose_stuck
from .diagnostics import AnalysisReport, Diagnostic
from .interfaces import interface_diagnostics
from .lifetime import lifetime_diagnostics
from .races import race_diagnostics
from .types import rank_interface_diagnostics, type_diagnostics

DEPTHS = ("quick", "deep")

# PIPER009 fires only past a generous slack: the abstract executor
# charges full-param buffers at gather dispatch while the estimator
# charges them at simulated completion, so small timing-model gaps are
# expected — a divergence has to be structural to matter.
_MEM_RATIO = 2.0
_MEM_FLOOR = 1 << 20  # 1 MiB


def _memory_crosscheck(prog, execution: Execution) -> list[Diagnostic]:
    if not prog.dag.meta.get("overlap"):
        # legacy plans charge full-param buffers on a different
        # convention (see memory.timeline_peak_bytes) — not comparable
        return []
    from ..runtime.memory import timeline_peak_bytes
    from ..runtime.simulator import TimelineSimulator
    sim = TimelineSimulator(prog).run()
    est_total = timeline_peak_bytes(prog, sim.records)
    diags: list[Diagnostic] = []
    for d, led in sorted(execution.ledgers.items()):
        abs_peak = led.peak - led.persistent
        est_peak = est_total.get(d, 0) - led.persistent
        hi = max(abs_peak, est_peak)
        lo = min(abs_peak, est_peak)
        if hi > lo * _MEM_RATIO + _MEM_FLOOR:
            diags.append(Diagnostic(
                code="PIPER009", severity="warning",
                message=(
                    f"transient peak memory on dev{d} diverges between "
                    f"the abstract executor ({abs_peak} B) and the "
                    f"static timeline estimator ({est_peak} B) — one of "
                    "the two is mis-charging a buffer lifetime"),
                device=d,
                details={"abstract_peak": abs_peak,
                         "estimator_peak": est_peak,
                         "persistent": led.persistent}))
    return diags


def analyze(prog, depth: str = "quick",
            gather_limit: Optional[int] = None,
            types: bool = True) -> AnalysisReport:
    """Run the static verifier on a compiled program.

    ``types=True`` (the default) includes the semantic layer — the
    shape/dtype/shard typechecker and the pairwise per-rank interface
    signatures (the MPMD-readiness check) — at every depth.

    Returns an :class:`AnalysisReport`; raises nothing — callers decide
    via ``report.raise_if_errors()``.
    """
    if depth not in DEPTHS:
        raise ValueError(f"depth must be one of {DEPTHS}, got {depth!r}")
    dag, plan = prog.dag, prog.plan
    report = AnalysisReport(meta={
        "depth": depth,
        "types": bool(types),
        "devices": len(plan.devices),
        "tasks": sum(p.n_tasks() for p in plan.device_plans.values()),
        "nodes": len(dag.nodes),
    })
    report.extend(interface_diagnostics(dag, plan))
    report.extend(comm_order_diagnostics(dag, plan))
    report.extend(race_diagnostics(dag, plan))
    if types:
        report.extend(type_diagnostics(dag, plan))
        report.extend(rank_interface_diagnostics(dag, plan))
    if depth == "deep":
        outcome = AbstractExecutor(prog, gather_limit=gather_limit).run()
        if isinstance(outcome, StuckState):
            report.meta["abstract"] = (
                f"stuck after {outcome.executed}/{outcome.total} tasks")
            report.extend(diagnose_stuck(dag, plan, outcome))
        else:
            report.meta["abstract"] = (
                f"completed {len(outcome.exec_order)} tasks")
            report.extend(lifetime_diagnostics(dag, outcome))
            report.extend(_memory_crosscheck(prog, outcome))
    return report
