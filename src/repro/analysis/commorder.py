"""Communication-ordering pass (PIPER004/PIPER005).

The same two rules ``scheduler.validate_comm_order`` has always
enforced (paper §4.3.2), upgraded to provenance-carrying diagnostics —
the scheduler now delegates here and raises
:class:`~repro.analysis.diagnostics.PlanVerificationError` (a
``ScheduleRejected``) so existing rejection handling is unchanged:

  (a) all ranks of a (group, stream) communicator must dispatch the
      group's collectives in the same order (PIPER004);
  (b) for each (src, dst, stream) direction, the send order on src must
      equal the recv order on dst (PIPER005).

Messages keep the historical "dispatch order" / "p2p order" phrasing —
callers and tests match on those substrings — and add the first
diverging operation with its origin.
"""
from __future__ import annotations

from collections import defaultdict

from ..core.plan import ROLE_COLL, ROLE_RECV, ROLE_SEND, GlobalPlan
from .diagnostics import Diagnostic, node_provenance


def _first_divergence(dag, a: list, b: list) -> tuple[str, tuple]:
    for i, (x, y) in enumerate(zip(a, b)):
        if x != y:
            return (f"first divergence at position {i}: "
                    f"{node_provenance(dag, x)} vs "
                    f"{node_provenance(dag, y)}", (x, y))
    i = min(len(a), len(b))
    longer = a if len(a) > len(b) else b
    extra = longer[i] if i < len(longer) else None
    if extra is None:
        return "sequences identical", ()
    return (f"first divergence at position {i}: "
            f"{node_provenance(dag, extra)} is missing on the other "
            "rank", (extra,))


def comm_order_diagnostics(dag, plan: GlobalPlan) -> list[Diagnostic]:
    diags: list[Diagnostic] = []

    # (a) collective dispatch order per (group, stream) communicator
    seqs: dict[tuple, dict[int, list[int]]] = defaultdict(dict)
    for d, p in sorted(plan.device_plans.items()):
        for stream, keys in p.streams.items():
            for key in keys:
                nid, _, role = key
                if role != ROLE_COLL or nid not in dag.nodes:
                    continue
                node = dag.nodes[nid]
                comm_key = (tuple(node.group), stream)
                seqs[comm_key].setdefault(d, []).append(nid)
    for (group, stream), per_dev in sorted(seqs.items()):
        items = sorted(per_dev.items())
        ref_dev, ref = items[0]
        for d, seq in items[1:]:
            if seq == ref:
                continue
            where, nodes = _first_divergence(dag, ref, seq)
            diags.append(Diagnostic(
                code="PIPER004",
                message=(
                    "collective dispatch order differs across ranks of "
                    f"group {group} on stream {stream!r}: dev{ref_dev} "
                    f"dispatches {ref} but dev{d} dispatches {seq}; "
                    f"{where}"),
                nodes=tuple(nodes), device=d,
                provenance=tuple(node_provenance(dag, n) for n in nodes),
                details={"group": list(group), "stream": stream,
                         "ref_device": ref_dev, "ref_order": list(ref),
                         "device": d, "order": list(seq)}))
            break  # one diagnostic per communicator is enough

    # (b) p2p send order vs recv order per (src, dst, base stream)
    sends: dict[tuple, list[int]] = defaultdict(list)
    recvs: dict[tuple, list[int]] = defaultdict(list)
    for d, p in sorted(plan.device_plans.items()):
        for stream, keys in p.streams.items():
            for key in keys:
                nid, dev, role = key
                node = dag.nodes.get(nid)
                if node is None:
                    continue
                base = stream.rsplit("#", 1)[0]
                if role == ROLE_SEND:
                    for (s, r) in node.meta["pairs"]:
                        if s == dev:
                            sends[(s, r, base)].append(nid)
                elif role == ROLE_RECV:
                    for (s, r) in node.meta["pairs"]:
                        if r == dev:
                            recvs[(s, r, base)].append(nid)
    for pair_key in sorted(set(sends) | set(recvs)):
        snd = sends.get(pair_key, [])
        rcv = recvs.get(pair_key, [])
        if snd == rcv:
            continue
        where, nodes = _first_divergence(dag, snd, rcv)
        diags.append(Diagnostic(
            code="PIPER005",
            message=(
                f"p2p order mismatch on {pair_key}: sends {snd} vs "
                f"recvs {rcv} — downstream workers must consume "
                "microbatches in the order produced (paper §4.3.2); "
                f"{where}"),
            nodes=tuple(nodes),
            provenance=tuple(node_provenance(dag, n) for n in nodes),
            details={"src": pair_key[0], "dst": pair_key[1],
                     "stream": pair_key[2], "send_order": list(snd),
                     "recv_order": list(rcv)}))
    return diags
