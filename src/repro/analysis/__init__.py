"""Static plan verifier (DESIGN.md §15).

A pass-based analysis layer over the compiled IR: abstractly executes
each rank's ``DevicePlan`` without touching XLA and reports deadlocks,
buffer-lifetime bugs, stream races and interface mismatches as
``Diagnostic`` records with stable ``PIPER`` codes and provenance
(which directive/fragment introduced the offending node).

Entry points:

  ``analyze(prog, depth="quick"|"deep")`` — run the pass pipeline on a
      ``CompiledProgram`` and return an ``AnalysisReport``;
  ``python -m repro.launch.lint`` — CLI surface (single strategy or the
      config × schedule grid), JSON/text output;
  ``compile_training(..., analyze=...)`` — the always-on quick subset.
"""
from .diagnostics import (CODES, AnalysisReport, Diagnostic,
                          PlanVerificationError, node_provenance)
from .verifier import analyze

__all__ = [
    "CODES", "AnalysisReport", "Diagnostic", "PlanVerificationError",
    "analyze", "node_provenance",
]
