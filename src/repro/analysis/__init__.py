"""Static plan verifier (DESIGN.md §15).

A pass-based analysis layer over the compiled IR: abstractly executes
each rank's ``DevicePlan`` without touching XLA and reports deadlocks,
buffer-lifetime bugs, stream races and interface mismatches as
``Diagnostic`` records with stable ``PIPER`` codes and provenance
(which directive/fragment introduced the offending node).

Entry points:

  ``analyze(prog, depth="quick"|"deep", types=True)`` — run the pass
      pipeline on a ``CompiledProgram`` and return an
      ``AnalysisReport``; ``types`` adds the semantic layer — the
      shape/dtype/shard typechecker and the pairwise per-rank interface
      signatures (PIPER020–025);
  ``typecheck(dag)`` / ``rank_signature(dag, plan, r)`` — the semantic
      layer standalone (the latter is the MPMD-readiness surface);
  ``dataflow_fingerprint(dag)`` / ``certify_equivalent(a, b, pass)`` —
      translation validation of compiler passes (PIPER026), run at
      every ``passes.run_all`` boundary under ``REPRO_CHECK_PASSES=1``;
  ``python -m repro.launch.lint`` — CLI surface (single strategy or the
      config × schedule grid), JSON/text output;
  ``compile_training(..., analyze=...)`` — the always-on quick subset.
"""
from .diagnostics import (CODES, AnalysisReport, Diagnostic,
                          PlanVerificationError, node_provenance)
from .equiv import (Fingerprint, certify_equivalent, dataflow_fingerprint,
                    fingerprint_diff)
from .types import (ShardSpec, rank_interface_diagnostics, rank_signature,
                    type_diagnostics, typecheck)
from .verifier import analyze

__all__ = [
    "CODES", "AnalysisReport", "Diagnostic", "Fingerprint",
    "PlanVerificationError", "ShardSpec", "analyze", "certify_equivalent",
    "dataflow_fingerprint", "fingerprint_diff", "node_provenance",
    "rank_interface_diagnostics", "rank_signature", "type_diagnostics",
    "typecheck",
]
