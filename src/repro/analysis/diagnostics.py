"""Diagnostic records for the static plan verifier.

Every finding carries a stable ``PIPER`` code (the catalog below —
documented with worked examples in docs/lint.md), a severity, the
node/task ids involved, and **provenance**: the ``Node.meta["origin"]``
labels threaded through tracing, autodiff, directive application and the
pass layer, so a diagnostic names ``Overlap(prefetch=4, bucket_mb=32)``
or ``ZeRO(stage=3, axis='dp')`` instead of a bare node id.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Optional

from ..core.plan import ScheduleRejected

# The stable code catalog.  Codes are append-only: a released code never
# changes meaning (tests and user tooling match on them).
CODES = {
    "PIPER001": "deadlock: cyclic cross-rank wait-for dependency",
    "PIPER002": "deadlock: gather rate-limiter semaphore cycle (ZeRO-3)",
    "PIPER003": "deadlock: unsatisfiable wait (missing rendezvous peer)",
    "PIPER004": "collective dispatch order differs across ranks",
    "PIPER005": "p2p send/recv order mismatch",
    "PIPER006": "buffer lifetime: use after free",
    "PIPER007": "buffer lifetime: double free",
    "PIPER008": "buffer lifetime: leak (buffer never freed)",
    "PIPER009": "memory accounting diverges from the static estimator",
    "PIPER010": "stream race: unordered access to a shared buffer",
    "PIPER011": "interface mismatch across communication endpoints",
    # -- semantic layer (PR 9): shape/dtype/shard typechecker + the
    #    translation validator (docs/lint.md, DESIGN.md §16) ----------------
    "PIPER020": "dtype mismatch at a data edge",
    "PIPER021": "shape mismatch or unfed/duplicated input slot",
    "PIPER022": "shard-spec disagreement at a collective endpoint",
    "PIPER023": "shape-incompatible collective fusion",
    "PIPER024": "mb_split microbatch token non-conservation",
    "PIPER025": "per-rank interface signature mismatch (MPMD readiness)",
    "PIPER026": "translation validation: pass changed the dataflow "
                "fingerprint",
}

SEVERITIES = ("error", "warning")


def node_provenance(dag, nid: int) -> str:
    """``[17]all_gather:stage0(...) <- ZeRO(stage=3, axis='dp')`` — the
    node's short description plus the origin label that introduced it.
    Nodes a compiler pass *rewrote in place* (remat stash rewrites,
    merged grad reduces, elision survivors) additionally render the pass
    under ``meta["pass"]``: ``... <- autodiff(B of 's0') <-
    pass:apply_remat``."""
    node = dag.nodes.get(nid)
    if node is None:
        return f"[{nid}]<removed node>"
    out = node.short()
    origin = node.meta.get("origin")
    if origin:
        out += f" <- {origin}"
    pass_name = node.meta.get("pass")
    if pass_name:
        out += f" <- pass:{pass_name}"
    return out


@dataclass
class Diagnostic:
    code: str                       # "PIPER001" ...
    message: str                    # one-line human statement
    severity: str = "error"
    nodes: tuple[int, ...] = ()     # DAG node ids involved
    provenance: tuple[str, ...] = ()  # origin labels, parallel-ish to nodes
    device: Optional[int] = None
    details: dict[str, Any] = field(default_factory=dict)

    def __post_init__(self) -> None:
        assert self.code in CODES, f"unknown diagnostic code {self.code}"
        assert self.severity in SEVERITIES

    def format(self) -> str:
        head = f"{self.code} {self.severity}: {self.message}"
        lines = [head]
        for p in self.provenance:
            lines.append(f"    at {p}")
        return "\n".join(lines)

    def to_dict(self) -> dict:
        return {"code": self.code, "title": CODES[self.code],
                "severity": self.severity, "message": self.message,
                "nodes": list(self.nodes),
                "provenance": list(self.provenance),
                "device": self.device, "details": self.details}


@dataclass
class AnalysisReport:
    diagnostics: list[Diagnostic] = field(default_factory=list)
    meta: dict[str, Any] = field(default_factory=dict)  # depth, label, ...

    @property
    def ok(self) -> bool:
        return not self.errors()

    def errors(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "error"]

    def warnings(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "warning"]

    def codes(self) -> list[str]:
        return [d.code for d in self.diagnostics]

    def by_code(self, code: str) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.code == code]

    def extend(self, diags) -> None:
        self.diagnostics.extend(diags)

    def format_text(self) -> str:
        if not self.diagnostics:
            return "plan verified: no diagnostics"
        lines = [d.format() for d in self.diagnostics]
        ne, nw = len(self.errors()), len(self.warnings())
        lines.append(f"{ne} error(s), {nw} warning(s)")
        return "\n".join(lines)

    def to_json(self, **kw) -> str:
        return json.dumps({"meta": self.meta,
                           "ok": self.ok,
                           "diagnostics": [d.to_dict()
                                           for d in self.diagnostics]},
                          **{"indent": 2, **kw})

    def raise_if_errors(self) -> None:
        errs = self.errors()
        if errs:
            raise PlanVerificationError(self)


class PlanVerificationError(ScheduleRejected):
    """A static-analysis pass found error-severity diagnostics.  Subclasses
    ``ScheduleRejected`` so existing rejection handling (spmd executor,
    autotuner candidate pruning) treats a verifier rejection like any
    other invalid schedule."""

    def __init__(self, report: AnalysisReport) -> None:
        self.report = report
        super().__init__(report.format_text())
