"""Buffer-lifetime dataflow pass (PIPER006/007/008).

Consumes a completed :class:`~repro.analysis.abstract.Execution`: the
abstract executor already replayed every free/alloc against the
interpreter's rules, so this pass only has to translate its anomaly
events and leftovers into diagnostics:

  use-after-free / never-materialized reads      -> PIPER006
  a backward accumulating after the final reduce -> PIPER006 (lost update)
  a grad reduce over an empty accumulation stash -> PIPER007
  ledger double-frees                            -> PIPER007
  values / transient buffers live at completion  -> PIPER008 (leak)
"""
from __future__ import annotations

from collections import defaultdict

from .abstract import Execution
from .diagnostics import Diagnostic, node_provenance

# keep pathological plans from drowning the report: per-category cap,
# with the overflow count recorded on the last diagnostic
_CAP = 16


def _capped(diags: list[Diagnostic], total: int) -> list[Diagnostic]:
    if total > len(diags) and diags:
        diags[-1].details["suppressed"] = total - len(diags)
    return diags


def lifetime_diagnostics(dag, execution: Execution) -> list[Diagnostic]:
    diags: list[Diagnostic] = []

    uaf = [(ev, tkey, detail) for (ev, tkey, detail) in execution.events
           if ev in ("uaf", "missing_value")]
    out: list[Diagnostic] = []
    for (ev, tkey, detail) in uaf[:_CAP]:
        src, slot, dev = detail
        what = ("after its last consumer freed it" if ev == "uaf"
                else "but it never materialized on that device")
        out.append(Diagnostic(
            code="PIPER006",
            message=(f"task {tkey[2]}@dev{tkey[1]} of "
                     f"{node_provenance(dag, tkey[0])} reads output "
                     f"{slot} of {node_provenance(dag, src)} on "
                     f"dev{dev} {what}"),
            nodes=(tkey[0], src), device=dev,
            provenance=(node_provenance(dag, tkey[0]),
                        node_provenance(dag, src)),
            details={"kind": ev, "value": [src, slot, dev],
                     "reader": list(tkey)}))
    diags += _capped(out, len(uaf))

    lost = [(tkey, b) for (ev, tkey, b) in execution.events
            if ev == "grad_after_reduce"]
    out = []
    for (tkey, b) in lost[:_CAP]:
        out.append(Diagnostic(
            code="PIPER006",
            message=(f"backward chunk {node_provenance(dag, tkey[0])} on "
                     f"dev{tkey[1]} accumulates gradients into bucket "
                     f"{b!r} after the bucket's final reduction already "
                     "fired — the update is lost"),
            nodes=(tkey[0],), device=tkey[1],
            provenance=(node_provenance(dag, tkey[0]),),
            details={"kind": "grad_after_reduce", "bucket": b}))
    diags += _capped(out, len(lost))

    empty = [(tkey, b) for (ev, tkey, b) in execution.events
             if ev == "reduce_empty"]
    out = []
    for (tkey, b) in empty[:_CAP]:
        out.append(Diagnostic(
            code="PIPER007",
            message=(f"gradient reduction {node_provenance(dag, tkey[0])} "
                     f"fired over an empty accumulation stash for bucket "
                     f"{b!r} — the stash was already consumed by an "
                     "earlier reduce or no backward wrote it yet"),
            nodes=(tkey[0],), device=tkey[1],
            provenance=(node_provenance(dag, tkey[0]),),
            details={"kind": "reduce_empty", "bucket": b}))
    diags += _capped(out, len(empty))

    # raw ledger double-frees: the executor guards its frees against the
    # live set, so any of these left are genuine double releases
    dfree = [(d, key, nb) for d, led in sorted(execution.ledgers.items())
             for (kind, key, nb) in (led.events or ())
             if kind == "double_free"]
    out = []
    for (d, key, nb) in dfree[:_CAP]:
        nid = key[1] if len(key) > 1 and isinstance(key[1], int) else None
        out.append(Diagnostic(
            code="PIPER007",
            message=(f"buffer {key!r} freed twice on dev{d}"),
            nodes=(nid,) if nid is not None else (), device=d,
            provenance=((node_provenance(dag, nid),)
                        if nid is not None and nid in dag.nodes else ()),
            details={"kind": "double_free", "buffer": repr(key)}))
    diags += _capped(out, len(dfree))

    # leaks: group leftover store values by producing node, leftover
    # ledger buffers by (device, buffer kind)
    by_node: dict[int, list[tuple]] = defaultdict(list)
    for (nid, slot, dev) in execution.leftover_values:
        by_node[nid].append((slot, dev))
    out = []
    for nid, slots in sorted(by_node.items())[:_CAP]:
        out.append(Diagnostic(
            code="PIPER008",
            message=(f"{len(slots)} value(s) produced by "
                     f"{node_provenance(dag, nid)} still live at plan "
                     f"completion (slots/devices {sorted(slots)[:6]}) — "
                     "a consumer never ran or the consumer count is "
                     "wrong"),
            nodes=(nid,),
            provenance=(node_provenance(dag, nid),),
            details={"kind": "leaked_values",
                     "slots_devices": [list(x) for x in sorted(slots)]}))
    diags += _capped(out, len(by_node))

    by_buf: dict[tuple, list[tuple]] = defaultdict(list)
    for (d, key, nb) in execution.leftover_buffers:
        by_buf[(d, key[0])].append((key, nb))
    out = []
    for (d, kind), bufs in sorted(by_buf.items(),
                                  key=lambda kv: repr(kv))[:_CAP]:
        total = sum(nb for (_, nb) in bufs)
        nids = [k[1] for (k, _) in bufs
                if len(k) > 1 and isinstance(k[1], int)][:4]
        out.append(Diagnostic(
            code="PIPER008",
            message=(f"{len(bufs)} {kind!r} buffer(s) totalling "
                     f"{total} B still charged on dev{d} at plan "
                     "completion — never freed"),
            nodes=tuple(nids), device=d,
            provenance=tuple(node_provenance(dag, n) for n in nids
                             if n in dag.nodes),
            details={"kind": "leaked_buffers", "buffer_kind": kind,
                     "bytes": total,
                     "buffers": [[repr(k), nb] for (k, nb) in bufs[:8]]}))
    diags += _capped(out, len(by_buf))
    return diags
