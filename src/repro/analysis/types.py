"""Shape/dtype/shard typechecker over the compiled IR (PIPER020–025).

The scheduling side of "directives compose safely" has been statically
checked since PR 8 (deadlock, lifetime, races, comm order).  This module
checks the *semantic* side: every value flowing along a DAG edge carries
a ``ValueSpec`` (shape + dtype) and, at collective endpoints, an implied
shard spec; the typechecker propagates these through every node in
topological order and reports disagreements as stable ``PIPER02x``
codes with directive/pass provenance.

Typing rules (the repo's IR conventions, encoded — not a textbook):

* **compute chunks** type from the trace's abstract values
  (``Node.out_specs`` via ``jax.eval_shape``); every declared input slot
  must be fed exactly once, except cotangent slots (the runtime sums
  multiple cotangent edges on one slot) and the seeded/zero-cotangent
  slots the autodiff pass marks (``seed_slots`` / ``zero_cot_slots``);
* **param all-gathers** (ZeRO-3) take no data in-edges — the shard is
  owned state — and produce the *full* flat bf16 param of their bucket;
  their group must be exactly the bucket's replica group, and a fused
  gather (overlap engine) types as the concat of its members: one output
  slot per member bucket, each the member's full-param spec;
* **grad reduce-scatters / all-reduces** declare the *pre-scatter* grad
  part spec (the runtime shards internally); ``reduce_scatter`` pairs
  with ``Bucket.shard_grads`` and ``all_reduce`` with unsharded grads,
  each over exactly the bucket's replica group;
* **all-to-alls** (expert parallelism) permute tokens across the group
  but preserve shape and dtype;
* **p2p / d2h / h2d** round-trips preserve the spec end to end;
* **``Split``'s microbatch tokens** are conserved: a base input split
  into ``k`` sub-inputs keeps exactly ``k`` live tokens, each consumed
  by its own microbatch's clones, and a ``Pipeline(mb_split=...)``
  assignment re-distributes — never creates or loses — them.

``rank_signature`` / ``rank_interface_diagnostics`` extract each rank's
typed communication interface from ``GlobalPlan.rank_program(r)`` and
check the signatures *pairwise* — the MPMD-readiness gate: a per-rank
(multi-controller) backend has no global trace to cross-check, so the
send/recv and collective sequences of every rank pair must already
agree in type before per-rank programs can be compiled independently
(ROADMAP "MPMD multi-controller backend"; JaxPP, arxiv 2412.14374).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from ..core.dag import TrainingDAG, ValueSpec
from ..core.plan import GlobalPlan
from .diagnostics import Diagnostic, node_provenance


# ---------------------------------------------------------------------------
# shard specs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShardSpec:
    """How a value relates to a device group.

    ``replicated``: every member holds the full value.  ``sharded``:
    each member holds 1/len(group) of axis 0 (ZeRO-3 params at rest,
    post-scatter grads).  ``partial``: each member holds an unreduced
    partial sum (grads before their reduce).  ``local``: single-device
    value, no group semantics."""
    kind: str                       # replicated | sharded | partial | local
    group: tuple[int, ...] = ()

    def short(self) -> str:
        if self.kind == "local" or not self.group:
            return self.kind
        g = list(self.group)
        gs = (f"[{g[0]}..{g[-1]}]x{len(g)}" if len(g) > 4 else str(g))
        return f"{self.kind}@{gs}"


def _full_param_spec(bucket) -> ValueSpec:
    """The full flat bf16 param a ZeRO-3 all-gather materializes
    (matches ``Replicate.apply``)."""
    return ValueSpec((max(bucket.param_bytes // 2, 1),), "bfloat16")


def _grad_part_spec(bucket, n_parts: int) -> ValueSpec:
    """The pre-scatter fp32 grad part a grad reduce declares (matches
    ``Replicate.apply``; the runtime shards reduce-scatter outputs
    internally)."""
    return ValueSpec((max(bucket.param_bytes // 4 // max(n_parts, 1), 1),),
                     "float32")


# ---------------------------------------------------------------------------
# the typechecker
# ---------------------------------------------------------------------------

_TRANSPARENT_OPS = ("p2p", "send", "recv", "d2h", "h2d", "broadcast")
_BACKWARD_PASSES = ("B", "Bi", "Bw")


class _Checker:
    def __init__(self, dag: TrainingDAG) -> None:
        self.dag = dag
        self.diags: list[Diagnostic] = []
        self.in_by_node: dict[int, list] = {}
        self.out_by_node: dict[int, list] = {}
        for e in dag.edges:
            self.in_by_node.setdefault(e.dst, []).append(e)
            self.out_by_node.setdefault(e.src, []).append(e)
        # graph-input feeds per (node, slot)
        self.input_feeds: dict[tuple[int, int], str] = {}
        for name, (_spec, consumers) in dag.inputs.items():
            for (nid, slot) in consumers:
                self.input_feeds[(nid, slot)] = name

    def diag(self, code: str, msg: str, nodes=(), **details) -> None:
        self.diags.append(Diagnostic(
            code=code, message=msg, nodes=tuple(nodes),
            provenance=tuple(node_provenance(self.dag, n) for n in nodes),
            details=details))

    # -- per-edge specs vs producer declarations ----------------------------
    def check_edges(self) -> None:
        dag = self.dag
        for e in dag.edges:
            src = dag.nodes.get(e.src)
            dst = dag.nodes.get(e.dst)
            if src is None or dst is None or e.dst_in < 0:
                # dangling edges are the pass-boundary checker's problem;
                # param-plumbing edges (dst_in < 0) intentionally carry
                # the per-rank shard spec, not the full-param spec
                continue
            if not (0 <= e.src_out < len(src.out_specs)):
                self.diag(
                    "PIPER021",
                    f"edge reads output slot {e.src_out} of "
                    f"{node_provenance(dag, e.src)} which declares only "
                    f"{len(src.out_specs)} outputs",
                    nodes=(e.src, e.dst), slot=e.src_out)
                continue
            declared = src.out_specs[e.src_out]
            if str(declared.dtype) != str(e.spec.dtype):
                self.diag(
                    "PIPER020",
                    f"dtype mismatch: {node_provenance(dag, e.src)} "
                    f"produces {declared.dtype} at slot {e.src_out} but "
                    f"the edge into {node_provenance(dag, e.dst)} slot "
                    f"{e.dst_in} was typed {e.spec.dtype}",
                    nodes=(e.src, e.dst), slot=e.src_out,
                    produced=str(declared.dtype), wired=str(e.spec.dtype))
            elif tuple(declared.shape) != tuple(e.spec.shape) \
                    and not self._accum_part_edge(dst):
                self.diag(
                    "PIPER021",
                    f"shape mismatch: {node_provenance(dag, e.src)} "
                    f"produces {tuple(declared.shape)} at slot "
                    f"{e.src_out} but the edge into "
                    f"{node_provenance(dag, e.dst)} slot {e.dst_in} was "
                    f"typed {tuple(e.spec.shape)}",
                    nodes=(e.src, e.dst), slot=e.src_out,
                    produced=list(declared.shape),
                    wired=list(e.spec.shape))

    def _accum_part_edge(self, dst) -> bool:
        """Multi-part grad reduces (``Replicate(bucket_sz=...)``) consume
        a 1/n_parts slice of the backward chunk's declared grad output —
        the one sanctioned producer/edge shape divergence."""
        return (dst.is_comm and dst.payload == "grad"
                and dst.meta.get("n_parts", 1) > 1)

    # -- chunk input-slot completeness --------------------------------------
    def check_chunk_slots(self) -> None:
        dag = self.dag
        for n in dag.chunks():
            m = n.meta.get("n_inputs")
            if m is None:
                continue   # hand-built chunk with no declared arity
            n_cots = n.meta.get("n_cots", 0)
            cot_start = m - n_cots
            internal = set(n.meta.get("seed_slots", ())) \
                | set(n.meta.get("zero_cot_slots", ()))
            fed: dict[int, int] = {}
            for e in self.in_by_node.get(n.id, []):
                if e.dst_in >= 0:
                    fed[e.dst_in] = fed.get(e.dst_in, 0) + 1
            for (nid, slot), _name in self.input_feeds.items():
                if nid == n.id and slot >= 0:
                    fed[slot] = fed.get(slot, 0) + 1
            for slot in range(m):
                count = fed.get(slot, 0)
                if count == 0 and slot not in internal:
                    kind = ("cotangent" if slot >= cot_start
                            else "residual/data")
                    self.diag(
                        "PIPER021",
                        f"chunk {node_provenance(dag, n.id)} declares "
                        f"{m} inputs but {kind} slot {slot} is unfed "
                        "(no edge, graph input, or seeded cotangent)",
                        nodes=(n.id,), slot=slot)
                elif count > 1 and slot < cot_start:
                    self.diag(
                        "PIPER021",
                        f"chunk {node_provenance(dag, n.id)} input slot "
                        f"{slot} is fed {count} times (only cotangent "
                        "slots may sum multiple edges)",
                        nodes=(n.id,), slot=slot, feeds=count)
            for slot in fed:
                if slot >= m:
                    self.diag(
                        "PIPER021",
                        f"chunk {node_provenance(dag, n.id)} declares "
                        f"{m} inputs but is fed at slot {slot}",
                        nodes=(n.id,), slot=slot)

    # -- collective endpoints ------------------------------------------------
    def check_collectives(self) -> None:
        for n in self.dag.comms():
            if n.op == "all_gather" and n.payload == "param":
                self._check_param_gather(n)
            elif n.payload == "grad" and n.op in ("reduce_scatter",
                                                  "all_reduce"):
                self._check_grad_reduce(n)
            elif n.op == "all_to_all":
                self._check_identity(n, what="all_to_all (permutes "
                                      "tokens, preserves shape/dtype)")
            elif n.op in _TRANSPARENT_OPS:
                self._check_identity(n, what=n.op)

    def _check_param_gather(self, n) -> None:
        dag = self.dag
        data_ins = [e for e in self.in_by_node.get(n.id, [])
                    if e.dst_in >= 0]
        if data_ins:
            self.diag(
                "PIPER022",
                f"param all-gather {node_provenance(dag, n.id)} has "
                f"{len(data_ins)} data in-edges — gathers read the "
                "owned shard, never a dataflow value",
                nodes=(n.id,))
        buckets = n.meta.get("buckets") or (
            [n.meta["bucket"]] if n.meta.get("bucket") else [])
        if not buckets:
            self.diag(
                "PIPER022",
                f"param all-gather {node_provenance(dag, n.id)} names "
                "no param bucket — its payload is untyped",
                nodes=(n.id,))
            return
        fused = len(buckets) > 1 or n.meta.get("fused")
        if len(n.out_specs) != len(buckets):
            self.diag(
                "PIPER023" if fused else "PIPER022",
                f"all-gather {node_provenance(dag, n.id)} carries "
                f"{len(buckets)} bucket(s) but declares "
                f"{len(n.out_specs)} output slot(s) — a fused gather "
                "types as the concat of its members, one slot each",
                nodes=(n.id,), buckets=list(buckets),
                slots=len(n.out_specs))
            return
        group = tuple(n.group or ())
        for i, bname in enumerate(buckets):
            b = dag.buckets.get(bname)
            if b is None:
                self.diag(
                    "PIPER022",
                    f"all-gather {node_provenance(dag, n.id)} references "
                    f"unregistered bucket {bname!r}", nodes=(n.id,))
                continue
            if not b.shard_params:
                self.diag(
                    "PIPER022",
                    f"all-gather {node_provenance(dag, n.id)} gathers "
                    f"bucket {bname!r} whose params are not sharded "
                    "(Bucket.shard_params=False — nothing to gather)",
                    nodes=(n.id,), bucket=bname)
            if b.replica_devices is not None \
                    and group != tuple(b.replica_devices):
                self.diag(
                    "PIPER022",
                    f"all-gather {node_provenance(dag, n.id)} group "
                    f"{ShardSpec('sharded', group).short()} disagrees "
                    f"with bucket {bname!r}'s replica group "
                    f"{ShardSpec('sharded', tuple(b.replica_devices)).short()}"
                    " — the gathered value would be partial",
                    nodes=(n.id,), bucket=bname, group=list(group),
                    replica=list(b.replica_devices))
            want = _full_param_spec(b)
            got = n.out_specs[i]
            if got != want:
                self.diag(
                    "PIPER023" if fused else "PIPER022",
                    f"all-gather {node_provenance(dag, n.id)} slot {i} "
                    f"({bname!r}) declares {got} but the full flat "
                    f"param of the bucket is {want}"
                    + (" — wrong member axis/size after fusion"
                       if fused else ""),
                    nodes=(n.id,), bucket=bname, slot=i,
                    declared=repr(got), expected=repr(want))

    def _check_grad_reduce(self, n) -> None:
        dag = self.dag
        members = n.meta.get("fused_members")
        fused = bool(members)
        if not members:
            members = [{"bucket": n.meta.get("bucket"),
                        "part": n.meta.get("part", 0),
                        "n_parts": n.meta.get("n_parts", 1)}]
        if len(n.out_specs) != len(members):
            self.diag(
                "PIPER023",
                f"grad reduce {node_provenance(dag, n.id)} fuses "
                f"{len(members)} member reduction(s) but declares "
                f"{len(n.out_specs)} output slot(s)",
                nodes=(n.id,), members=len(members),
                slots=len(n.out_specs))
            return
        if fused:
            for e in self.in_by_node.get(n.id, []):
                if not (0 <= e.dst_in < len(members)):
                    self.diag(
                        "PIPER023",
                        f"fused grad reduce {node_provenance(dag, n.id)} "
                        f"is fed at member slot {e.dst_in} but fuses "
                        f"only {len(members)} members",
                        nodes=(n.id, e.src), slot=e.dst_in)
        group = tuple(n.group or ())
        for i, m in enumerate(members):
            bname = m.get("bucket")
            b = dag.buckets.get(bname) if bname else None
            if b is None:
                self.diag(
                    "PIPER022",
                    f"grad reduce {node_provenance(dag, n.id)} member "
                    f"{i} references unregistered bucket {bname!r}",
                    nodes=(n.id,))
                continue
            want_op = "reduce_scatter" if b.shard_grads else "all_reduce"
            if n.op != want_op:
                self.diag(
                    "PIPER022",
                    f"grad reduce {node_provenance(dag, n.id)} uses "
                    f"{n.op} for bucket {bname!r} but the bucket's grads "
                    f"are {'sharded' if b.shard_grads else 'replicated'} "
                    f"(expected {want_op})",
                    nodes=(n.id,), bucket=bname, op=n.op,
                    expected=want_op)
            if b.replica_devices is not None \
                    and group != tuple(b.replica_devices):
                self.diag(
                    "PIPER022",
                    f"grad reduce {node_provenance(dag, n.id)} group "
                    f"{ShardSpec('partial', group).short()} disagrees "
                    f"with bucket {bname!r}'s replica group "
                    f"{ShardSpec('partial', tuple(b.replica_devices)).short()}"
                    " — some partial grads would never be summed",
                    nodes=(n.id,), bucket=bname, group=list(group),
                    replica=list(b.replica_devices))
            want = _grad_part_spec(b, m.get("n_parts", 1))
            got = n.out_specs[i]
            if str(got.dtype) != str(want.dtype) or (
                    fused and tuple(got.shape) != tuple(want.shape)):
                self.diag(
                    "PIPER023" if fused else "PIPER022",
                    f"grad reduce {node_provenance(dag, n.id)} slot {i} "
                    f"({bname!r}) declares {got}, expected the "
                    f"pre-scatter grad part {want}",
                    nodes=(n.id,), bucket=bname, slot=i,
                    declared=repr(got), expected=repr(want))

    def _check_identity(self, n, what: str) -> None:
        dag = self.dag
        if not n.out_specs:
            return
        out = n.out_specs[0]
        for e in self.in_by_node.get(n.id, []):
            if e.dst_in < 0:
                continue
            if str(e.spec.dtype) != str(out.dtype):
                self.diag(
                    "PIPER020",
                    f"{what} {node_provenance(dag, n.id)} takes "
                    f"{e.spec.dtype} in but delivers {out.dtype}",
                    nodes=(n.id, e.src), took=str(e.spec.dtype),
                    delivers=str(out.dtype))
            elif tuple(e.spec.shape) != tuple(out.shape):
                self.diag(
                    "PIPER021",
                    f"{what} {node_provenance(dag, n.id)} takes "
                    f"{tuple(e.spec.shape)} in but delivers "
                    f"{tuple(out.shape)}",
                    nodes=(n.id, e.src), took=list(e.spec.shape),
                    delivers=list(out.shape))

    # -- microbatch token conservation --------------------------------------
    def check_mb_tokens(self) -> None:
        dag = self.dag
        mb = dag.meta.get("microbatch_inputs") or {}
        for base, info in sorted(mb.items()):
            names, k, dim = info["names"], info["k"], info["dim"]
            if len(names) != k:
                self.diag(
                    "PIPER024",
                    f"input {base!r} was split into {k} microbatches "
                    f"but only {len(names)} tokens are recorded",
                    base=base, k=k, names=list(names))
            for i, sub in enumerate(names):
                if sub not in dag.inputs:
                    self.diag(
                        "PIPER024",
                        f"microbatch token {sub!r} (of {base!r}) is "
                        "missing from the graph inputs — a microbatch "
                        "of data would silently never be consumed",
                        base=base, token=sub, index=i)
                    continue
                _spec, consumers = dag.inputs[sub]
                if not consumers:
                    self.diag(
                        "PIPER024",
                        f"microbatch token {sub!r} (of {base!r}) has no "
                        "consumers — the microbatch is dropped",
                        base=base, token=sub, index=i)
                    continue
                wrong = [nid for (nid, _slot) in consumers
                         if nid in dag.nodes
                         and dag.nodes[nid].dims.get(dim) != i]
                if wrong:
                    self.diag(
                        "PIPER024",
                        f"microbatch token {sub!r} feeds nodes of a "
                        f"different {dim} index than {i} — tokens are "
                        "cross-wired between microbatches",
                        nodes=tuple(wrong[:4]), base=base, token=sub,
                        index=i)
        split = dag.meta.get("mb_split")
        if split and mb:
            ks = {info["k"] for info in mb.values()
                  if info.get("dim") == "MB"}
            total = sum(split.values())
            for k in sorted(ks):
                if total != k:
                    self.diag(
                        "PIPER024",
                        f"mb_split assigns {total} microbatches across "
                        f"ranks but the plan was split into {k} — the "
                        "split re-assigns microbatches, it never "
                        "changes their number",
                        split=dict(split), k=k)
            if any(c < 0 for c in split.values()):
                self.diag(
                    "PIPER024",
                    f"mb_split carries negative counts: {dict(split)}",
                    split=dict(split))


def type_diagnostics(dag: TrainingDAG,
                     plan: Optional[GlobalPlan] = None) -> list[Diagnostic]:
    """Run the shape/dtype/shard typechecker (PIPER020–024) over the
    DAG.  ``plan`` is accepted for pass-signature symmetry; the checks
    are pure graph passes."""
    c = _Checker(dag)
    c.check_edges()
    c.check_chunk_slots()
    c.check_collectives()
    c.check_mb_tokens()
    return c.diags


# backwards-friendly alias — the docs call this "the typechecker"
typecheck = type_diagnostics


# ---------------------------------------------------------------------------
# per-rank interface signatures (PIPER025, the MPMD-readiness check)
# ---------------------------------------------------------------------------

def _supplied_spec(dag, checker_in, node) -> Optional[ValueSpec]:
    """What the send side actually feeds into a p2p node."""
    for e in checker_in.get(node.id, []):
        if e.dst_in >= 0:
            return e.spec
    return node.out_specs[0] if node.out_specs else None


def _expected_specs(checker_out, node) -> list[ValueSpec]:
    """What the recv side's consumers were wired to expect (distinct)."""
    seen: list[ValueSpec] = []
    for e in checker_out.get(node.id, []):
        if e.dst_in < 0:
            continue
        if e.spec not in seen:
            seen.append(e.spec)
    return seen


def rank_signature(dag: TrainingDAG, plan: GlobalPlan,
                   device: int) -> dict:
    """The typed communication interface of one rank's program, in
    ``GlobalPlan.rank_program`` dispatch order — what a per-rank MPMD
    executor must agree on with its peers *without* a global trace:

      ``sends``:       [(peer, node, spec)] — p2p payloads this rank
                       supplies, per destination, in order;
      ``recvs``:       [(peer, node, spec)] — p2p payloads this rank's
                       consumers expect, per source, in order;
      ``collectives``: [(group, node, op, payload, specs)] — the
                       rendezvous sequence per communicator group.
    """
    ins: dict[int, list] = {}
    outs: dict[int, list] = {}
    for e in dag.edges:
        ins.setdefault(e.dst, []).append(e)
        outs.setdefault(e.src, []).append(e)
    sig = {"device": device, "sends": [], "recvs": [], "collectives": []}
    for t in plan.rank_program(device):
        n = dag.nodes.get(t.node)
        if n is None or not n.is_comm:
            continue
        if t.role == "send":
            spec = _supplied_spec(dag, ins, n)
            for (s, d) in (n.meta.get("pairs") or ()):
                if s == device:
                    sig["sends"].append((d, n.id, spec))
        elif t.role == "recv":
            expected = _expected_specs(outs, n)
            spec = expected[0] if expected else None
            for (s, d) in (n.meta.get("pairs") or ()):
                if d == device:
                    sig["recvs"].append((s, n.id, spec))
        elif t.role == "coll":
            group = tuple(n.group or ())
            if device in group:
                sig["collectives"].append(
                    (group, n.id, n.op, n.payload,
                     tuple(n.out_specs)))
    return sig


def rank_interface_diagnostics(dag: TrainingDAG,
                               plan: GlobalPlan) -> list[Diagnostic]:
    """Pairwise-check every rank's typed interface signature (PIPER025).

    For each directed p2p channel (src rank, dst rank), the sequence of
    specs the sender supplies must equal — position by position — the
    sequence the receiver's consumers expect; for each communicator
    group, every member must dispatch the identical (op, payload,
    specs) collective sequence.  This is exactly the agreement a
    multi-controller MPMD backend needs to hold *by construction*, so
    violations here mean the plan cannot be split into per-rank
    programs."""
    diags: list[Diagnostic] = []

    def diag(msg, nodes=(), **details):
        diags.append(Diagnostic(
            code="PIPER025", message=msg, nodes=tuple(nodes),
            provenance=tuple(node_provenance(dag, n) for n in nodes),
            details=details))

    sigs = {d: rank_signature(dag, plan, d) for d in plan.devices}

    # p2p channels: sender's supplied sequence vs receiver's expected
    sends: dict[tuple[int, int], list] = {}
    recvs: dict[tuple[int, int], list] = {}
    for d, sig in sigs.items():
        for (peer, nid, spec) in sig["sends"]:
            sends.setdefault((d, peer), []).append((nid, spec))
        for (peer, nid, spec) in sig["recvs"]:
            recvs.setdefault((peer, d), []).append((nid, spec))
    for chan in sorted(set(sends) | set(recvs)):
        s_seq = sends.get(chan, [])
        r_seq = recvs.get(chan, [])
        if len(s_seq) != len(r_seq):
            nodes = tuple({nid for nid, _ in s_seq + r_seq})
            diag(f"rank {chan[0]} sends {len(s_seq)} p2p payload(s) to "
                 f"rank {chan[1]} but rank {chan[1]}'s program expects "
                 f"{len(r_seq)} — the per-rank programs would desync",
                 nodes=tuple(sorted(nodes))[:6], channel=list(chan),
                 sent=len(s_seq), expected=len(r_seq))
            continue
        for i, ((snid, sspec), (rnid, rspec)) in enumerate(
                zip(s_seq, r_seq)):
            if sspec is None or rspec is None:
                continue
            if sspec != rspec:
                diag(f"p2p interface mismatch on channel rank "
                     f"{chan[0]} -> rank {chan[1]} at position {i}: "
                     f"the sender supplies {sspec} but the receiver's "
                     f"program was wired for {rspec}",
                     nodes=(snid,) if snid == rnid else (snid, rnid),
                     channel=list(chan), position=i,
                     send_spec=repr(sspec), recv_spec=repr(rspec))

    # collective groups: identical typed rendezvous sequence per member
    by_group: dict[tuple, dict[int, list]] = {}
    for d, sig in sigs.items():
        for (group, nid, op, payload, specs) in sig["collectives"]:
            by_group.setdefault(group, {}).setdefault(d, []).append(
                (nid, op, payload, specs))
    for group, per_rank in sorted(by_group.items()):
        ranks = sorted(group)
        seqs = {r: per_rank.get(r, []) for r in ranks}
        ref_rank = ranks[0]
        ref = seqs[ref_rank]
        for r in ranks[1:]:
            if seqs[r] == ref:
                continue
            # first divergence position for the message
            pos = next((i for i, (a, b) in enumerate(
                zip(ref, seqs[r])) if a != b),
                min(len(ref), len(seqs[r])))
            nodes = []
            if pos < len(ref):
                nodes.append(ref[pos][0])
            if pos < len(seqs[r]) and (not nodes
                                       or seqs[r][pos][0] != nodes[0]):
                nodes.append(seqs[r][pos][0])
            diag(f"collective signature of group "
                 f"{ShardSpec('replicated', group).short()} diverges "
                 f"between rank {ref_rank} ({len(ref)} dispatches) and "
                 f"rank {r} ({len(seqs[r])} dispatches) at position "
                 f"{pos} — an MPMD rendezvous would hang or corrupt",
                 nodes=tuple(nodes), group=list(group),
                 ranks=[ref_rank, r], position=pos)
    return diags
