"""Abstract execution of a ``GlobalPlan`` — no XLA, no tensors.

Mirrors the interpreter's dispatch loop exactly (``runtime.interpreter``:
per-(device, stream) in-order queues, dependency gating, collective
rendezvous across all member stream heads, and the FSDP-style gather
rate limiter modeled as a counting semaphore over live full-param
buffers) while executing only *buffer bookkeeping*:

  - a slot-granularity value store at (node, out_slot, device) keys with
    live/dead sets, mirroring the interpreter's ``store`` — reading a
    dead or never-materialized key is the use-after-free evidence;
  - a node-granularity activation ledger per device using the static
    estimator's sizing rules (``memory.node_out_bytes``) and release
    points, so its transient peak is comparable to
    ``memory.timeline_peak_bytes`` buffer for buffer (PIPER009);
  - ZeRO-3 full-param and ZeRO-2 full-grad lifetimes and the gradient
    accumulation side-channel keyed (bucket, device), whose anomalies
    (a reduce firing over an empty stash, a backward accumulating after
    its bucket's last reduce) are the double-free / lost-update evidence.

Two outputs: a :class:`StuckState` when no stream head can make progress
(the deadlock pass turns it into a wait-for graph) or an
:class:`Execution` on completion (the lifetime pass reads its events and
leftovers).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Union

from ..core.plan import (ROLE_COLL, GlobalPlan, Task, TaskKey)
from ..runtime.memory import (GRAD_BYTES_PER_ELEM, DeviceLedger,
                              bucket_persistent_bytes, gather_param_bytes,
                              node_out_bytes)

# grad-writing backward passes (Bi — backward-for-inputs — produces no
# bucket grads; the interpreter skips its accumulate at runtime)
_GRAD_PASSES = ("B", "Bw")


@dataclass
class StuckState:
    """The minimal stuck configuration: every blocked stream head plus
    enough scheduling state for the deadlock pass to explain each."""
    heads: list[tuple[int, str, TaskKey]]      # (device, stream, head key)
    done: set[TaskKey]
    executed: int
    total: int
    # param gathers blocked by the rate limiter at stuck time:
    # gather task key -> holder task keys (the remaining consumers of
    # the live full-param buffers on the gather's group devices)
    limiter_blocked: dict[TaskKey, list[TaskKey]] = field(
        default_factory=dict)
    gather_limit: int = 0


@dataclass
class Execution:
    exec_order: list[TaskKey]
    ledgers: dict[int, DeviceLedger]
    # anomalous lifetime facts: ("uaf" | "missing_value" | "reduce_empty"
    # | "grad_after_reduce", observing task key, detail)
    events: list[tuple] = field(default_factory=list)
    # (node, slot, device) store keys still live at completion
    leftover_values: list[tuple] = field(default_factory=list)
    # (device, ledger key, nbytes) transients still charged at completion
    leftover_buffers: list[tuple] = field(default_factory=list)

    def transient_peaks(self) -> dict[int, int]:
        return {d: led.peak - led.persistent
                for d, led in self.ledgers.items()}


class AbstractExecutor:
    """One-shot abstract run of ``prog.plan`` over ``prog.dag``."""

    def __init__(self, prog, gather_limit: Optional[int] = None) -> None:
        self.dag = prog.dag
        self.plan: GlobalPlan = prog.plan
        if gather_limit is None:
            gather_limit = int(self.dag.meta.get("gather_limit", 2))
        self.gather_limit = gather_limit
        dag = self.dag
        # slot-granularity consumer counts (interpreter._consumer_counts)
        self.cons0: dict[tuple[int, int, int], int] = {}
        for e in dag.edges:
            for d in self._value_devices(e.dst):
                k = (e.src, e.src_out, d)
                self.cons0[k] = self.cons0.get(k, 0) + 1
        # node-granularity activation consumer counts — the estimator's
        # (param-slot edges dst_in < 0 excluded; see timeline_peak_bytes)
        self.act_cons0: dict[tuple[int, int], int] = {}
        for e in dag.edges:
            if e.dst_in < 0:
                continue
            for d in (dag.nodes[e.dst].devices or ()):
                k = (e.src, d)
                self.act_cons0[k] = self.act_cons0.get(k, 0) + 1
        # graph-input feeds: externally-fed slots are always available
        self.fed_slots: set[tuple[int, int]] = set()
        for _name, (_spec, consumers) in dag.inputs.items():
            self.fed_slots.update(consumers)
        # ZeRO-3 gather lifetimes (interpreter.__init__)
        self.gather_consumers: dict[int, set[int]] = {}
        for n in dag.nodes.values():
            g = n.meta.get("param_from_comm")
            if g is not None:
                self.gather_consumers.setdefault(g, set()).add(n.id)
        self.gather_left0 = {
            g: {(c, d) for c in cs
                for d in (dag.nodes[c].devices or ())}
            for g, cs in self.gather_consumers.items()}
        # remaining grad reductions per bucket: a backward chunk that
        # accumulates after its bucket's count hits zero lost its update
        self.reduces_left0: dict[str, int] = {}
        for n in dag.comms():
            if n.op not in ("all_reduce", "reduce_scatter") or \
                    n.payload != "grad":
                continue
            for member in n.meta.get("fused_members") or [n.meta]:
                if member.get("part", 0) != 0:
                    continue
                b = member.get("bucket")
                if b:
                    self.reduces_left0[b] = self.reduces_left0.get(b, 0) + 1

    def _value_devices(self, nid: int) -> tuple[int, ...]:
        n = self.dag.nodes[nid]
        if n.is_comm and n.op == "p2p":
            return tuple(s for (s, _) in n.meta["pairs"])
        return n.devices or ()

    def _stored_slots(self, node) -> list[int]:
        """Output slots the interpreter writes to the store: forward
        chunks store every output; backward chunks store only the input
        cotangents (slot 0 is the bucket-grad side channel)."""
        if node.meta.get("is_backward"):
            n_cots = node.meta.get("n_cots")
            if n_cots is None:
                fwd = self.dag.nodes.get(node.meta.get("fwd_node"))
                n_cots = fwd.n_outputs if fwd is not None else 0
            slots = range(1, 1 + n_cots)
        else:
            slots = range(node.n_outputs)
        discard = set(node.meta.get("discard_out_slots", []))
        return [s for s in slots if s not in discard]

    # ------------------------------------------------------------------ run
    def run(self) -> Union["Execution", "StuckState"]:
        dag, plan = self.dag, self.plan
        ledgers = {d: DeviceLedger(device=d, events=[])
                   for d in plan.devices}
        for bname, bucket in dag.buckets.items():
            homes: set = set()
            for n in dag.nodes.values():
                if n.is_chunk and n.bucket == bname:
                    homes.update(n.devices or ())
            for d in homes or {0}:
                if d in ledgers:
                    ledgers[d].alloc_persistent(
                        bucket_persistent_bytes(bucket, d))

        live: set[tuple[int, int, int]] = set()   # (node, slot, device)
        dead: set[tuple[int, int, int]] = set()
        cons = dict(self.cons0)
        act_cons = dict(self.act_cons0)
        acted: set[tuple[int, int]] = set()       # (node, device) executed
        gather_left = {g: set(s) for g, s in self.gather_left0.items()}
        reduces_left = dict(self.reduces_left0)
        grad_acc: set[tuple[str, int]] = set()
        fullparam_live: dict[int, set[int]] = {d: set()
                                               for d in plan.devices}
        events: list[tuple] = []

        done: set[TaskKey] = set()
        heads: dict[tuple[int, str], int] = {}
        exec_order: list[TaskKey] = []
        queues = {(d, s): list(keys)
                  for d, p in plan.device_plans.items()
                  for s, keys in p.streams.items()}

        def head_task(d, s) -> Optional[Task]:
            q = queues[(d, s)]
            i = heads.get((d, s), 0)
            return None if i >= len(q) else plan.device_plans[d].tasks[q[i]]

        def deps_met(t: Task) -> bool:
            return all(k in done for k in t.deps)

        def at_head(key: TaskKey) -> bool:
            nid, d, role = key
            t = plan.device_plans[d].tasks.get(key)
            if t is None:
                return False
            q = queues.get((d, t.stream), ())
            i = heads.get((d, t.stream), 0)
            return i < len(q) and q[i] == key

        def advance(t: Task) -> None:
            heads[(t.device, t.stream)] = heads.get(
                (t.device, t.stream), 0) + 1
            done.add(t.key)
            exec_order.append(t.key)

        def peer_task(pk: TaskKey) -> Optional[Task]:
            dp = plan.device_plans.get(pk[1])
            return dp.tasks.get(pk) if dp is not None else None

        def limiter_holders(group_tasks) -> list[TaskKey]:
            holders: list[TaskKey] = []
            for g in group_tasks:
                for gid in sorted(fullparam_live[g.device]):
                    for (c, d) in sorted(gather_left.get(gid, ())):
                        if d == g.device and (c, d, "compute") not in done:
                            holders.append((c, d, "compute"))
            return holders

        def store_value(nid: int, slot: int, d: int) -> None:
            key = (nid, slot, d)
            if cons.get(key):
                live.add(key)

        def release_value(key: tuple[int, int, int]) -> None:
            """Interpreter's cons decrement + store delete."""
            if key in cons:
                cons[key] -= 1
                if cons[key] <= 0 and key in live:
                    live.discard(key)
                    dead.add(key)

        def read_value(key, tkey) -> None:
            """A chunk/recv reads the store: dead → use-after-free;
            counted-but-absent → never materialized on this device."""
            if key in live:
                return
            if key in dead:
                events.append(("uaf", tkey, key))
            elif cons.get(key):
                events.append(("missing_value", tkey, key))

        def node_act(node, d: int) -> None:
            """Estimator-mirror ledger step for one (node, device):
            charge the node's pinned output bytes, then release every
            input activation whose last on-device consumer this is."""
            if (node.id, d) in acted:
                return
            acted.add((node.id, d))
            led = ledgers[d]
            if act_cons.get((node.id, d)) and \
                    not (node.is_comm and node.op == "d2h"):
                led.alloc(("act", node.id, d), node_out_bytes(node))
            for e in dag.in_edges(node.id):
                nkey = (e.src, d)
                if nkey in act_cons:
                    act_cons[nkey] -= 1
                    if act_cons[nkey] <= 0 and \
                            ("act", e.src, d) in led.live:
                        led.free(("act", e.src, d))

        def exec_chunk(node, t: Task) -> None:
            m = node.meta.get("n_inputs", 0)
            skip = set(node.meta.get("seed_slots", ())) | \
                set(node.meta.get("zero_cot_slots", ()))
            for e in dag.in_edges(node.id):
                if (0 <= e.dst_in < m and e.dst_in not in skip
                        and (node.id, e.dst_in) not in self.fed_slots):
                    read_value((e.src, e.src_out, t.device), t.key)
            if (node.meta.get("is_backward") and node.bucket is not None
                    and node.dims.get("PASS") in _GRAD_PASSES):
                b = dag.bucket_of(node.bucket)
                if b.shard_grads:
                    ledgers[t.device].alloc(
                        ("fullgrad", node.bucket, t.device),
                        b.param_elems * GRAD_BYTES_PER_ELEM)
                if node.bucket in self.reduces_left0 and \
                        reduces_left.get(node.bucket, 0) <= 0:
                    events.append(
                        ("grad_after_reduce", t.key, node.bucket))
                grad_acc.add((node.bucket, t.device))
            for slot in self._stored_slots(node):
                store_value(node.id, slot, t.device)
            node_act(node, t.device)
            for e in dag.in_edges(node.id):
                release_value((e.src, e.src_out, t.device))
            g = node.meta.get("param_from_comm")
            if g is not None and g in gather_left:
                gather_left[g].discard((node.id, t.device))
                if not any(d == t.device for (_, d) in gather_left[g]):
                    ledgers[t.device].free(("fullparam", g, t.device))
                    fullparam_live[t.device].discard(g)

        def exec_collective(node, group_tasks) -> None:
            op = node.op
            if op in ("all_reduce", "reduce_scatter") and \
                    node.payload == "grad":
                for member in node.meta.get("fused_members") or [node.meta]:
                    if member.get("part", 0) != 0:
                        continue
                    bkt = member["bucket"]
                    reduces_left[bkt] = reduces_left.get(bkt, 1) - 1
                    if not any((bkt, t.device) in grad_acc
                               for t in group_tasks):
                        # the interpreter's _reduce_bucket_grads returns
                        # early here — a reduce consumed an empty stash
                        events.append(
                            ("reduce_empty", group_tasks[0].key, bkt))
                        continue
                    b = dag.bucket_of(bkt)
                    for t in group_tasks:
                        grad_acc.discard((bkt, t.device))
                        if b.shard_grads:
                            ledgers[t.device].free(
                                ("fullgrad", bkt, t.device))
                for t in group_tasks:
                    node_act(node, t.device)
            elif op == "all_gather" and node.payload == "param":
                try:
                    nbytes = gather_param_bytes(dag, node)
                except KeyError:
                    nbytes = 0  # reported by the interface pass
                for t in group_tasks:
                    ledgers[t.device].alloc(
                        ("fullparam", node.id, t.device), nbytes)
                    fullparam_live[t.device].add(node.id)
                    node_act(node, t.device)
            else:
                # value-moving collectives (d2h/h2d, all_to_all, generic
                # pass-through): output appears wherever an input lives
                for t in group_tasks:
                    for e in dag.in_edges(node.id):
                        if (e.src, e.src_out, t.device) in live:
                            store_value(node.id, 0, t.device)
                        elif (e.src, e.src_out, t.device) in dead:
                            events.append(
                                ("uaf", t.key,
                                 (e.src, e.src_out, t.device)))
                    node_act(node, t.device)
                for t in group_tasks:
                    for e in dag.in_edges(node.id):
                        release_value((e.src, e.src_out, t.device))

        def exec_recv(node, t: Task) -> None:
            src_dev = None
            for (s, d) in node.meta["pairs"]:
                if d == t.device:
                    src_dev = s
            for e in dag.in_edges(node.id):
                key = (e.src, e.src_out, src_dev)
                read_value(key, t.key)
                store_value(node.id, 0, t.device)
                release_value(key)
            node_act(node, t.device)

        total = sum(p.n_tasks() for p in plan.device_plans.values())
        progress = True
        while len(done) < total:
            if not progress:
                pending = [(d, s, queues[(d, s)][heads.get((d, s), 0)])
                           for (d, s) in sorted(queues)
                           if heads.get((d, s), 0) < len(queues[(d, s)])]
                limiter: dict[TaskKey, list[TaskKey]] = {}
                for (d, s, key) in pending:
                    t = plan.device_plans[d].tasks[key]
                    node = dag.nodes.get(t.node)
                    if (node is not None and t.role == ROLE_COLL
                            and node.op == "all_gather"
                            and node.payload == "param" and deps_met(t)):
                        group_tasks = [t] + [
                            g for g in map(peer_task, t.peers)
                            if g is not None]
                        if all(deps_met(g) and at_head(g.key)
                               for g in group_tasks):
                            limiter[t.key] = limiter_holders(group_tasks)
                return StuckState(heads=pending, done=done,
                                  executed=len(exec_order), total=total,
                                  limiter_blocked=limiter,
                                  gather_limit=self.gather_limit)
            progress = False
            # comm streams dispatch eagerly before "main" — same sweep
            # order as the interpreter, or the replayed order drifts
            sweep = sorted(queues, key=lambda ds: (ds[0],
                                                   ds[1] == "main", ds[1]))
            for (d, s) in sweep:
                t = head_task(d, s)
                if t is None or not deps_met(t):
                    continue
                node = dag.nodes.get(t.node)
                if node is None:
                    advance(t)  # plan names a removed node; the
                    progress = True  # interface pass reports it
                    continue
                if t.role == ROLE_COLL:
                    group_tasks = [t]
                    missing_peer = False
                    for pk in t.peers:
                        g = peer_task(pk)
                        if g is None:
                            missing_peer = True
                        else:
                            group_tasks.append(g)
                    if missing_peer:
                        continue  # unsatisfiable; reported at stuck time
                    if not all(deps_met(g) and at_head(g.key)
                               for g in group_tasks):
                        continue
                    if node.op == "all_gather" and node.payload == "param":
                        inflight = max(len(fullparam_live[g.device])
                                       for g in group_tasks)
                        if inflight >= self.gather_limit:
                            continue  # the counting semaphore is full
                    exec_collective(node, group_tasks)
                    for g in group_tasks:
                        advance(g)
                elif t.role == "send":
                    node_act(node, t.device)  # frees the producer-side
                    advance(t)                # activation on src
                elif t.role == "recv":
                    exec_recv(node, t)
                    advance(t)
                else:
                    exec_chunk(node, t)
                    advance(t)
                progress = True

        leftover_buffers = [(d, key, nb)
                            for d, led in sorted(ledgers.items())
                            for key, nb in sorted(led.live.items(),
                                                  key=lambda kv: repr(kv))]
        return Execution(exec_order=exec_order, ledgers=ledgers,
                         events=events, leftover_values=sorted(live),
                         leftover_buffers=leftover_buffers)
