"""Stream-race pass (PIPER010).

The one shared mutable buffer in Piper's runtime that two streams can
legally touch is a bucket's gradient-accumulation stash: backward chunks
on the compute stream add into it, and the bucket's (possibly merged)
gradient reduction — often placed on a dedicated reduce stream by
``Replicate(reduce_stream=...)`` or the overlap engine — consumes it.
When ``merge_grad_reduces`` collapses per-microbatch reductions into one
accumulated collective, every surviving writer *must* be ordered before
the merged reduce by an explicit edge; in-stream program order no longer
protects them.

This pass checks exactly that: for every accumulated grad reduce, every
backward chunk writing one of its buckets on a participating device must
be reachable through the plan's happens-before relation —

  task dependencies  ∪  same-stream predecessors  ∪  collective
  rendezvous peers (a collective dispatches only once every peer is at
  its stream head with deps met, so peers' predecessors precede it too).

An unreached writer is an unordered cross-stream access to the stash.
"""
from __future__ import annotations

from ..core.plan import ROLE_COLL, GlobalPlan, TaskKey
from .diagnostics import Diagnostic, node_provenance

_GRAD_PASSES = ("B", "Bw")


def _happens_before(plan: GlobalPlan, pred: dict, start: TaskKey) -> set:
    seen = {start}
    stack = [start]
    while stack:
        k = stack.pop()
        dp = plan.device_plans.get(k[1])
        t = dp.tasks.get(k) if dp is not None else None
        if t is None:
            continue
        nxt = list(t.deps)
        if k in pred:
            nxt.append(pred[k])
        if t.role == ROLE_COLL:
            nxt.extend(t.peers)
        for nk in nxt:
            if nk not in seen:
                seen.add(nk)
                stack.append(nk)
    return seen


def race_diagnostics(dag, plan: GlobalPlan) -> list[Diagnostic]:
    targets = []
    for n in dag.comms():
        if n.op not in ("all_reduce", "reduce_scatter") or \
                n.payload != "grad":
            continue
        members = n.meta.get("fused_members") or [n.meta]
        abuckets = [m.get("bucket") for m in members
                    if m.get("accumulated") and m.get("bucket")]
        if abuckets:
            targets.append((n, abuckets))
    if not targets:
        return []

    pred: dict[TaskKey, TaskKey] = {}
    for d, p in plan.device_plans.items():
        for keys in p.streams.values():
            for i in range(1, len(keys)):
                pred[keys[i]] = keys[i - 1]

    writers_of: dict[str, list] = {}

    def writers(bkt: str):
        if bkt not in writers_of:
            writers_of[bkt] = [
                w for w in dag.nodes.values()
                if (w.is_chunk and w.bucket == bkt
                    and w.meta.get("is_backward")
                    and w.dims.get("PASS") in _GRAD_PASSES)]
        return writers_of[bkt]

    diags: list[Diagnostic] = []
    for (n, abuckets) in targets:
        for d in sorted(n.devices or ()):
            key = (n.id, d, ROLE_COLL)
            dp = plan.device_plans.get(d)
            if dp is None or key not in dp.tasks:
                continue  # missing member: the interface pass reports it
            reach = _happens_before(plan, pred, key)
            for bkt in abuckets:
                for w in writers(bkt):
                    if d not in (w.devices or ()):
                        continue
                    wk = (w.id, d, "compute")
                    if wk in reach or wk not in dp.tasks:
                        continue
                    rt, wt = dp.tasks[key], dp.tasks[wk]
                    diags.append(Diagnostic(
                        code="PIPER010",
                        message=(
                            "stream race on the gradient-accumulation "
                            f"stash of bucket {bkt!r} on dev{d}: "
                            f"accumulated reduce "
                            f"{node_provenance(dag, n.id)} on stream "
                            f"{rt.stream!r} has no ordering edge to "
                            f"backward writer "
                            f"{node_provenance(dag, w.id)} on stream "
                            f"{wt.stream!r}"),
                        nodes=(n.id, w.id), device=d,
                        provenance=(node_provenance(dag, n.id),
                                    node_provenance(dag, w.id)),
                        details={"bucket": bkt,
                                 "reduce_stream": rt.stream,
                                 "writer_stream": wt.stream,
                                 "reduce_task": list(key),
                                 "writer_task": list(wk)}))
    return diags
