"""Translation validation for the compiler pass layer (PIPER026).

Instead of proving each pass correct once, certify every *run*: before
the finalization passes rewrite a DAG, normalize it to a
scheduling-independent **dataflow fingerprint**; re-normalize at every
``passes.run_all`` boundary and demand equality.  A pass may change how
values move (fuse collectives, splice host round-trips, dedup gathers,
reassign devices/streams, add temporal gates) but never *what* is
computed from *what* — exactly the discipline the parity grid checks
dynamically in fp64, turned into a per-compile static guarantee.

The fingerprint is built so every legal rewrite is invisible:

* **value numbering** — each chunk gets a structural value number from
  its name/dims/bucket and the value numbers feeding its input slots,
  never from ids, devices, streams, or its exec ``fn``;
* **remat modulo duplication** — a backward chunk's residual inputs
  (re-fed forward inputs under ``Remat("full")``, stashed vjp leaves
  under ``"none"``) collapse to one ``("res", vn(forward))`` token and
  its cotangent slots renumber from the end, so both residual policies
  of the same chunk value-number identically;
* **collectives modulo fusion/bucketing** — param gathers become
  ``(consumer vn, bucket, group)`` facts read off ``param_from_comm``
  (elision and fused gathers dedupe to the same fact set); grad reduces
  become per-``(bucket, part, op, group)`` producer sets, aggregated by
  key so per-microbatch reduces, one merged accumulated reduce, and a
  fused reduce-scatter's members all normalize to the same reduction;
* **transport erased** — ``p2p``/``send``/``recv`` and the offload
  ``d2h``/``h2d`` round-trip are transparent: consumers resolve through
  them to the producing chunk's value number.

``certify_equivalent(before, after, pass_name)`` returns a PIPER026
diagnostic when the fingerprints differ; ``passes.run_all`` raises it at
the boundary of the offending pass under ``REPRO_CHECK_PASSES=1`` (the
whole test suite runs that way via tests/conftest.py), and the elastic
trainer certifies ``Pipeline(mb_split=...)`` recompiles the same way.
"""
from __future__ import annotations

import hashlib
from collections import Counter
from dataclasses import dataclass, field

from ..core.dag import TrainingDAG
from .diagnostics import Diagnostic

_TRANSPARENT = ("p2p", "send", "recv", "d2h", "h2d", "broadcast")
_BACKWARD = ("B", "Bi", "Bw")
_GRAD_REDUCE_OPS = ("all_reduce", "reduce_scatter")


def _digest(structure) -> str:
    return hashlib.blake2b(repr(structure).encode(),
                           digest_size=12).hexdigest()


class _ValueNumbers:
    """Structural value numbers over chunks / all-to-alls, resolved
    through transparent transport nodes.  Iterative (explicit stack):
    pipeline DAGs chain hundreds of chunks deep."""

    def __init__(self, dag: TrainingDAG) -> None:
        self.dag = dag
        self.vn: dict[int, str] = {}
        self.labels: dict[str, str] = {}   # vn -> human label (for diffs)
        self.in_by: dict[int, list] = {}
        for e in dag.edges:
            if e.dst_in >= 0:
                self.in_by.setdefault(e.dst, []).append(e)
        self.input_feeds: dict[int, list[tuple[int, str]]] = {}
        for name, (_spec, consumers) in dag.inputs.items():
            for (nid, slot) in consumers:
                if slot >= 0:
                    self.input_feeds.setdefault(nid, []).append((slot,
                                                                 name))

    # -- transparent-transport resolution -----------------------------------
    def head(self, nid: int, slot: int):
        """Resolve (node, out slot) through transport chains.  Returns
        ``("node", id, slot)`` when the producer is a value-numbered
        node, else a terminal token."""
        seen: set[int] = set()
        while True:
            n = self.dag.nodes.get(nid)
            if n is None:
                return ("dangling", nid, slot)
            if n.is_chunk or n.op == "all_to_all":
                return ("node", nid, slot)
            if n.op in _TRANSPARENT:
                if nid in seen:
                    return ("cycle", nid)
                seen.add(nid)
                feed = next((e for e in self.in_by.get(nid, [])), None)
                if feed is None:
                    return ("comm", n.op, n.name)
                nid, slot = feed.src, feed.src_out
                continue
            # collective producer (param gather / grad reduce feeding a
            # data slot — unusual, but normalize stably by identity)
            return ("coll", n.op, n.payload,
                    tuple(n.meta.get("buckets")
                          or [n.meta.get("bucket")]),
                    tuple(n.group or ()), slot)

    def token(self, nid: int, slot: int):
        h = self.head(nid, slot)
        if h[0] != "node":
            return h
        return (self.of(h[1]), h[2])

    # -- value numbering -----------------------------------------------------
    def _deps(self, nid: int) -> list[int]:
        deps = []
        for e in self.in_by.get(nid, []):
            h = self.head(e.src, e.src_out)
            if h[0] == "node":
                deps.append(h[1])
        n = self.dag.nodes[nid]
        fwd = n.meta.get("fwd_node")
        if (n.is_chunk and n.dims.get("PASS") in _BACKWARD
                and fwd in self.dag.nodes):
            deps.append(fwd)
        return deps

    def of(self, nid: int) -> str:
        if nid in self.vn:
            return self.vn[nid]
        stack = [nid]
        on_stack = set(stack)
        while stack:
            cur = stack[-1]
            if cur in self.vn:
                stack.pop()
                on_stack.discard(cur)
                continue
            pending = [d for d in self._deps(cur) if d not in self.vn]
            pending = [d for d in pending if d not in on_stack]
            if pending:
                stack.extend(pending)
                on_stack.update(pending)
            else:
                self.vn[cur] = self._make(cur)
                stack.pop()
                on_stack.discard(cur)
        return self.vn[nid]

    def _make(self, nid: int) -> str:
        n = self.dag.nodes[nid]
        dims_t = tuple(sorted((k, str(v)) for k, v in n.dims.items()))
        m = n.meta.get("n_inputs")
        n_cots = n.meta.get("n_cots", 0)
        fwd = n.meta.get("fwd_node")
        if (n.is_chunk and n.dims.get("PASS") in _BACKWARD
                and fwd in self.dag.nodes and m is not None):
            # remat-modulo-duplication normal form: every pre-cotangent
            # slot (re-fed forward inputs OR stashed residual leaves)
            # collapses to the forward's value; cotangent slots
            # renumber from the end so the "full"/"none" slot shifts
            # cancel out
            cot_start = m - n_cots
            cots: dict[int, list] = {}
            for e in self.in_by.get(nid, []):
                if e.dst_in >= cot_start:
                    cots.setdefault(e.dst_in - cot_start, []).append(
                        self.token(e.src, e.src_out))
            for (slot, name) in self.input_feeds.get(nid, []):
                if slot >= cot_start:
                    cots.setdefault(slot - cot_start, []).append(
                        ("in", name))
            sig = tuple(
                (rel, tuple(sorted(cots[rel], key=repr)))
                for rel in sorted(cots))
            seeds = tuple(sorted(s - cot_start
                                 for s in n.meta.get("seed_slots", ())))
            zeros = tuple(sorted(
                s - cot_start for s in n.meta.get("zero_cot_slots", ())))
            key = ("bwd", n.name, dims_t, n.bucket,
                   ("res", self.vn.get(fwd)), sig, seeds, zeros)
        else:
            ins = [(e.dst_in, self.token(e.src, e.src_out))
                   for e in self.in_by.get(nid, [])]
            ins += [(slot, ("in", name))
                    for (slot, name) in self.input_feeds.get(nid, [])]
            sig = tuple(sorted(ins, key=lambda t: (t[0], repr(t[1]))))
            tag = "a2a" if (n.is_comm and n.op == "all_to_all") else \
                "chunk"
            extra = tuple(n.group or ()) if tag == "a2a" else n.bucket
            key = (tag, n.name, dims_t, extra, sig)
        vn = _digest(key)
        self.labels.setdefault(vn, n.short())
        return vn


@dataclass
class Fingerprint:
    """The scheduling-independent dataflow normal form of a DAG."""
    compute: Counter                       # vn -> multiplicity
    params: frozenset                      # (consumer vn, bucket, group)
    reductions: dict                       # key -> frozenset(producer tok)
    grad_sinks: dict                       # bucket -> frozenset(facts)
    outputs: Counter                       # token -> multiplicity
    inputs: frozenset                      # consumed graph-input names
    labels: dict = field(default_factory=dict, compare=False)

    def __eq__(self, other) -> bool:
        if not isinstance(other, Fingerprint):
            return NotImplemented
        return (self.compute == other.compute
                and self.params == other.params
                and self.reductions == other.reductions
                and self.grad_sinks == other.grad_sinks
                and self.outputs == other.outputs
                and self.inputs == other.inputs)

    def digest(self) -> str:
        canon = (
            sorted(self.compute.items()),
            sorted(self.params, key=repr),
            sorted((k, sorted(v, key=repr))
                   for k, v in self.reductions.items()),
            sorted((k, sorted(v, key=repr))
                   for k, v in self.grad_sinks.items()),
            sorted(self.outputs.items(), key=repr),
            sorted(self.inputs),
        )
        return _digest(canon)

    def summary(self) -> dict:
        return {"digest": self.digest(),
                "compute": sum(self.compute.values()),
                "distinct_values": len(self.compute),
                "params": len(self.params),
                "reductions": len(self.reductions),
                "outputs": sum(self.outputs.values()),
                "inputs": len(self.inputs)}


def dataflow_fingerprint(dag: TrainingDAG) -> Fingerprint:
    """Normalize a (possibly mid-pass-pipeline) DAG to its dataflow
    fingerprint.  Pure — never mutates the DAG; requires an acyclic DAG
    with no dangling data edges (``run_all``'s boundary checks that
    first)."""
    vns = _ValueNumbers(dag)

    compute: Counter = Counter()
    for n in dag.nodes.values():
        if n.is_chunk or (n.is_comm and n.op == "all_to_all"):
            compute[vns.of(n.id)] += 1

    params = set()
    for n in dag.chunks():
        gid = n.meta.get("param_from_comm")
        g = dag.nodes.get(gid) if gid is not None else None
        if g is not None and g.is_comm:
            params.add((vns.of(n.id), n.bucket, tuple(g.group or ())))

    temporal_in: dict[int, list[int]] = {}
    for (u, v) in dag.temporal:
        temporal_in.setdefault(v, []).append(u)

    reductions: dict[tuple, set] = {}
    for n in dag.comms():
        if n.payload != "grad" or n.op not in _GRAD_REDUCE_OPS:
            continue
        members = n.meta.get("fused_members") or [{
            "bucket": n.meta.get("bucket"),
            "part": n.meta.get("part", 0)}]
        group = tuple(n.group or ())
        for i, m in enumerate(members):
            key = (m.get("bucket"), m.get("part", 0), n.op, group)
            prods = reductions.setdefault(key, set())
            for e in vns.in_by.get(n.id, []):
                if len(members) == 1 or e.dst_in == i:
                    prods.add(vns.token(e.src, e.src_out))
            # merged accumulated reduces carry their folded-away
            # producers as temporal edges (merge_grad_reduces) — fold
            # them back in, attributed by the producing chunk's bucket
            for u in temporal_in.get(n.id, ()):
                un = dag.nodes.get(u)
                if (un is not None and un.is_chunk
                        and un.dims.get("PASS") in _BACKWARD
                        and un.bucket == m.get("bucket")):
                    prods.add((vns.of(u), 0))

    grad_sinks: dict[str, frozenset] = {}
    for bucket, sinks in dag.grad_sinks.items():
        facts = set()
        for (nid, slot) in sinks:
            n = dag.nodes.get(nid)
            if n is None:
                facts.add(("dangling", nid, slot))
            elif n.is_comm and n.op in _GRAD_REDUCE_OPS:
                members = n.meta.get("fused_members") or [{
                    "bucket": n.meta.get("bucket"),
                    "part": n.meta.get("part", 0)}]
                group = tuple(n.group or ())
                for m in members:
                    if m.get("bucket") == bucket:
                        facts.add(("red", bucket, m.get("part", 0),
                                   n.op, group))
            else:
                facts.add(("val", vns.token(nid, slot)))
        grad_sinks[bucket] = frozenset(facts)

    outputs: Counter = Counter()
    for (nid, slot) in dag.outputs:
        outputs[vns.token(nid, slot)] += 1

    inputs = frozenset(name for name, (_s, consumers) in dag.inputs.items()
                       if consumers)

    return Fingerprint(
        compute=compute, params=frozenset(params),
        reductions={k: frozenset(v) for k, v in reductions.items()},
        grad_sinks=grad_sinks, outputs=outputs, inputs=inputs,
        labels=dict(vns.labels))


def dataflow_fingerprint_safe(dag: TrainingDAG):
    """``dataflow_fingerprint`` or None when the DAG is not yet in a
    fingerprintable state (dangling references, cycles mid-rewrite) —
    the reference-capture spelling for pass-boundary certification."""
    try:
        return dataflow_fingerprint(dag)
    except Exception:
        return None


# ---------------------------------------------------------------------------
# diffing / certification
# ---------------------------------------------------------------------------

def _label(fp_a: Fingerprint, fp_b: Fingerprint, vn) -> str:
    if isinstance(vn, str):
        return fp_a.labels.get(vn) or fp_b.labels.get(vn) or vn[:8]
    return repr(vn)


def fingerprint_diff(a: Fingerprint, b: Fingerprint,
                     limit: int = 6) -> list[str]:
    """Human-readable facts present in one fingerprint and not the
    other (empty iff equivalent)."""
    out: list[str] = []

    def name(vn):
        return _label(a, b, vn)

    gone = a.compute - b.compute
    new = b.compute - a.compute
    for vn, k in list(gone.items())[:limit]:
        out.append(f"compute value lost: {name(vn)} x{k}")
    for vn, k in list(new.items())[:limit]:
        out.append(f"compute value introduced: {name(vn)} x{k}")
    for (vn, bucket, _group) in sorted(set(a.params) - set(b.params),
                                      key=repr)[:limit]:
        out.append(f"param feed lost: bucket {bucket!r} -> {name(vn)}")
    for (vn, bucket, _group) in sorted(set(b.params) - set(a.params),
                                      key=repr)[:limit]:
        out.append(f"param feed introduced: bucket {bucket!r} -> "
                   f"{name(vn)}")
    keys = set(a.reductions) | set(b.reductions)
    for key in sorted(keys, key=repr):
        pa = a.reductions.get(key, frozenset())
        pb = b.reductions.get(key, frozenset())
        if pa == pb:
            continue
        bucket, part, op, _group = key
        lost = {t for t in pa - pb}
        gained = {t for t in pb - pa}
        bits = []
        if lost:
            bits.append("lost producers "
                        + ", ".join(sorted(name(t[0]) if isinstance(t, tuple)
                                           and t and isinstance(t[0], str)
                                           else repr(t)
                                           for t in lost)[:limit]))
        if gained:
            bits.append("gained producers "
                        + ", ".join(sorted(name(t[0]) if isinstance(t, tuple)
                                           and t and isinstance(t[0], str)
                                           else repr(t)
                                           for t in gained)[:limit]))
        out.append(f"reduction ({op} {bucket!r} part {part}): "
                   + "; ".join(bits))
        if len(out) >= limit * 3:
            break
    for bucket in sorted(set(a.grad_sinks) | set(b.grad_sinks)):
        if a.grad_sinks.get(bucket) != b.grad_sinks.get(bucket):
            out.append(f"grad sink set changed for bucket {bucket!r}")
    if a.outputs != b.outputs:
        out.append(f"graph outputs changed: {sum(a.outputs.values())} "
                   f"-> {sum(b.outputs.values())} "
                   "(or re-wired to different values)")
    if a.inputs != b.inputs:
        lost_in = sorted(a.inputs - b.inputs)[:limit]
        new_in = sorted(b.inputs - a.inputs)[:limit]
        if lost_in:
            out.append(f"graph inputs no longer consumed: {lost_in}")
        if new_in:
            out.append(f"graph inputs newly consumed: {new_in}")
    return out


def certify_equivalent(before, after, pass_name: str) -> list[Diagnostic]:
    """Translation-validate one pass: empty list when ``after`` computes
    exactly the dataflow of ``before``, else a single PIPER026
    diagnostic naming the pass and the first differing facts.  A None
    fingerprint on either side (un-normalizable snapshot) certifies
    vacuously — the structural boundary checks still run."""
    if before is None or after is None or before == after:
        return []
    diff = fingerprint_diff(before, after)
    shown = diff[:4]
    more = len(diff) - len(shown)
    detail = "; ".join(shown) + (f"; (+{more} more)" if more > 0 else "")
    return [Diagnostic(
        code="PIPER026",
        message=(f"pass {pass_name!r} changed the dataflow fingerprint "
                 f"({before.digest()} -> {after.digest()}): {detail}"),
        provenance=(f"pass:{pass_name}",),
        details={"pass": pass_name,
                 "before": before.summary(), "after": after.summary(),
                 "diff": diff})]
