"""Interface-consistency pass (PIPER011).

Checks that every communication endpoint agrees with its counterpart:
p2p transfers carry the same dtype/shape on the send and recv side and
name real endpoint pairs; collectives have non-empty groups contained in
their device placement, with a task instance in every member's device
plan; param all-gathers reference registered buckets (so their
payload-bytes are well defined); and comm out-edges match the declared
output specs slot for slot.
"""
from __future__ import annotations

from ..core.plan import ROLE_COLL, GlobalPlan
from ..runtime.memory import gather_param_bytes
from .diagnostics import Diagnostic, node_provenance


def interface_diagnostics(dag, plan: GlobalPlan) -> list[Diagnostic]:
    diags: list[Diagnostic] = []

    def diag(msg, nodes=(), device=None, **details):
        diags.append(Diagnostic(
            code="PIPER011", message=msg, nodes=tuple(nodes),
            device=device,
            provenance=tuple(node_provenance(dag, n) for n in nodes),
            details=details))

    # tasks referencing nodes a pass removed without fixing the plan
    for d, p in sorted(plan.device_plans.items()):
        for key, t in sorted(p.tasks.items()):
            if t.node not in dag.nodes:
                diag(f"device plan {d} schedules task {t.role}@dev{d} "
                     f"for node {t.node} which no longer exists in the "
                     "DAG", device=d, task=list(key))

    for n in dag.comms():
        devs = set(n.devices or ())
        if n.op == "p2p":
            pairs = n.meta.get("pairs") or []
            if not pairs:
                diag(f"p2p {node_provenance(dag, n.id)} has no endpoint "
                     "pairs", nodes=(n.id,))
                continue
            endpoints = ({s for (s, _) in pairs}
                         | {r for (_, r) in pairs})
            if devs and endpoints != devs:
                diag(f"p2p {node_provenance(dag, n.id)} endpoint pairs "
                     f"{sorted(pairs)} do not cover its device placement "
                     f"{sorted(devs)}", nodes=(n.id,),
                     pairs=[list(p) for p in pairs],
                     devices=sorted(devs))
            if n.out_specs:
                spec0 = n.out_specs[0]
                for e in dag.in_edges(n.id):
                    if e.spec != spec0:
                        diag("p2p dtype/shape mismatch: "
                             f"{node_provenance(dag, e.src)} sends "
                             f"{e.spec} but "
                             f"{node_provenance(dag, n.id)} delivers "
                             f"{spec0}", nodes=(n.id, e.src),
                             send_spec=repr(e.spec),
                             recv_spec=repr(spec0))
        else:
            group = tuple(n.group or ())
            if not group:
                diag(f"collective {node_provenance(dag, n.id)} has an "
                     "empty communicator group", nodes=(n.id,))
            elif devs and not set(group) <= devs:
                diag(f"collective {node_provenance(dag, n.id)} group "
                     f"{sorted(group)} is not contained in its device "
                     f"placement {sorted(devs)}", nodes=(n.id,),
                     group=sorted(group), devices=sorted(devs))
            for d in group:
                dp = plan.device_plans.get(d)
                if dp is None or (n.id, d, ROLE_COLL) not in dp.tasks:
                    diag(f"collective {node_provenance(dag, n.id)} "
                         f"rendezvous needs group member dev{d} but "
                         "that device plan has no task for it — the "
                         "remaining members would wait forever",
                         nodes=(n.id,), device=d, group=sorted(group))
            if n.op == "all_gather" and n.payload == "param":
                try:
                    gather_param_bytes(dag, n)
                except KeyError as exc:
                    diag(f"param all-gather payload undefined: {exc}",
                         nodes=(n.id,))

        # declared output specs vs what consumers were wired to expect
        # (param-plumbing edges, dst_in < 0, carry the per-rank shard
        # spec by design — the gather's output is the full param)
        for e in dag.out_edges(n.id):
            if e.dst_in < 0:
                continue
            if 0 <= e.src_out < len(n.out_specs) and \
                    e.spec != n.out_specs[e.src_out]:
                diag(f"comm {node_provenance(dag, n.id)} declares output "
                     f"{e.src_out} as {n.out_specs[e.src_out]} but "
                     f"consumer {node_provenance(dag, e.dst)} was wired "
                     f"for {e.spec}", nodes=(n.id, e.dst),
                     slot=e.src_out, declared=repr(n.out_specs[e.src_out]),
                     wired=repr(e.spec))
            elif e.src_out >= len(n.out_specs) or e.src_out < 0:
                diag(f"comm {node_provenance(dag, n.id)} has "
                     f"{len(n.out_specs)} outputs but consumer "
                     f"{node_provenance(dag, e.dst)} reads slot "
                     f"{e.src_out}", nodes=(n.id, e.dst), slot=e.src_out)
    return diags
