"""MPMD multi-controller executor: one traced program PER RANK.

The SPMD executor (``runtime/spmd.py``) traces every rank's chunks into
ONE whole-mesh ``jax.jit`` program and gates per-rank compute with
``lax.cond`` — correct and bit-verified, but each device carries the
entire mesh's trace and all communication lowers to SPMD collectives
(``lax.ppermute`` / ``lax.psum``) inside a single dispatch.  This module
is the multi-controller alternative the ROADMAP's top open item asks
for, following JaxPP's MPMD pipeline-parallel design (PAPERS.md,
arxiv 2412.14374): ``GlobalPlan.rank_program(r)`` compiles into a
*per-rank* ``jax.jit`` program containing ONLY rank r's chunks — no
``lax.cond`` gating, no whole-mesh trace (``trace_sizes()`` vs
``SpmdExecutor.trace_size()`` quantifies the shrink) — and N controller
threads dispatch the N programs concurrently, communicating through a
real asynchronous message transport instead of XLA collectives.

IR-op -> transport lowering (DESIGN.md §17 has the full table, the MPMD
mirror of §12's SPMD table):

  chunk                 traced unconditionally (only members carry the
                        task); feeds/params resolved per rank
  p2p send              ordered ``io_callback`` posting the payload on
                        the tagged channel (node, src, dst)
  p2p recv              ordered ``io_callback`` blocking on that channel
                        and dynamically type-checking the payload
                        against the receiver's wired ``ValueSpec``
  all_gather (param)    the rank's 1/|group| byte shard of the bucket's
                        bit-cast params goes through a subgroup
                        rendezvous; the callback returns the full byte
                        vector, rebuilt in-trace into the gathered tree
                        the consuming chunks read (load-bearing, exactly
                        like the SPMD lowering)
  all_reduce /          every member posts its locally accumulated
  reduce_scatter (grad) (tree, count) to the subgroup rendezvous; the
                        group's lowest rank folds contributions in the
                        interpreter's own advance order with the
                        reference formula ``sum(x/c)/n`` and hands the
                        mean to the controller epilogue
  all_to_all (EP)       rendezvous round trip: each member's block
                        crosses the transport and returns (identity
                        values, real dispatch + return bytes — the
                        reference runtime models EP math shard-locally)
  d2h / h2d (Offload)   rank-local ``lax.optimization_barrier`` identity
                        (same documented fallback as SPMD)

Startup handshake (the PIPER025 gate, cashed in): before any program
runs, every rank serializes its typed interface signature
(``GlobalPlan.rank_signature`` — sends/recvs/collectives in dispatch
order) and exchanges it with all peers over the transport; each rank
then pairwise-validates every p2p channel and collective group it is
party to, exactly the agreement ``analysis.rank_interface_diagnostics``
checks statically.  A mismatch raises ``MpmdHandshakeError`` naming both
ranks — the executor refuses to start rather than desync at runtime
(``signature_overrides=`` is the fault-injection seam the negative-path
test corrupts).

Transports (one ``_Board`` semantics, two wire shapes):

  ``transport="inproc"``  threads + queues + condition-variable
                          rendezvous in-process (the CI default on N
                          host-faked devices);
  ``transport="tcp"``     the same board behind a localhost TCP server —
                          every send/recv/rendezvous serializes its
                          payload over a real socket (process-shaped
                          wire realism).

  True subprocess-per-rank is not possible here: ``Node.fn`` chunk
  closures capture traced model callables that do not pickle.  The
  controller therefore drives N threads — but each rank's program is
  its own jit executable on its own XLA device, every cross-rank byte
  moves through the transport, and nothing in the executor assumes
  shared memory beyond the transport API, so swapping in a socket
  transport per real host is a deployment change, not a redesign.

Bit-parity with the reference interpreter is by construction, the same
argument as SPMD: each rank's compute/collective trace order IS the
interpreter's dynamic dispatch order restricted to that rank
(``replay_schedule``, including the FSDP-style gather rate limiter),
gradient reductions fold in the interpreter's own member order with its
exact formula, and the controller epilogue applies the reference
loss/grad reductions in ``ScheduleReplay`` order
(tests/test_mpmd_executor.py: fp64 bit-parity on the
{1f1b,gpipe,dualpipev} x ZeRO{0,3} grid).

One wrinkle the raw replay projection hides: the interpreter consumes
p2p VALUES straight from the producer's store, so its global order can
legally run a recv *task* before the matching send task — fine for a
sequential simulator, a deadlock for real blocking transports (rank A
blocks in the recv, never reaching the collective post rank B needs
before it can send; XLA's CPU runtime executes a rank program's
callbacks strictly sequentially, so a blocking callback blocks the
whole rank).  ``_rank_orders`` therefore re-derives each rank's trace
order by replaying the plan's task graph under *real* transport
semantics — sends complete once their producer ran (non-blocking
post), a recv completes only after its send task, rendezvous
collectives complete atomically when every member arrives — while
pinning every compute/collective to its replay-projection position.
The construction sequence is itself a feasible global interleaving
(a witness), so the per-rank blocking execution it projects to cannot
deadlock; and because only send/recv tasks move (neither touches
gradient accumulation or reduction state), bit-parity is untouched.

A plan that fails ``validate_comm_order`` is rejected at construction,
before tracing; a rank that stalls at runtime trips the transport
timeout and poisons all peers (``MpmdTransportError`` — the dynamic
analogue of the PIPER001 deadlock the static verifier rejects).
"""
from __future__ import annotations

import json
import pickle
import socket
import struct
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import io_callback

from ..core.compiler import CompiledProgram
from ..core.plan import ROLE_COLL, ROLE_COMPUTE, ROLE_RECV, ROLE_SEND
from ..core.scheduler import validate_comm_order
from .executor import jaxpr_eqn_count, register_backend
from .interpreter import RunResult, ScheduleReplay, _PlanWalker
from .spmd import _bytes_to_tree, _tree_to_bytes, gather_chunk_args

tree_map = jax.tree_util.tree_map


class MpmdBackendError(RuntimeError):
    """The MPMD executor cannot run this plan on the available devices."""


class MpmdHandshakeError(MpmdBackendError):
    """The startup signature handshake found peers whose typed
    interfaces disagree (the dynamic PIPER025) — the executor refuses
    to start."""


class MpmdTransportError(RuntimeError):
    """A transport operation timed out or was poisoned by a failing
    peer — the dynamic analogue of the PIPER001 deadlock the static
    verifier rejects."""


# ---------------------------------------------------------------------------
# message board: tagged channels + keyed rendezvous
# ---------------------------------------------------------------------------

class _Board:
    """The one message-passing semantics both transports implement:
    FIFO channels keyed by tag (p2p) and all-post/all-fetch rendezvous
    slots keyed by op instance (collectives).  ``abort`` poisons every
    current and future waiter so one failing rank cannot strand its
    peers at a rendezvous."""

    def __init__(self) -> None:
        self._cv = threading.Condition()
        self._chan: dict[tuple, deque] = {}
        self._rdv: dict[tuple, dict] = {}
        self._poison: Optional[str] = None

    def _check(self) -> None:
        if self._poison is not None:
            raise MpmdTransportError(
                f"transport poisoned: {self._poison}")

    def reset(self) -> None:
        with self._cv:
            self._chan.clear()
            self._rdv.clear()
            self._poison = None
            self._cv.notify_all()

    def abort(self, msg: str) -> None:
        with self._cv:
            if self._poison is None:
                self._poison = msg
            self._cv.notify_all()

    def send(self, tag: tuple, payload) -> None:
        with self._cv:
            self._check()
            self._chan.setdefault(tag, deque()).append(payload)
            self._cv.notify_all()

    def recv(self, tag: tuple, timeout: float):
        deadline = time.monotonic() + timeout
        with self._cv:
            while True:
                self._check()
                q = self._chan.get(tag)
                if q:
                    return q.popleft()
                left = deadline - time.monotonic()
                if left <= 0 or not self._cv.wait(timeout=left):
                    raise MpmdTransportError(
                        f"recv on channel {tag} timed out after "
                        f"{timeout:.0f}s — peer never sent (the dynamic "
                        "analogue of a PIPER001 desync)")

    def gather(self, key: tuple, pos: int, nposts: int, payload,
               timeout: float) -> list:
        """Rendezvous allgather: post as member ``pos`` of ``nposts``,
        block until all members posted, return payloads in pos order.
        The last fetcher retires the slot."""
        deadline = time.monotonic() + timeout
        with self._cv:
            self._check()
            slot = self._rdv.setdefault(key, {"posts": {}, "taken": 0})
            slot["posts"][pos] = payload
            self._cv.notify_all()
            while len(slot["posts"]) < nposts:
                self._check()
                left = deadline - time.monotonic()
                if left <= 0 or not self._cv.wait(timeout=left):
                    missing = sorted(set(range(nposts))
                                     - set(slot["posts"]))
                    raise MpmdTransportError(
                        f"rendezvous {key} timed out after "
                        f"{timeout:.0f}s waiting for member(s) "
                        f"{missing} of {nposts}")
            out = [slot["posts"][p] for p in sorted(slot["posts"])]
            slot["taken"] += 1
            if slot["taken"] >= nposts:
                self._rdv.pop(key, None)
            return out


class InprocTransport:
    """Threads sharing one in-process board — the CI default.  All
    payloads still flow through the board (no rank reads another's
    store); only the wire is a queue instead of a socket."""
    name = "inproc"

    def __init__(self) -> None:
        self._board = _Board()

    def reset(self) -> None:
        self._board.reset()

    def abort(self, msg: str) -> None:
        self._board.abort(msg)

    def send(self, tag, payload) -> None:
        self._board.send(tag, payload)

    def recv(self, tag, timeout):
        return self._board.recv(tag, timeout)

    def gather(self, key, pos, nposts, payload, timeout):
        return self._board.gather(key, pos, nposts, payload, timeout)

    def close(self) -> None:
        pass


class TcpTransport:
    """The same board behind a localhost TCP server: every operation is
    a length-prefixed pickled request over a fresh socket, so every
    cross-rank payload crosses a real OS socket (process-shaped wire
    realism; blocking ops block their server-side connection thread).
    """
    name = "tcp"

    def __init__(self) -> None:
        self._board = _Board()
        self._srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._srv.bind(("127.0.0.1", 0))
        self._srv.listen(128)
        self.address = self._srv.getsockname()
        self._closing = False
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="mpmd-tcp-accept", daemon=True)
        self._accept_thread.start()

    # -- framing ---------------------------------------------------------
    @staticmethod
    def _send_msg(sock, obj) -> None:
        data = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
        sock.sendall(struct.pack(">Q", len(data)) + data)

    @staticmethod
    def _recv_msg(sock):
        hdr = b""
        while len(hdr) < 8:
            part = sock.recv(8 - len(hdr))
            if not part:
                raise ConnectionError("peer closed")
            hdr += part
        (n,) = struct.unpack(">Q", hdr)
        buf = bytearray()
        while len(buf) < n:
            part = sock.recv(min(1 << 20, n - len(buf)))
            if not part:
                raise ConnectionError("peer closed")
            buf += part
        return pickle.loads(bytes(buf))

    # -- server ----------------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._closing:
            try:
                conn, _ = self._srv.accept()
            except OSError:
                return
            threading.Thread(target=self._serve_one, args=(conn,),
                             daemon=True).start()

    def _serve_one(self, conn) -> None:
        try:
            with conn:
                op, args = self._recv_msg(conn)
                try:
                    result = getattr(self._board, op)(*args)
                    self._send_msg(conn, (True, result))
                except Exception as e:  # marshalled to the client
                    self._send_msg(conn, (False, f"{type(e).__name__}: {e}"))
        except (ConnectionError, OSError):
            pass

    # -- client ----------------------------------------------------------
    def _call(self, op: str, *args):
        with socket.create_connection(self.address, timeout=600) as sock:
            self._send_msg(sock, (op, args))
            ok, result = self._recv_msg(sock)
        if not ok:
            raise MpmdTransportError(result)
        return result

    def reset(self) -> None:
        self._call("reset")

    def abort(self, msg: str) -> None:
        self._call("abort", msg)

    def send(self, tag, payload) -> None:
        self._call("send", tag, payload)

    def recv(self, tag, timeout):
        return self._call("recv", tag, timeout)

    def gather(self, key, pos, nposts, payload, timeout):
        return self._call("gather", key, pos, nposts, payload, timeout)

    def close(self) -> None:
        self._closing = True
        try:
            self._srv.close()
        except OSError:
            pass


_TRANSPORTS = {"inproc": InprocTransport, "tcp": TcpTransport}


def _ensure_sync_cpu_dispatch() -> None:
    """Force synchronous CPU dispatch before any rank program runs.

    jax's CPU async dispatch executes programs on a small client-wide
    worker pool.  A rank parked inside a blocking transport callback
    (recv / rendezvous) parks one of those workers, and once every
    worker is parked the remaining rank programs never START — a
    starvation deadlock the per-rank order witness cannot see, because
    it is not an ordering problem (observed on a 4-rank ZeRO-3 run:
    the starved ranks reached their first transport op exactly when a
    parked peer timed out and freed its worker).  Synchronous dispatch
    runs each rank's program — and its blocking callbacks — on its own
    controller thread, which is the multi-controller model anyway.

    The flag is consumed at CPU *client creation*
    (``xla_bridge.make_cpu_client(asynchronous=...)``), so flipping the
    config after first jax use is a no-op; if an async client already
    exists it must be rebuilt.  Old arrays stay readable (np.asarray
    re-transfers), but device handles captured before the rebuild go
    stale — hence this runs before ``__init__`` touches
    ``jax.devices()``.
    """
    if not bool(getattr(jax.config, "jax_cpu_enable_async_dispatch",
                        True)):
        return
    jax.config.update("jax_cpu_enable_async_dispatch", False)
    from jax._src import xla_bridge as _xb
    if getattr(_xb, "_backends", None):
        import jax.extend.backend as _jeb
        _jeb.clear_backends()


# ---------------------------------------------------------------------------
# rank-signature serialization (the handshake payload)
# ---------------------------------------------------------------------------

def serialize_rank_signature(sig: dict) -> bytes:
    """Deterministic wire form of ``GlobalPlan.rank_signature``: specs
    as stable reprs, groups as lists — byte-comparable and corruptible
    (the ``signature_overrides`` test seam)."""
    return json.dumps({
        "device": sig["device"],
        "sends": [[p, n, repr(s)] for (p, n, s) in sig["sends"]],
        "recvs": [[p, n, repr(s)] for (p, n, s) in sig["recvs"]],
        "collectives": [[list(g), n, op, payload, [repr(s) for s in specs]]
                        for (g, n, op, payload, specs)
                        in sig["collectives"]],
    }, sort_keys=True).encode()


def _pairwise_errors(r: int, mine: dict, peers: dict[int, dict]) -> list[str]:
    """Rank r's view of the PIPER025 pairwise agreement: every p2p
    channel r is party to, both directions, and every collective group
    containing r — mirroring ``analysis.rank_interface_diagnostics``."""
    errs: list[str] = []

    def chan_seqs(src_sig, dst_sig, src, dst):
        s_seq = [(n, sp) for (p, n, sp) in src_sig["sends"] if p == dst]
        r_seq = [(n, sp) for (p, n, sp) in dst_sig["recvs"] if p == src]
        return s_seq, r_seq

    out_peers = {p for (p, _, _) in mine["sends"]}
    in_peers = {p for (p, _, _) in mine["recvs"]}
    for p in sorted(out_peers | in_peers):
        if p not in peers:
            errs.append(f"[PIPER025] rank {r} names rank {p} in its "
                        "interface but no such rank joined the handshake")
            continue
        for (src, dst), (src_sig, dst_sig) in (
                ((r, p), (mine, peers[p])), ((p, r), (peers[p], mine))):
            s_seq, r_seq = chan_seqs(src_sig, dst_sig, src, dst)
            if len(s_seq) != len(r_seq):
                errs.append(
                    f"[PIPER025] rank {src} sends {len(s_seq)} p2p "
                    f"payload(s) to rank {dst} but rank {dst}'s program "
                    f"expects {len(r_seq)} — the per-rank programs "
                    "would desync")
                continue
            for i, ((snid, ss), (rnid, rs)) in enumerate(
                    zip(s_seq, r_seq)):
                if ss != rs and "None" not in (ss, rs):
                    errs.append(
                        f"[PIPER025] p2p interface mismatch on channel "
                        f"rank {src} -> rank {dst} at position {i} "
                        f"(nodes {snid}/{rnid}): the sender supplies "
                        f"{ss} but the receiver was wired for {rs}")

    groups = {tuple(g) for (g, *_rest) in mine["collectives"]}
    for g in sorted(groups):
        ref = [c[1:] for c in mine["collectives"] if tuple(c[0]) == g]
        for m in g:
            if m == r:
                continue
            if m not in peers:
                errs.append(f"[PIPER025] collective group {list(g)} "
                            f"names rank {m} but it never joined the "
                            "handshake")
                continue
            seq = [c[1:] for c in peers[m]["collectives"]
                   if tuple(c[0]) == g]
            if seq == ref:
                continue
            pos = next((i for i, (a, b) in enumerate(zip(ref, seq))
                        if a != b), min(len(ref), len(seq)))
            errs.append(
                f"[PIPER025] collective signature of group {list(g)} "
                f"diverges between rank {r} ({len(ref)} dispatches) "
                f"and rank {m} ({len(seq)} dispatches) at position "
                f"{pos} — an MPMD rendezvous would hang or corrupt")
    return errs


# ---------------------------------------------------------------------------
# wire-shape oracle
# ---------------------------------------------------------------------------

class _ShapeOracle(_PlanWalker):
    """Device-aware abstract interpretation of one batch signature.

    IR ``ValueSpec``s are *logical* shapes — a DP-replicated producer
    declares ``(mb, d)`` while each device actually emits its
    ``(mb/dp, d)`` shard — so a receiver cannot learn its wire shape
    from the edge spec alone.  This pass walks the interpreter's own
    dispatch loop (it IS the ``_PlanWalker`` replay, so the executor
    gets the ``ScheduleReplay`` and the shapes from ONE walk) with
    chunk execution replaced by ``jax.eval_shape``, propagating
    per-device avals through every store move and recording, for each
    p2p recv, the concrete (shape, dtype) that crosses that channel —
    the receiver-side contract ``MpmdExecutor._trace_recv`` traces
    against and dynamically re-checks on every arriving payload."""

    def __init__(self, prog: CompiledProgram,
                 gather_limit: Optional[int] = None) -> None:
        super().__init__(prog, gather_limit=gather_limit)
        self.p2p_shapes: dict[tuple[int, int], tuple] = {}

    def replay(self, batch: dict[str, Any]) -> ScheduleReplay:
        self.p2p_shapes = {}
        return super().replay(batch)

    def _aval_args(self, node, t, store, feeds):
        # _gather_chunk_inputs, aval-safe: multi-source cotangent slots
        # share one shape, so the summed aval is its first contributor
        m = node.meta.get("n_inputs", 0)
        args: list = []
        for slot in range(m):
            key = (node.id, slot, t.device)
            if key in feeds:
                args.append(feeds[key])
                continue
            vals = [store[(e.src, e.src_out, t.device)]
                    for e in self.dag.in_edges(node.id)
                    if e.dst_in == slot
                    and (e.src, e.src_out, t.device) in store]
            args.append(vals[0] if vals else None)
        if "fwd_node" in node.meta:
            fwd = self.dag.nodes[node.meta["fwd_node"]]
            n_cots = node.meta.get("n_cots", fwd.n_outputs)
            m0 = node.meta["n_inputs"] - n_cots
            for slot in (list(node.meta.get("seed_slots", []))
                         + list(node.meta.get("zero_cot_slots", []))):
                s = fwd.out_specs[slot - m0]
                args[slot] = jax.ShapeDtypeStruct(tuple(s.shape),
                                                  np.dtype(s.dtype))
        return args

    def _exec_chunk(self, node, t, store, feeds, cons, grad_acc, grad_cnt,
                    losses, ledgers, gather_left, gather_consumers) -> None:
        args = self._aval_args(node, t, store, feeds)
        bp = self.params.get(node.bucket) if node.bucket else None
        outs = jax.eval_shape(lambda p, a: node.fn(p, *a), bp, tuple(args))
        if node.meta.get("is_backward", False):
            out_vals = list(outs[1:])
            out_slots = list(range(1, len(outs)))
        else:
            out_vals = list(outs)
            out_slots = list(range(len(outs)))
        discard = set(node.meta.get("discard_out_slots", []))
        for slot, val in zip(out_slots, out_vals):
            if slot in discard or val is None:
                continue
            key = (node.id, slot, t.device)
            if cons.get(key):
                store[key] = val
        self._release_inputs(node, t, store, cons, ledgers)
        super()._exec_chunk(node, t, store, feeds, cons, grad_acc,
                            grad_cnt, losses, ledgers, gather_left,
                            gather_consumers)

    def _exec_recv(self, node, t, store, cons, ledgers) -> None:
        e = self.dag.in_edges(node.id)[0]
        src_dev = None
        for (s, d) in node.meta["pairs"]:
            if d == t.device:
                src_dev = s
        val = store.get((e.src, e.src_out, src_dev))
        if val is not None:
            store[(node.id, 0, t.device)] = val
            self.p2p_shapes[(node.id, t.device)] = (
                tuple(val.shape), np.dtype(val.dtype))
            pkey = (e.src, e.src_out, src_dev)
            cons[pkey] = cons.get(pkey, 1) - 1
            if cons[pkey] <= 0:
                store.pop(pkey, None)

    def _exec_collective(self, node, group_tasks, store, grad_acc,
                         grad_cnt, reduced, reduced_cnt, ledgers, cons,
                         gather_left) -> None:
        # keep the walker's rate-limiter/reduction bookkeeping, but also
        # move avals through pass-through ops so downstream chunks on
        # the same device can assemble their inputs
        if node.op in ("d2h", "h2d", "all_to_all", "broadcast") \
                or (node.op not in ("all_gather",)
                    and node.payload != "grad"):
            for t in group_tasks:
                for e in self.dag.in_edges(node.id):
                    v = store.get((e.src, e.src_out, t.device))
                    if v is not None:
                        store[(node.id, 0, t.device)] = v
            for t in group_tasks:
                self._release_inputs(node, t, store, cons, ledgers)
        super()._exec_collective(node, group_tasks, store, grad_acc,
                                 grad_cnt, reduced, reduced_cnt, ledgers,
                                 cons, gather_left)


# ---------------------------------------------------------------------------
# the executor
# ---------------------------------------------------------------------------

@dataclass
class _Built:
    """Per-batch-signature build: one traced+jitted program per rank,
    plus the replayed schedule facts the controller epilogue reads."""
    replay: ScheduleReplay
    traced: dict[int, Any] = field(default_factory=dict)
    fns: dict[int, Any] = field(default_factory=dict)
    compiled: dict[int, Any] = field(default_factory=dict)
    reduce_fold: dict[int, list[int]] = field(default_factory=dict)
    acc_cnt: dict[tuple[str, int], int] = field(default_factory=dict)
    p2p_shapes: dict[tuple[int, int], tuple] = field(default_factory=dict)
    n_tasks: int = 0


@register_backend("mpmd")
class MpmdExecutor:
    """Execute a ``CompiledProgram`` as N per-rank jit programs driven
    by N controller threads over an async message transport.

    ``transport``: "inproc" (default) or "tcp" (localhost sockets).
    ``timeout``: seconds any single transport wait may block before the
    run is declared desynced.
    ``signature_overrides``: {rank: signature-dict-or-bytes} replacing
    that rank's handshake payload — the fault-injection seam.
    ``handshake=False`` skips the startup signature exchange (only for
    harnesses that measure its cost separately).
    """

    def __init__(self, prog: CompiledProgram,
                 params: Optional[dict[str, Any]] = None, *,
                 transport: str = "inproc",
                 gather_limit: Optional[int] = None,
                 physical_devices: Optional[Sequence[int]] = None,
                 timeout: float = 60.0,
                 signature_overrides: Optional[dict] = None,
                 handshake: bool = True) -> None:
        # static rejection BEFORE any thread or trace exists — the
        # dynamic analogue is a rendezvous deadlock across controllers
        validate_comm_order(prog.dag, prog.plan)
        # must precede the jax.devices() capture below: rebuilding the
        # CPU client invalidates previously captured device handles
        _ensure_sync_cpu_dispatch()
        self.prog = prog
        self.dag = prog.dag
        self.plan = prog.plan
        self.params = params if params is not None else prog.params
        self.timeout = float(timeout)
        self.devices = sorted(self.plan.devices)
        self.n = len(self.devices)
        if transport not in _TRANSPORTS:
            raise MpmdBackendError(
                f"unknown transport {transport!r}; available: "
                f"{sorted(_TRANSPORTS)}")
        self.transport = _TRANSPORTS[transport]()
        avail = jax.devices()
        if physical_devices is not None:
            # elastic recovery contract (same rules as SpmdExecutor):
            # the n logical ranks land on exactly these distinct
            # jax.devices() indices, so a shrunk/regrown world never
            # touches a failed chip
            phys = [int(p) for p in physical_devices]
            if len(phys) != self.n:
                raise MpmdBackendError(
                    f"plan spans {self.n} devices but physical_devices "
                    f"names {len(phys)}: {phys}")
            bad = [p for p in phys if not 0 <= p < len(avail)]
            if bad or len(set(phys)) != len(phys):
                raise MpmdBackendError(
                    f"physical_devices must be {len(phys)} distinct "
                    f"indices into jax.devices() (0..{len(avail)-1}), "
                    f"got {phys}")
            chosen = [avail[p] for p in phys]
        else:
            # unlike SPMD (one shard_map over n mesh devices), rank
            # programs are independent executables — oversubscribing
            # fewer real devices is allowed (rank r -> device r mod D),
            # which is what lets world-4 smoke tests run on 1 CPU device
            chosen = [avail[i % len(avail)] for i in range(self.n)]
        self.physical_devices = tuple(
            d.id if hasattr(d, "id") else i for i, d in enumerate(chosen))
        self._devmap = {d: chosen[i] for i, d in enumerate(self.devices)}
        self._resolver = _ShapeOracle(prog, gather_limit=gather_limit)
        self._built: dict[tuple, _Built] = {}
        self._gen = 0
        self._events: list[tuple[str, bool, Any]] = []
        self._events_lock = threading.Lock()
        if handshake:
            self._handshake(signature_overrides or {})

    # ------------------------------------------------------------ handshake
    def _handshake(self, overrides: dict) -> None:
        raw: dict[int, bytes] = {}
        for r in self.devices:
            o = overrides.get(r)
            if o is None:
                raw[r] = serialize_rank_signature(
                    self.plan.rank_signature(r, self.dag))
            else:
                raw[r] = o if isinstance(o, bytes) \
                    else serialize_rank_signature(o)
        errors: list[str] = []
        lock = threading.Lock()

        def worker(pos: int, r: int) -> None:
            try:
                posts = self.transport.gather(
                    ("handshake", self._gen), pos, self.n,
                    (r, raw[r]), self.timeout)
                sigs = {d: json.loads(b) for (d, b) in posts}
                errs = _pairwise_errors(r, sigs[r], sigs)
                if errs:
                    with lock:
                        errors.extend(errs)
            except MpmdTransportError as e:
                with lock:
                    errors.append(f"[PIPER025] rank {r}: {e}")
                self.transport.abort(f"handshake failed on rank {r}")

        threads = [threading.Thread(target=worker, args=(i, r),
                                    name=f"mpmd-hs{r}")
                   for i, r in enumerate(self.devices)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(self.timeout + 5)
        self.transport.reset()
        if errors:
            uniq = sorted(set(errors))
            raise MpmdHandshakeError(
                "MPMD startup handshake failed — peer rank signatures "
                "disagree (PIPER025):\n  " + "\n  ".join(uniq[:8]))

    # ------------------------------------------------------------ helpers
    def _sig(self, batch) -> tuple:
        return tuple(sorted(
            (k, tuple(np.shape(v)),
             str(getattr(v, "dtype", None) or np.asarray(v).dtype))
            for k, v in batch.items()))

    def _rank_feeds(self, batch) -> dict[int, dict[tuple, Any]]:
        feeds3 = self._resolver._resolve_inputs(batch)
        out: dict[int, dict[tuple, Any]] = {r: {} for r in self.devices}
        for (nid, slot, d), v in feeds3.items():
            out[d][(nid, slot)] = np.asarray(v)
        return out

    # ------------------------------------------------------------ build
    def _ensure_built(self, batch) -> _Built:
        key = self._sig(batch)
        if key not in self._built:
            self._built[key] = self._build(batch)
        return self._built[key]

    def _build(self, batch) -> _Built:
        replay = self._resolver.replay(batch)
        b = _Built(replay=replay,
                   p2p_shapes=dict(self._resolver.p2p_shapes),
                   n_tasks=sum(p.n_tasks()
                               for p in self.plan.device_plans.values()))
        # grad-reduce fold order: the interpreter advances a collective's
        # group tasks consecutively ([t] + peers), so the run of same-nid
        # ROLE_COLL entries in exec_order IS its member fold order
        grad_nids = {n.id for n in self.dag.nodes.values()
                     if n.is_comm and n.payload == "grad"
                     and n.op in ("all_reduce", "reduce_scatter")}
        for (nid, dev, role) in replay.exec_order:
            if role == ROLE_COLL and nid in grad_nids:
                b.reduce_fold.setdefault(nid, []).append(dev)
        orders = self._rank_orders(replay)
        for r in self.devices:
            traced = self._make_traced(r, orders[r], b)
            b.traced[r] = traced
            b.fns[r] = jax.jit(traced)
        return b

    def _rank_orders(self, replay) -> dict[int, list[tuple[int, str]]]:
        """Deadlock-free per-rank trace orders (module docstring: the
        witness construction).  Greedy completion over the plan's task
        graph in replay order, under blocking-transport semantics:

          compute/coll   pinned to the replay projection — each waits
                         for its rank's previous compute/coll, so the
                         numerics-bearing order is exactly the
                         interpreter's
          send           completes once its ``Task.deps`` (the producer
                         chunk) ran — a non-blocking post may float
                         ahead of its replay slot
          recv           completes only after its paired send task
                         (``Task.deps`` already contains it)
          rendezvous     all members complete atomically, each member's
                         own prerequisites permitting

        The completion sequence is a feasible global interleaving, so
        its per-rank projections cannot deadlock when each rank runs
        them as one blocking ordered-callback chain."""
        keys = [k for k in replay.exec_order]
        tasks = {}
        for p in self.plan.device_plans.values():
            tasks.update(p.tasks)
        # pinned chain: non-p2p tasks in per-rank projection order
        pinned: dict[tuple, tuple] = {}
        last: dict[int, tuple] = {}
        for k in keys:
            (nid, dev, role) = k
            if role in (ROLE_SEND, ROLE_RECV):
                continue
            if dev in last:
                pinned[k] = last[dev]
            last[dev] = k
        done: set[tuple] = set()
        pending = dict.fromkeys(keys)   # insertion-ordered set
        out: dict[int, list[tuple[int, str]]] = {
            r: [] for r in self.devices}

        def arrived(k) -> bool:
            t = tasks.get(k)
            peers = set(t.peers) if t is not None else set()
            if t is not None and any(d not in done for d in t.deps
                                     if d not in peers):
                return False
            return pinned.get(k) is None or pinned[k] in done

        def solo_ready(k) -> bool:
            t = tasks.get(k)
            if t is not None and any(d not in done for d in t.deps):
                return False
            return pinned.get(k) is None or pinned[k] in done

        def finish(k) -> None:
            done.add(k)
            pending.pop(k, None)
            out[k[1]].append((k[0], k[2]))

        while pending:
            progressed = False
            for k in list(pending):
                role = k[2]
                if role == ROLE_COLL:
                    t = tasks.get(k)
                    cohort = [k] + [p for p in (t.peers if t else [])
                                    if p in pending]
                    if all(arrived(m) for m in cohort):
                        for m in cohort:
                            finish(m)
                        progressed = True
                elif solo_ready(k):
                    finish(k)
                    progressed = True
                if progressed:
                    break
            if not progressed:
                stuck = ", ".join(map(str, list(pending)[:6]))
                raise MpmdBackendError(
                    "no feasible blocking execution of this plan — "
                    f"{len(pending)} task(s) unreachable under "
                    f"transport semantics (first: {stuck}); the static "
                    "verifier should have rejected this schedule "
                    "(PIPER001)")
        return out

    # ------------------------------------------------------------ tracing
    def _make_traced(self, r: int, order: list[tuple[int, str]],
                     built: _Built):
        dag = self.dag

        def traced(prm, feeds):
            store: dict[tuple[int, int], Any] = {}
            gathered: dict[int, dict[str, Any]] = {}
            grad_acc: dict[str, Any] = {}
            grad_cnt: dict[str, int] = {}
            loss_vals: dict[tuple[int, int], Any] = {}
            toks: list[Any] = []
            for (nid, role) in order:
                node = dag.nodes[nid]
                if role == ROLE_COMPUTE:
                    self._trace_chunk(r, node, prm, feeds, store,
                                      gathered, grad_acc, grad_cnt,
                                      loss_vals)
                elif role == ROLE_SEND:
                    self._trace_send(r, node, store, toks)
                elif role == ROLE_RECV:
                    self._trace_recv(r, node, store, built)
                elif node.op == "all_gather" and node.payload == "param":
                    self._trace_param_gather(r, node, prm, gathered)
                elif node.op in ("all_reduce", "reduce_scatter") \
                        and node.payload == "grad":
                    self._trace_grad_reduce(r, node, grad_acc, grad_cnt,
                                            built, toks)
                elif node.op == "all_to_all":
                    self._trace_a2a(r, node, store)
                elif node.op in ("d2h", "h2d"):
                    self._trace_passthrough(node, store, barrier=True)
                else:  # broadcast / generic activation collective
                    self._trace_passthrough(node, store, barrier=False)
            for bkt, cnt in grad_cnt.items():   # never-reduced buckets
                built.acc_cnt[(bkt, r)] = cnt
            # completion fence: block_until_ready on the outputs only
            # waits for the OUTPUT buffers — a trailing callback whose
            # result is otherwise unused (a send, an owner-side reduce)
            # may still be in flight when the controller snapshots the
            # event log.  Every send/reduce callback returns a uint8
            # token; folding them into an output makes each callback's
            # completion a data dependency of the step result.
            fence = jnp.zeros((), jnp.uint8)
            for t in toks:
                fence = jnp.bitwise_or(fence, t)
            return {"loss": loss_vals, "fence": fence,
                    "acc": {bkt: grad_acc[bkt] for bkt in grad_cnt}}

        return traced

    # -- chunks --------------------------------------------------------------
    def _trace_chunk(self, r, node, prm, feeds, store, gathered,
                     grad_acc, grad_cnt, loss_vals):
        args = gather_chunk_args(self.dag, node, feeds, store)
        g = node.meta.get("param_from_comm")
        if node.bucket is not None:
            bparams = (gathered[g][node.bucket] if g in gathered
                       else prm.get(node.bucket))
        else:
            bparams = None

        # No lax.cond MEMBERSHIP gate: rank r's program contains only
        # rank r's tasks — that is the whole point of the MPMD
        # lowering.  The chunk body still runs inside a cond branch,
        # for numerics, not membership: a branch is its own XLA
        # computation, so the chunk compiles context-free — exactly
        # like the reference's per-chunk jit and the SPMD trace's
        # gated branch.  Inlined bare instead, XLA specializes the
        # body to its surroundings (seed-cotangent constants, fusion
        # into neighbors) and fp64 grads drift by ~1 ulp (observed on
        # dualpipev-z0).  The barrier keeps the always-true predicate
        # out of reach of conditional constant-folding.
        def run_fn(ops):
            bp, a = ops
            return node.fn(bp, *a)

        operands = (bparams, tuple(args))
        out_avals = jax.eval_shape(run_fn, operands)
        zeros = tree_map(lambda av: jnp.zeros(av.shape, av.dtype),
                         out_avals)
        pred = lax.optimization_barrier(jnp.asarray(True))
        outs = lax.cond(pred, run_fn, lambda _ops: zeros, operands)
        if node.meta.get("is_backward", False):
            bucket_grads = outs[0]
            cots = outs[1:]
            if node.bucket is not None and bucket_grads is not None:
                bkt = node.bucket
                grad_acc[bkt] = (bucket_grads if bkt not in grad_acc
                                 else tree_map(jnp.add, grad_acc[bkt],
                                               bucket_grads))
                grad_cnt[bkt] = grad_cnt.get(bkt, 0) + 1
            out_vals = cots
            out_slots = list(range(1, 1 + len(cots)))
        else:
            out_vals = outs
            out_slots = list(range(len(outs)))
        discard = set(node.meta.get("discard_out_slots", []))
        for slot, val in zip(out_slots, out_vals):
            if slot in discard or val is None:
                continue
            store[(node.id, slot)] = val
        for (nid, slot) in self.dag.outputs:
            if nid == node.id:
                loss_vals[(nid, slot)] = outs[slot]

    # -- p2p -----------------------------------------------------------------
    def _trace_send(self, r, node, store, toks):
        e_in = self.dag.in_edges(node.id)
        assert len(e_in) == 1, f"p2p with {len(e_in)} inputs"
        val = store[(e_in[0].src, e_in[0].src_out)]
        dsts = [d for (s, d) in node.meta["pairs"] if s == r]
        if not dsts:
            return
        nid = node.id

        def cb(v):
            payload = np.asarray(v)
            for d in dsts:
                self.transport.send(("p2p", self._gen, nid, r, d),
                                    payload)
            return np.zeros((), np.uint8)

        # ordered=True chains this into the rank's transport-op token
        # sequence, so sends post in program order
        tok = io_callback(cb, jax.ShapeDtypeStruct((), np.uint8), val,
                          ordered=True)
        toks.append(tok)

    def _trace_recv(self, r, node, store, built):
        src = None
        for (s, d) in node.meta["pairs"]:
            if d == r:
                src = s   # last match, mirroring Interpreter._exec_recv
        if src is None:
            return
        # wire shape from the oracle walk (edge ValueSpecs are logical,
        # pre-DP-shard shapes — the oracle saw what actually moves)
        wire = built.p2p_shapes.get((node.id, r))
        if wire is None:
            e_in = self.dag.in_edges(node.id)
            spec = e_in[0].spec
            wire = (tuple(spec.shape), np.dtype(spec.dtype))
        shape, dt = tuple(wire[0]), np.dtype(wire[1])
        nid = node.id

        def cb():
            v = self.transport.recv(("p2p", self._gen, nid, src, r),
                                    self.timeout)
            if tuple(v.shape) != shape or np.dtype(v.dtype) != dt:
                raise MpmdTransportError(
                    f"p2p payload on channel rank {src} -> rank {r} "
                    f"(node {nid}) arrived as {v.dtype}{list(v.shape)} "
                    f"but the receiver was wired for {dt}{list(shape)}")
            return v

        store[(node.id, 0)] = io_callback(
            cb, jax.ShapeDtypeStruct(shape, dt), ordered=True)

    # -- collectives ---------------------------------------------------------
    def _group_of(self, node) -> list[int]:
        return sorted(set(node.group or node.devices))

    def _trace_param_gather(self, r, node, prm, gathered):
        buckets = node.meta.get("buckets") or [node.meta["bucket"]]
        group = self._group_of(node)
        g = len(group)
        if g <= 1:
            gathered[node.id] = {b: prm[b] for b in buckets}
            return
        # fused buckets cross the wire as ONE concatenated byte payload
        flats, metas = [], []
        for bkt in buckets:
            u8, recipe = _tree_to_bytes(prm[bkt])
            flats.append(u8)
            metas.append((bkt, recipe, int(u8.size)))
        cat = jnp.concatenate(flats) if len(flats) > 1 else flats[0]
        total = int(cat.size)
        chunk = -(-total // g)  # ceil: pad to g equal shards
        padded = (jnp.concatenate(
            [cat, jnp.zeros((chunk * g - total,), cat.dtype)])
            if chunk * g != total else cat)
        pos = group.index(r)
        shard = padded[pos * chunk:(pos + 1) * chunk]
        nid = node.id

        def cb(sh):
            parts = self.transport.gather(
                ("gather", self._gen, nid), pos, g, np.asarray(sh),
                self.timeout)
            return np.concatenate(parts)[:total]

        full = io_callback(cb, jax.ShapeDtypeStruct((total,), np.uint8),
                           shard, ordered=True)
        out, off = {}, 0
        for bkt, recipe, nb in metas:
            out[bkt] = _bytes_to_tree(full[off:off + nb], recipe)
            off += nb
        gathered[node.id] = out

    def _trace_grad_reduce(self, r, node, grad_acc, grad_cnt, built,
                           toks):
        group = self._group_of(node)
        g = len(group)
        pos = group.index(r)
        members = [(m["bucket"], bool(m.get("accumulated")))
                   for m in node.meta.get("fused_members") or [node.meta]
                   if not m.get("part", 0)]
        # which member buckets THIS rank contributes is trace-static
        contrib = {bkt: grad_cnt[bkt] for bkt, _acc in members
                   if bkt in grad_acc}
        payload_trees = {bkt: grad_acc[bkt] for bkt in contrib}
        nid = node.id
        owner = pos == 0  # the group's lowest rank folds and records

        def cb(trees):
            # jax may hand callback args over as jax.Arrays; the fold
            # below MUST stay pure numpy — a jnp op here dispatches a
            # fresh jit from inside an XLA host callback, which
            # deadlocks against the very programs this rendezvous is
            # waiting on (device busy -> dispatch queues -> rendezvous
            # never completes)
            np_trees = {bkt: (contrib[bkt], tree_map(np.asarray, t))
                        for bkt, t in trees.items()}
            posts = self.transport.gather(
                ("reduce", self._gen, nid), pos, g, (r, np_trees),
                self.timeout)
            if owner:
                by_dev = {d: data for (d, data) in posts}
                fold = built.reduce_fold.get(nid) or group
                for bkt, accumulated in members:
                    xs, cnts = [], []
                    for d in fold:
                        if bkt in by_dev.get(d, {}):
                            c, t = by_dev[d][bkt]
                            xs.append(t)
                            cnts.append(c)
                    if not xs:
                        continue  # no contributions yet (mirrors ref)
                    # the reference formula, in the reference member
                    # fold order (builtin sum from 0: same -0.0+0
                    # normalization as the interpreter's jnp version)
                    mean = tree_map(
                        lambda *ls: sum(x / c for x, c
                                        in zip(ls, cnts)) / len(ls),
                        *xs)
                    with self._events_lock:
                        self._events.append((bkt, accumulated, mean))
            return np.zeros((), np.uint8)

        tok = io_callback(cb, jax.ShapeDtypeStruct((), np.uint8),
                          payload_trees, ordered=True)
        toks.append(tok)
        for bkt in contrib:   # grads were consumed by the reduction
            grad_acc.pop(bkt, None)
            grad_cnt.pop(bkt, None)

    def _trace_a2a(self, r, node, store):
        e_in = self.dag.in_edges(node.id)
        assert len(e_in) == 1, f"a2a with {len(e_in)} inputs"
        val = store[(e_in[0].src, e_in[0].src_out)]
        group = self._group_of(node)
        g = len(group)
        if g <= 1:
            store[(node.id, 0)] = lax.optimization_barrier(val)
            return
        pos = group.index(r)
        nid = node.id

        def cb(v):
            # dispatch + return round trip: this rank's block crosses
            # the transport and comes back (identity values — the
            # reference runtime models EP math shard-locally)
            parts = self.transport.gather(
                ("a2a", self._gen, nid), pos, g, np.asarray(v),
                self.timeout)
            return parts[pos]

        store[(node.id, 0)] = io_callback(
            cb, jax.ShapeDtypeStruct(val.shape, val.dtype), val,
            ordered=True)

    def _trace_passthrough(self, node, store, *, barrier: bool):
        for e in self.dag.in_edges(node.id):
            val = store[(e.src, e.src_out)]
            store[(node.id, 0)] = (lax.optimization_barrier(val)
                                   if barrier else val)

    # ------------------------------------------------------------ dispatch
    def _dispatch(self, b: _Built, feeds_by_rank):
        """One multi-controller step: N threads each drive their rank's
        jit program on its own device; any rank failure poisons the
        transport so peers fail fast instead of hanging."""
        self._gen += 1
        self.transport.reset()
        with self._events_lock:
            self._events = []
        outs: dict[int, Any] = {}
        errors: dict[int, BaseException] = {}
        # compile barrier: a rank that compiles fast must not start
        # executing (and its transport timeouts ticking) while a peer
        # is still lowering — big models compile rank programs in
        # minutes, far beyond any sane recv timeout.  Each worker AOT-
        # compiles first, then all ranks cross the barrier together.
        gate = threading.Barrier(len(self.devices))

        def worker(r: int) -> None:
            try:
                dev = self._devmap[r]
                prm = jax.device_put(self.params, dev)
                fd = {k: jax.device_put(v, dev)
                      for k, v in feeds_by_rank[r].items()}
                try:
                    if r not in b.compiled:
                        b.compiled[r] = b.fns[r].lower(prm, fd).compile() \
                            if hasattr(b.fns[r], "lower") else b.fns[r]
                    gate.wait(timeout=max(self.timeout, 600.0))
                except BaseException:
                    gate.abort()  # free peers parked at the barrier
                    raise
                # device_get: rank outputs land on rank-local devices;
                # the controller epilogue folds across ranks, so bring
                # every leaf to host (numpy) before mixing them
                outs[r] = jax.device_get(
                    jax.block_until_ready(b.compiled[r](prm, fd)))
            except BaseException as e:
                errors[r] = e
                self.transport.abort(f"rank {r} failed: {e}")

        threads = [threading.Thread(target=worker, args=(r,),
                                    name=f"mpmd-rank{r}")
                   for r in self.devices]
        for t in threads:
            t.start()
        # first dispatch pays AOT compile before the barrier opens;
        # grant it the same generous budget the compile gate uses
        compile_grace = (0.0 if all(r in b.compiled for r in self.devices)
                         else max(self.timeout, 600.0))
        deadline = time.monotonic() + self.timeout + 30 + compile_grace
        for t in threads:
            t.join(max(0.1, deadline - time.monotonic()))
        if any(t.is_alive() for t in threads):
            self.transport.abort("controller join timeout")
            for t in threads:
                t.join(5)
            raise MpmdTransportError(
                "rank program(s) did not finish within the controller "
                "deadline — transport poisoned")
        if errors:
            r, e = sorted(errors.items())[0]
            raise e
        with self._events_lock:
            events = list(self._events)
        return outs, events

    # ------------------------------------------------------------ run
    def run(self, batch: dict[str, Any]) -> RunResult:
        b = self._ensure_built(batch)
        outs, events = self._dispatch(b, self._rank_feeds(batch))
        # loss: reference append order, same stack/mean ops
        losses = [outs[d]["loss"][(nid, slot)]
                  for (nid, slot, d) in b.replay.loss_order]
        loss = float(jnp.mean(jnp.stack(losses)))
        # reduced buckets: replay the interpreter's reduced/reduced_cnt
        # state machine over the owner-recorded reduction events (per
        # bucket the event order IS schedule order — each group's next
        # rendezvous cannot complete before every member passed the
        # previous one)
        reduced: dict[str, Any] = {}
        reduced_cnt: dict[str, int] = {}
        for (bkt, accumulated, mean) in events:
            if bkt in reduced and not accumulated:
                reduced[bkt] = tree_map(jnp.add, reduced[bkt], mean)
                reduced_cnt[bkt] += 1
            else:
                reduced[bkt] = mean
                reduced_cnt[bkt] = 1
        grads: dict[str, Any] = {}
        for bkt, tree in reduced.items():
            cnt = reduced_cnt[bkt]
            grads[bkt] = tree_map(lambda x: jnp.asarray(x / cnt), tree)
        # never-reduced buckets: reference device fold order
        per_bucket: dict[str, list] = {}
        for (bkt, d) in b.replay.grad_key_order:
            if bkt in grads or bkt not in outs[d]["acc"]:
                continue
            cnt = b.acc_cnt[(bkt, d)]
            per_bucket.setdefault(bkt, []).append(
                tree_map(lambda x: x / cnt, outs[d]["acc"][bkt]))
        for bkt, gs in per_bucket.items():
            acc = gs[0]
            for g2 in gs[1:]:
                acc = tree_map(jnp.add, acc, g2)
            grads[bkt] = tree_map(lambda x: x / len(gs), acc)
        return RunResult(
            loss=loss, grads=grads, ledgers={},
            exec_order=list(b.replay.exec_order),
            stats={"backend": "mpmd", "tasks": b.n_tasks,
                   "losses": len(losses), "devices": self.n,
                   "transport": self.transport.name,
                   "reduce_events": len(events)})

    # ------------------------------------------------------------ protocol
    @classmethod
    def compile(cls, prog: CompiledProgram,
                params: Optional[dict[str, Any]] = None, *,
                physical_devices: Optional[Sequence[int]] = None,
                **opts) -> "MpmdExecutor":
        return cls(prog, params, physical_devices=physical_devices,
                   **opts)

    def measure(self, batch: dict[str, Any], reps: int = 3,
                warmup: int = 1) -> float:
        """Wall-clock seconds per multi-controller step (min over
        ``reps`` after ``warmup`` dispatches) — includes per-rank
        dispatch, transport waits, and host device_put, i.e. the real
        MPMD step critical path."""
        if reps < 1:
            raise ValueError(f"measure needs reps >= 1, got {reps}")
        b = self._ensure_built(batch)
        feeds = self._rank_feeds(batch)
        for _ in range(max(warmup, 0)):
            self._dispatch(b, feeds)
        times = []
        for _ in range(reps):
            t0 = time.perf_counter()
            self._dispatch(b, feeds)
            times.append(time.perf_counter() - t0)
        return min(times)

    def trace_sizes(self, batch: dict[str, Any]) -> dict[int, int]:
        """Per-rank traced program size (total jaxpr equation count,
        sub-jaxprs included) — the acceptance metric: every rank's
        count must be strictly below the SPMD whole-mesh trace
        (``SpmdExecutor.trace_size``) for world >= 4."""
        b = self._ensure_built(batch)
        feeds = self._rank_feeds(batch)
        return {r: jaxpr_eqn_count(
            jax.make_jaxpr(b.traced[r])(self.params, feeds[r]))
            for r in self.devices}

    def close(self) -> None:
        self.transport.close()
