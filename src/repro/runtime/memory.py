"""Per-device memory accounting (paper §4.3.2 'Memory management').

Piper manages flat per-bucket buffers for params/grads, temporary full
buffers for ZeRO rematerialization, and intermediate activations freed
after their last consumer.  The interpreter charges every one of those to
a per-device ledger so peak memory is exact — this is what reproduces the
paper's PP x ZeRO results (Fig. 8) on CPU.

Mixed-precision convention (Megatron-style, used for accounting):
  weights bf16 (2 B/elem) · grads fp32 (4 B/elem) ·
  optimizer m+v+master fp32 (12 B/elem)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

WEIGHT_BYTES_PER_ELEM = 2
GRAD_BYTES_PER_ELEM = 4
OPT_BYTES_PER_ELEM = 12


@dataclass
class DeviceLedger:
    device: int
    persistent: int = 0
    current: int = 0
    peak: int = 0
    # live transient allocations: key -> bytes
    live: dict = field(default_factory=dict)
    # lifetime-event hook (static verifier): when a list is supplied,
    # every transition is recorded as (kind, key, nbytes) — including
    # the anomalous ``double_alloc`` (alloc of a live key, normally
    # ignored) and ``double_free`` (free of a dead key, normally a
    # no-op).  The interpreter leaves this None: its accounting is
    # unchanged.
    events: Optional[list] = None

    def alloc_persistent(self, nbytes: int) -> None:
        self.persistent += nbytes
        self.current += nbytes
        self.peak = max(self.peak, self.current)

    def alloc(self, key, nbytes: int) -> None:
        if key in self.live:
            if self.events is not None:
                self.events.append(("double_alloc", key, nbytes))
            return
        if self.events is not None:
            self.events.append(("alloc", key, nbytes))
        self.live[key] = nbytes
        self.current += nbytes
        self.peak = max(self.peak, self.current)

    def free(self, key) -> None:
        if self.events is not None:
            self.events.append(
                ("free" if key in self.live else "double_free", key,
                 self.live.get(key, 0)))
        nbytes = self.live.pop(key, 0)
        self.current -= nbytes

    def snapshot(self) -> dict:
        return {"device": self.device, "persistent": self.persistent,
                "current": self.current, "peak": self.peak,
                "live_buffers": len(self.live)}


def timeline_peak_bytes(prog, records) -> dict:
    """Static per-device peak-memory estimate from a simulated timeline.

    Replays the ``TimelineSimulator`` records (one per executed
    (node, device)) in completion order against the same ledger rules the
    interpreter charges for real: persistent bucket state via
    ``bucket_persistent_bytes``, boundary activations alive from producer
    completion to last on-device consumer, ZeRO-3 full-param buffers over
    their consuming chunks' lifetime, ZeRO-2 full-grad buffers from the
    first backward chunk to the bucket's reduce-scatter.

    ZeRO-3 buffers are charged in one of two modes.  Legacy plans
    (no overlap engine): deliberately NOT from all-gather completion —
    param gathers have no data dependencies, so on the simulated
    timeline they all fire near t=0 and charging there would keep every
    full-param buffer live at once, the "defeats parameter sharding"
    failure mode the interpreter's FSDP-style ``gather_limit`` exists
    to prevent; charging [first consumer, last consumer] models the
    just-in-time prefetch instead.  Overlap-engine plans
    (``dag.meta["overlap"]`` present): the engine's prefetch temporal
    edges gate gather dispatch for real, so the (possibly fused)
    full-param buffer is charged over its true lifetime — from the
    gather's simulated completion to its last consumer.

    This is an *estimate* (used by the strategy autotuner to reject
    over-budget candidates): graph-input buffers and allocator
    fragmentation are not charged, and DP/EP-sharded activations are
    approximated as 1/len(devices) of the unsharded spec.  The
    interpreter's ledger (``RunResult.peak_bytes``) remains the exact
    accounting for programs small enough to execute.
    """
    dag = prog.dag
    ledgers = {d: DeviceLedger(device=d) for d in prog.plan.devices}

    # persistent model state per bucket home
    for bname, bucket in dag.buckets.items():
        homes: set = set()
        for n in dag.nodes.values():
            if n.is_chunk and n.bucket == bname:
                homes.update(n.devices or ())
        for d in homes or {0}:
            if d in ledgers:
                ledgers[d].alloc_persistent(
                    bucket_persistent_bytes(bucket, d))

    # consumer counts per (producer node, device).  Param-slot edges
    # (dst_in < 0: ZeRO-3 gather -> chunk plumbing) are excluded — those
    # bytes are the ("fullparam", g) buffers, charged just-in-time below;
    # counting the gather's output as an activation would both
    # double-charge and pin it from t~=0 (gathers have no data deps).
    cons: dict = {}
    for e in dag.edges:
        if e.dst_in < 0:
            continue
        for d in (dag.nodes[e.dst].devices or ()):
            cons[(e.src, d)] = cons.get((e.src, d), 0) + 1

    def out_bytes(n) -> int:
        return node_out_bytes(n)

    # ZeRO-3 gather lifetimes: gather node -> consuming chunks per device
    gather_left: dict = {}
    for n in dag.nodes.values():
        g = n.meta.get("param_from_comm")
        if g is not None and g in dag.nodes:
            for d in (n.devices or ()):
                gather_left.setdefault((g, d), set()).add(n.id)

    overlap_mode = bool(dag.meta.get("overlap"))
    seen: set = set()
    events = sorted(records, key=lambda r: (r.end, r.start, r.node,
                                            r.device))
    for r in events:
        if (r.node, r.device) in seen or r.node not in dag.nodes:
            continue
        seen.add((r.node, r.device))
        n, d = dag.nodes[r.node], r.device
        led = ledgers[d]
        bucket = n.bucket or n.meta.get("bucket")
        b = dag.buckets.get(bucket) if bucket else None
        if (overlap_mode and n.is_comm and n.op == "all_gather"
                and n.payload == "param"):
            # prefetch gates make gather completion the honest
            # materialization time of the (fused) full-param buffer
            led.alloc(("fullparam", n.id), gather_param_bytes(dag, n))
        g = n.meta.get("param_from_comm")
        if g is not None and not overlap_mode and g in dag.nodes:
            led.alloc(("fullparam", g),
                      gather_param_bytes(dag, dag.nodes[g]))
        if (n.is_chunk and b is not None and b.shard_grads
                and n.dims.get("PASS") in ("B", "Bi", "Bw")):
            led.alloc(("fullgrad", bucket),
                      b.param_elems * GRAD_BYTES_PER_ELEM)
        if (n.is_comm and n.op == "reduce_scatter"
                and n.payload == "grad"):
            for bname in (n.meta.get("buckets")
                          or ([bucket] if bucket else [])):
                led.free(("fullgrad", bname))
        if cons.get((n.id, d)) and not (n.is_comm and n.op == "d2h"):
            # a d2h offload parks its output in host RAM — the device
            # ledger holds nothing between stash and the h2d fetch
            led.alloc(("act", n.id), out_bytes(n))
        for e in dag.in_edges(n.id):
            key = (e.src, d)
            if key in cons:
                cons[key] -= 1
                if cons[key] <= 0:
                    led.free(("act", e.src))
        if g is not None and (g, d) in gather_left:
            gather_left[(g, d)].discard(n.id)
            if not gather_left[(g, d)]:
                led.free(("fullparam", g))
    return {d: led.peak for d, led in ledgers.items()}


def node_out_bytes(n) -> int:
    """Per-device activation bytes a node's outputs pin — the sizing rule
    shared by the static timeline estimator above and the verifier's
    abstract executor (``repro.analysis.abstract``), so their ledgers
    are comparable buffer for buffer."""
    total = sum(s.nbytes for s in n.out_specs)
    if n.is_comm and n.op == "p2p":
        # pairwise replica transfer: each receiver holds its own
        # producer's shard (1/len(pairs) of the spec); a
        # single-source fan-out delivers the full value to every
        # receiver
        pairs = n.meta.get("pairs") or ()
        srcs = {s for (s, _) in pairs}
        if len(pairs) > 1 and len(srcs) == len(pairs):
            return total // len(pairs)
        return total
    k = len(n.devices or ()) or 1
    if n.is_comm and n.meta.get("offload_static"):
        # batch-static residual offload: a full copy per replica
        return total
    if k > 1 and (n.meta.get("placement_mode") in
                  ("replicate", "shard_expert")
                  or (n.is_comm and n.payload == "act")):
        return total // k
    return total


def gather_param_bytes(dag, gnode) -> int:
    """Full-param bytes a (possibly fused) ZeRO-3 all-gather
    materializes: sum over its member buckets.

    A member bucket missing from ``dag.buckets`` is an IR bug (a fusion
    or rename pass dropped the bucket registration); silently skipping
    it would undercount peak memory, so fail loudly instead."""
    names = gnode.meta.get("buckets")
    if not names:
        b = gnode.meta.get("bucket")
        names = [b] if b else []
    total = 0
    for b in names:
        if b not in dag.buckets:
            raise KeyError(
                f"all-gather node {gnode.short()} references param "
                f"bucket {b!r} that is missing from dag.buckets "
                f"(known: {sorted(dag.buckets)}) — peak-memory "
                "accounting would silently undercount")
        total += dag.buckets[b].param_elems * WEIGHT_BYTES_PER_ELEM
    return total


def bucket_persistent_bytes(bucket, device: int) -> int:
    """Persistent model-state bytes bucket ``bucket`` pins on ``device``."""
    elems = bucket.param_elems
    dp = len(bucket.replica_devices) if bucket.replica_devices else 1
    ep = len(bucket.expert_devices) if bucket.expert_devices else 1
    elems = elems // ep  # expert shard
    w = elems * WEIGHT_BYTES_PER_ELEM
    if bucket.shard_params:
        w //= dp
    g = elems * GRAD_BYTES_PER_ELEM
    if bucket.shard_grads:
        g //= dp
    o = elems * OPT_BYTES_PER_ELEM
    if bucket.shard_opt and dp > 1:
        o //= dp
    return w + g + o
