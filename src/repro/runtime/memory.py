"""Per-device memory accounting (paper §4.3.2 'Memory management').

Piper manages flat per-bucket buffers for params/grads, temporary full
buffers for ZeRO rematerialization, and intermediate activations freed
after their last consumer.  The interpreter charges every one of those to
a per-device ledger so peak memory is exact — this is what reproduces the
paper's PP x ZeRO results (Fig. 8) on CPU.

Mixed-precision convention (Megatron-style, used for accounting):
  weights bf16 (2 B/elem) · grads fp32 (4 B/elem) ·
  optimizer m+v+master fp32 (12 B/elem)
"""
from __future__ import annotations

from dataclasses import dataclass, field

WEIGHT_BYTES_PER_ELEM = 2
GRAD_BYTES_PER_ELEM = 4
OPT_BYTES_PER_ELEM = 12


@dataclass
class DeviceLedger:
    device: int
    persistent: int = 0
    current: int = 0
    peak: int = 0
    # live transient allocations: key -> bytes
    live: dict = field(default_factory=dict)

    def alloc_persistent(self, nbytes: int) -> None:
        self.persistent += nbytes
        self.current += nbytes
        self.peak = max(self.peak, self.current)

    def alloc(self, key, nbytes: int) -> None:
        if key in self.live:
            return
        self.live[key] = nbytes
        self.current += nbytes
        self.peak = max(self.peak, self.current)

    def free(self, key) -> None:
        nbytes = self.live.pop(key, 0)
        self.current -= nbytes

    def snapshot(self) -> dict:
        return {"device": self.device, "persistent": self.persistent,
                "current": self.current, "peak": self.peak,
                "live_buffers": len(self.live)}


def bucket_persistent_bytes(bucket, device: int) -> int:
    """Persistent model-state bytes bucket ``bucket`` pins on ``device``."""
    elems = bucket.param_elems
    dp = len(bucket.replica_devices) if bucket.replica_devices else 1
    ep = len(bucket.expert_devices) if bucket.expert_devices else 1
    elems = elems // ep  # expert shard
    w = elems * WEIGHT_BYTES_PER_ELEM
    if bucket.shard_params:
        w //= dp
    g = elems * GRAD_BYTES_PER_ELEM
    if bucket.shard_grads:
        g //= dp
    o = elems * OPT_BYTES_PER_ELEM
    if bucket.shard_opt and dp > 1:
        o //= dp
    return w + g + o
