"""SPMD plan executor: run a compiled ``GlobalPlan`` on real XLA devices.

The reference ``Interpreter`` *simulates* devices (one Python loop, one
jit per chunk, no wire traffic).  This module lowers the same plan into
ONE ``jax.jit`` + ``shard_map`` program over N real XLA devices — on CI,
host-platform devices faked with ``--xla_force_host_platform_device_count``
(``launch.hostdevices.ensure_host_devices``); on TPU/GPU, the physical
chips — so every collective in the plan becomes a real XLA collective on
the wire, in the plan's dispatch order.

IR-op -> lax lowering (DESIGN.md §12 has the full table):

  chunk                 traced compute, ``lax.cond``-gated on membership
                        of the chunk's device set (non-members take a
                        zeros branch, so at runtime each rank executes
                        only its own plan slice)
  p2p send/recv         ``lax.ppermute`` with the node's (src, dst)
                        pairs (non-destinations receive zeros)
  all_gather (param)    the bucket's params, bit-cast to one byte
                        vector, sharded 1/|group| per rank and
                        reassembled with ``lax.all_gather(tiled=True)``
                        over the subgroup; consuming chunks read the
                        GATHERED tree (the collective is load-bearing —
                        XLA cannot dead-code it away).  A fused node
                        (overlap engine) concatenates its member
                        buckets' bytes into ONE collective.
  all_reduce (grad)     ``lax.psum`` of the locally accumulated,
                        1/count-prescaled bucket grads over the replica
                        subgroup (fused members concatenate per dtype
                        into one collective)
  reduce_scatter (grad) ``lax.psum_scatter(tiled=True)`` over the
                        subgroup; an epilogue ``all_gather`` immediately
                        reassembles the full mean so the executor can
                        return the reference RunResult contract (full
                        grads).  Real ZeRO keeps the shard — the extra
                        gather is parity bookkeeping, and is part of
                        what this harness measures.
  all_to_all (EP)       an involutive double ``lax.all_to_all`` round
                        trip over the expert subgroup: real dispatch +
                        return bytes on the wire, bit-identical values
                        (the reference runtime models EP math as
                        shard-local with the full expert stack)
  d2h / h2d (Offload)   documented on-device fallback:
                        ``lax.optimization_barrier`` identity.  Host
                        callbacks would serialize the whole program on
                        CPU hosts; the barrier keeps the node's ordering
                        without modelling DMA time.

Bit-parity with the reference interpreter is by construction: the
executor traces nodes in the interpreter's OWN dynamic dispatch order
(``interpreter.replay_schedule`` — a schedule-only replay of the worker
loop, including the FSDP-style gather rate limiter), accumulates
gradients and losses in that order, and applies exactly the reference
reduction formulas (``sum(x/c)/n`` then the per-microbatch fold).  With
replica groups of size 2 every cross-rank sum is order-free in IEEE
arithmetic, so fp64 loss/grads match the interpreter bit for bit
(tests/test_spmd_executor.py).

What the host-device harness measures — and does not:

  * measures: the XLA-compiled critical path of the fused program —
    real collective dispatch, real inter-device copies on the host
    platform, cond-gated per-rank compute;
  * does not: HBM pressure (host RAM is shared), ICI/DCN link time
    (host "links" are memcpy), host-offload DMA (barrier fallback), or
    overlap of compute with communication (XLA's CPU collectives are
    synchronous).  Measured/predicted ratios (benchmarks/
    bench_spmd_parity.py) are therefore calibration inputs
    (``tune.measured``), not absolute claims.

A plan that fails ``validate_comm_order`` is rejected at construction,
BEFORE tracing — the static analogue of the hang such a plan would
cause on a real multi-controller cluster.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh as XlaMesh
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.6 promotes shard_map out of experimental
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map

from ..core.compiler import CompiledProgram
from ..core.dag import Node, TrainingDAG
from ..core.plan import ROLE_SEND
from ..core.scheduler import validate_comm_order
from .executor import jaxpr_eqn_count, register_backend
from .interpreter import RunResult, ScheduleReplay, _PlanWalker

AXIS = "spmd"

tree_map = jax.tree_util.tree_map
tree_flatten = jax.tree_util.tree_flatten
tree_unflatten = jax.tree_util.tree_unflatten
tree_leaves = jax.tree_util.tree_leaves


# ---------------------------------------------------------------------------
# byte/flat codecs (bit-exact tree <-> vector, for wire collectives)
# ---------------------------------------------------------------------------

def _tree_to_bytes(tree):
    """Flatten a pytree to one uint8 vector (bit-exact, dtype-agnostic).
    Returns (u8, recipe); ``_bytes_to_tree`` inverts."""
    leaves, treedef = tree_flatten(tree)
    chunks, recipe = [], []
    for l in leaves:
        dt = jnp.dtype(l.dtype)
        if dt == jnp.uint8:
            u8 = l.reshape(-1)
        else:
            u8 = lax.bitcast_convert_type(l, jnp.uint8).reshape(-1)
        chunks.append(u8)
        recipe.append((tuple(l.shape), dt))
    u8 = (jnp.concatenate(chunks) if len(chunks) > 1
          else chunks[0] if chunks else jnp.zeros((0,), jnp.uint8))
    return u8, (treedef, recipe)


def _bytes_to_tree(u8, recipe):
    treedef, leaf_recipe = recipe
    leaves, off = [], 0
    for shape, dt in leaf_recipe:
        n = int(np.prod(shape, dtype=np.int64)) if shape else 1
        nbytes = n * dt.itemsize
        seg = u8[off:off + nbytes]
        off += nbytes
        if dt == jnp.uint8:
            leaves.append(seg.reshape(shape))
        elif dt.itemsize == 1:
            leaves.append(lax.bitcast_convert_type(seg.reshape(shape), dt))
        else:
            leaves.append(lax.bitcast_convert_type(
                seg.reshape(tuple(shape) + (dt.itemsize,)), dt))
    return tree_unflatten(treedef, leaves)


def _flatten_by_dtype(tree):
    """Flatten a (gradient) pytree into one 1-D vector per dtype.
    Returns ({dtype_str: flat}, recipe)."""
    leaves, treedef = tree_flatten(tree)
    parts: dict[str, list] = {}
    recipe = []
    for l in leaves:
        dt = str(l.dtype)
        lst = parts.setdefault(dt, [])
        off = sum(int(x.size) for x in lst)
        lst.append(l.reshape(-1))
        recipe.append((dt, off, int(l.size), tuple(l.shape)))
    flats = {dt: (jnp.concatenate(lst) if len(lst) > 1 else lst[0])
             for dt, lst in parts.items()}
    return flats, (treedef, recipe)


def _unflatten_by_dtype(flats, recipe):
    treedef, leaf_recipe = recipe
    leaves = [flats[dt][off:off + n].reshape(shape)
              for (dt, off, n, shape) in leaf_recipe]
    return tree_unflatten(treedef, leaves)


def gather_chunk_args(dag: TrainingDAG, node: Node, feeds, store):
    """``Interpreter._gather_chunk_inputs`` on rank-local (nid, slot)
    keys: multi-source cotangent slots sum in edge order; seed/zero
    cotangent slots materialize from the forward's out_specs.  Shared
    by the SPMD trace (one whole-mesh program) and the MPMD per-rank
    traces (``runtime/mpmd.py``) — one source of truth for how a traced
    chunk assembles its inputs."""
    m = node.meta.get("n_inputs", 0)
    args: list = []
    for slot in range(m):
        key = (node.id, slot)
        if key in feeds:
            args.append(feeds[key])
            continue
        vals = [store[(e.src, e.src_out)]
                for e in dag.in_edges(node.id)
                if e.dst_in == slot]
        if not vals:
            if slot in node.meta.get("zero_cot_slots", []) \
                    or slot in node.meta.get("seed_slots", []):
                args.append(None)
                continue
            raise KeyError(
                f"no value for {node.short()} slot {slot}")
        args.append(vals[0] if len(vals) == 1
                    else sum(vals[1:], vals[0]))
    if "fwd_node" in node.meta:
        fwd = dag.nodes[node.meta["fwd_node"]]
        n_cots = node.meta.get("n_cots", fwd.n_outputs)
        m0 = node.meta["n_inputs"] - n_cots
        for slot in node.meta.get("seed_slots", []):
            s = fwd.out_specs[slot - m0]
            args[slot] = jnp.ones(s.shape, dtype=s.dtype)
        for slot in node.meta.get("zero_cot_slots", []):
            s = fwd.out_specs[slot - m0]
            args[slot] = jnp.zeros(s.shape, dtype=s.dtype)
    return args


@dataclass
class _Built:
    """One traced+jitted program (per batch-shape signature) plus the
    trace-time bookkeeping the extraction epilogue reads."""
    fn: Any
    replay: ScheduleReplay
    reduced_cnt: dict = field(default_factory=dict)    # bucket -> int
    red_group: dict = field(default_factory=dict)      # bucket -> devices
    acc_cnt: dict = field(default_factory=dict)        # bucket -> int
    n_tasks: int = 0
    traced_sm: Any = None   # unjitted shard_map fn (trace_size probes it)


class SpmdBackendError(RuntimeError):
    """The SPMD executor cannot run this plan on the available devices
    (too few XLA devices, or a collective group the 1-D axis cannot
    express)."""


@register_backend("spmd")
class SpmdExecutor:
    """Execute a ``CompiledProgram`` as one jit+shard_map SPMD program
    over ``len(plan.devices)`` real XLA devices.

    ``gate_compute=False`` disables the per-chunk ``lax.cond`` rank
    gates (every rank computes every chunk) — numerics are unchanged;
    only useful for debugging XLA cond issues."""

    def __init__(self, prog: CompiledProgram,
                 params: Optional[dict[str, Any]] = None, *,
                 gate_compute: bool = True,
                 gather_limit: Optional[int] = None,
                 physical_devices: Optional[Sequence[int]] = None) -> None:
        # hang detection: reject invalid comm orders BEFORE tracing —
        # the dynamic analogue is a rendezvous deadlock on real ranks
        validate_comm_order(prog.dag, prog.plan)
        self.prog = prog
        self.dag = prog.dag
        self.plan = prog.plan
        self.params = params if params is not None else prog.params
        self.gate_compute = gate_compute
        self.gather_limit = gather_limit
        self.devices = sorted(self.plan.devices)
        self.n = len(self.devices)
        self._idx = {d: i for i, d in enumerate(self.devices)}
        avail = jax.devices()
        if len(avail) < self.n:
            raise SpmdBackendError(
                f"plan spans {self.n} devices but jax sees only "
                f"{len(avail)}; fake host devices with launch.hostdevices."
                "ensure_host_devices(n) BEFORE jax initializes (tests use "
                "a subprocess with XLA_FLAGS="
                f"--xla_force_host_platform_device_count={self.n})")
        if physical_devices is not None:
            # elastic recovery: map the n logical plan ranks onto the
            # SURVIVING physical devices (by jax.devices() index), so a
            # shrunk-world program never touches the failed chip.  The
            # same mapping RE-expands on regrowth: survivors keep their
            # slots and replacement devices fill the new trailing ranks
            # (any distinct index set works — the indices need not be
            # contiguous or sorted, so a world regrown around a dead
            # chip simply never names it)
            phys = [int(p) for p in physical_devices]
            if len(phys) != self.n:
                raise SpmdBackendError(
                    f"plan spans {self.n} devices but physical_devices "
                    f"names {len(phys)}: {phys}")
            bad = [p for p in phys if not 0 <= p < len(avail)]
            if bad or len(set(phys)) != len(phys):
                raise SpmdBackendError(
                    f"physical_devices must be {len(phys)} distinct "
                    f"indices into jax.devices() (0..{len(avail)-1}), "
                    f"got {phys}")
            chosen = [avail[p] for p in phys]
        else:
            chosen = avail[:self.n]
        self.physical_devices = tuple(
            d.id if hasattr(d, "id") else i for i, d in enumerate(chosen))
        self.mesh = XlaMesh(np.array(chosen), (AXIS,))
        self._built: dict[tuple, _Built] = {}
        # feed resolution reuses the interpreter's input distribution
        # rules verbatim (one source of truth for microbatch slicing)
        self._resolver = _PlanWalker(prog, gather_limit=gather_limit)

    # ------------------------------------------------------------ helpers
    def _sig(self, batch) -> tuple:
        # cache key from shape/dtype attributes only — np.asarray here
        # would force a device-to-host transfer per call on real chips
        return tuple(sorted(
            (k, tuple(np.shape(v)),
             str(getattr(v, "dtype", None) or np.asarray(v).dtype))
            for k, v in batch.items()))

    def _axis_groups(self, group_devices):
        """(group_size, axis_index_groups) for a collective over plan
        devices.  The 1-D SPMD axis can express a subgroup only as a
        partition into equal contiguous aligned runs — which rank-major
        ``core.strategy.Mesh`` device groups always are."""
        gidx = sorted(self._idx[d] for d in group_devices)
        g = len(gidx)
        if g == self.n and gidx == list(range(self.n)):
            return g, None
        lo = gidx[0]
        if gidx == list(range(lo, lo + g)) and lo % g == 0 \
                and self.n % g == 0:
            return g, [list(range(i * g, (i + 1) * g))
                       for i in range(self.n // g)]
        raise SpmdBackendError(
            f"collective group {tuple(group_devices)} is not a contiguous "
            f"aligned run of the {self.n}-rank SPMD axis; rank-major mesh "
            "device groups always are (custom RawDirectives placements "
            "may not be)")

    def _member_pred(self, rank, devs):
        gidx = [self._idx[d] for d in devs]
        if len(gidx) == 1:
            return rank == gidx[0]
        return jnp.isin(rank, jnp.asarray(gidx))

    def _stack_feeds(self, batch):
        """Per-(consumer, slot) rank-major stacked feed arrays: slice r
        holds what plan device r consumes (zeros on non-consumers);
        shard_map's ``P(AXIS)`` in_spec hands each rank its slice."""
        feeds3 = self._resolver._resolve_inputs(batch)
        by_key: dict[tuple, dict[int, np.ndarray]] = {}
        for (nid, slot, d), v in feeds3.items():
            by_key.setdefault((nid, slot), {})[d] = np.asarray(v)
        stacked = {}
        for k, per_dev in sorted(by_key.items()):
            sample = next(iter(per_dev.values()))
            arr = np.zeros((self.n,) + sample.shape, sample.dtype)
            for d, v in per_dev.items():
                arr[self._idx[d]] = v
            stacked[k] = jnp.asarray(arr)
        return stacked

    # ------------------------------------------------------------ build
    def _build(self, batch) -> _Built:
        replay = self._resolver.replay(batch)
        b = _Built(fn=None, replay=replay,
                   n_tasks=sum(p.n_tasks()
                               for p in self.plan.device_plans.values()))
        # first-occurrence node trace order from the replayed dispatch
        trace_order: list[int] = []
        seen: set[int] = set()
        for (nid, _dev, role) in replay.exec_order:
            if role == ROLE_SEND or nid in seen:
                continue
            seen.add(nid)
            trace_order.append(nid)
        traced = self._make_traced(trace_order, b)
        sm = _shard_map(traced, mesh=self.mesh, in_specs=(P(), P(AXIS)),
                        out_specs=P(AXIS), check_rep=False)
        b.traced_sm = sm
        b.fn = jax.jit(sm)
        return b

    # ------------------------------------------------------------ tracing
    def _make_traced(self, trace_order, built: _Built):
        dag, params = self.dag, self.params

        def traced(prm, feeds_in):
            rank = lax.axis_index(AXIS)
            feeds = {k: v[0] for k, v in feeds_in.items()}  # local block
            store: dict[tuple[int, int], Any] = {}
            gathered: dict[int, dict[str, Any]] = {}
            grad_acc: dict[str, Any] = {}
            grad_cnt: dict[str, int] = {}
            acc_devs: dict[str, set] = {}
            reduced: dict[str, Any] = {}
            loss_vals: dict[tuple[int, int], Any] = {}

            for nid in trace_order:
                node = dag.nodes[nid]
                if node.is_chunk:
                    self._trace_chunk(node, rank, prm, feeds, store,
                                      gathered, grad_acc, grad_cnt,
                                      acc_devs, loss_vals, built)
                elif node.op == "p2p":
                    self._trace_p2p(node, store)
                elif node.op == "all_gather" and node.payload == "param":
                    self._trace_param_gather(node, rank, prm, gathered)
                elif node.op in ("all_reduce", "reduce_scatter") \
                        and node.payload == "grad":
                    self._trace_grad_reduce(node, grad_acc, grad_cnt,
                                            acc_devs, reduced, built)
                elif node.op in ("d2h", "h2d"):
                    self._trace_passthrough(node, store, barrier=True)
                elif node.op == "all_to_all":
                    self._trace_a2a(node, store)
                else:  # broadcast / generic activation collective
                    self._trace_passthrough(node, store, barrier=False)

            for bkt, cnt in grad_cnt.items():   # never-reduced buckets
                built.acc_cnt[bkt] = cnt
            out = {
                "loss": {k: v[None] for k, v in loss_vals.items()},
                "reduced": tree_map(lambda x: x[None], reduced),
                "acc": {bkt: tree_map(lambda x: x[None], grad_acc[bkt])
                        for bkt in grad_cnt},
            }
            return out

        return traced

    # -- chunks --------------------------------------------------------------
    def _trace_chunk(self, node, rank, prm, feeds, store, gathered,
                     grad_acc, grad_cnt, acc_devs, loss_vals, built):
        args = gather_chunk_args(self.dag, node, feeds, store)
        g = node.meta.get("param_from_comm")
        if node.bucket is not None:
            bparams = (gathered[g][node.bucket] if g in gathered
                       else prm.get(node.bucket))
        else:
            bparams = None

        def run_fn(ops):
            bp, a = ops
            return node.fn(bp, *a)

        operands = (bparams, tuple(args))
        devs = node.devices or self.devices
        gate = self.gate_compute and set(devs) != set(self.devices)
        if gate:
            out_avals = jax.eval_shape(run_fn, operands)
            zeros = tree_map(lambda a: jnp.zeros(a.shape, a.dtype),
                             out_avals)
            pred = self._member_pred(rank, devs)
            outs = lax.cond(pred, run_fn, lambda _ops: zeros, operands)
        else:
            outs = run_fn(operands)

        if node.meta.get("is_backward", False):
            bucket_grads = outs[0]
            cots = outs[1:]
            if node.bucket is not None and bucket_grads is not None:
                bkt = node.bucket
                grad_acc[bkt] = (bucket_grads if bkt not in grad_acc
                                 else tree_map(jnp.add, grad_acc[bkt],
                                               bucket_grads))
                grad_cnt[bkt] = grad_cnt.get(bkt, 0) + 1
                acc_devs.setdefault(bkt, set()).update(devs)
            out_vals = cots
            out_slots = list(range(1, 1 + len(cots)))
        else:
            out_vals = outs
            out_slots = list(range(len(outs)))
        discard = set(node.meta.get("discard_out_slots", []))
        for slot, val in zip(out_slots, out_vals):
            if slot in discard or val is None:
                continue
            store[(node.id, slot)] = val
        for (nid, slot) in self.dag.outputs:
            if nid == node.id:
                loss_vals[(nid, slot)] = outs[slot]

    # -- comms ---------------------------------------------------------------
    def _trace_p2p(self, node, store):
        e_in = self.dag.in_edges(node.id)
        assert len(e_in) == 1, f"p2p with {len(e_in)} inputs"
        e = e_in[0]
        val = store[(e.src, e.src_out)]
        perm = [(self._idx[s], self._idx[d])
                for (s, d) in node.meta["pairs"]]
        store[(node.id, 0)] = lax.ppermute(val, AXIS, perm)

    def _trace_passthrough(self, node, store, *, barrier: bool):
        for e in self.dag.in_edges(node.id):
            val = store[(e.src, e.src_out)]
            store[(node.id, 0)] = (lax.optimization_barrier(val)
                                   if barrier else val)

    def _trace_a2a(self, node, store):
        e_in = self.dag.in_edges(node.id)
        assert len(e_in) == 1, f"a2a with {len(e_in)} inputs"
        e = e_in[0]
        val = store[(e.src, e.src_out)]
        g, subs = self._axis_groups(node.group or node.devices)
        if g > 1 and val.ndim >= 1 and val.shape[0] % g == 0:
            # involutive round trip: dispatch + return on the wire,
            # identity on the values (matches the reference runtime's
            # shard-local EP numerics)
            fwd = lax.all_to_all(val, AXIS, split_axis=0, concat_axis=0,
                                 axis_index_groups=subs, tiled=True)
            val = lax.all_to_all(fwd, AXIS, split_axis=0, concat_axis=0,
                                 axis_index_groups=subs, tiled=True)
        else:
            val = lax.optimization_barrier(val)
        store[(node.id, 0)] = val

    def _trace_param_gather(self, node, rank, prm, gathered):
        buckets = node.meta.get("buckets") or [node.meta["bucket"]]
        g, subs = self._axis_groups(node.group or node.devices)
        if g <= 1:
            gathered[node.id] = {b: prm[b] for b in buckets}
            return
        # fused buckets lower as ONE concatenated byte collective
        flats, metas = [], []
        for b in buckets:
            u8, recipe = _tree_to_bytes(prm[b])
            flats.append(u8)
            metas.append((b, recipe, int(u8.size)))
        cat = jnp.concatenate(flats) if len(flats) > 1 else flats[0]
        total = int(cat.size)
        chunk = -(-total // g)  # ceil: pad to g equal shards
        padded = (jnp.concatenate(
            [cat, jnp.zeros((chunk * g - total,), cat.dtype)])
            if chunk * g != total else cat)
        pos = rank % g  # local position within the aligned subgroup
        shard = lax.dynamic_slice(padded, (pos * chunk,), (chunk,))
        full = lax.all_gather(shard, AXIS, axis_index_groups=subs,
                              tiled=True)[:total]
        out, off = {}, 0
        for b, recipe, nbytes in metas:
            out[b] = _bytes_to_tree(full[off:off + nbytes], recipe)
            off += nbytes
        gathered[node.id] = out

    def _trace_grad_reduce(self, node, grad_acc, grad_cnt, acc_devs,
                           reduced, built):
        g, subs = self._axis_groups(node.group or node.devices)
        group = set(node.group or node.devices)
        members = []
        for m in node.meta.get("fused_members") or [node.meta]:
            if m.get("part", 0) != 0:
                continue  # bucket_sz parts: numerics once, on part 0
            bkt = m["bucket"]
            if bkt not in grad_acc:
                continue  # no contributions yet (mirrors interpreter)
            members.append((bkt, bool(m.get("accumulated"))))
        if not members:
            return
        # pre-scale each contribution by 1/count (reference formula
        # sum(x/c)/n), flatten, and run ONE collective per dtype over
        # the concatenated fused payload
        scaled, recipes, contrib = [], [], []
        for bkt, _acc in members:
            cnt = grad_cnt[bkt]
            tr = tree_map(lambda x: x / cnt, grad_acc[bkt])
            flats, recipe = _flatten_by_dtype(tr)
            scaled.append(flats)
            recipes.append(recipe)
            contrib.append(max(len(acc_devs.get(bkt, set()) & group), 1))
        per_dtype: dict[str, list] = {}
        bounds: list[dict[str, tuple[int, int]]] = []
        for flats in scaled:
            d = {}
            for dt, flat in flats.items():
                lst = per_dtype.setdefault(dt, [])
                off = sum(int(x.size) for x in lst)
                lst.append(flat)
                d[dt] = (off, int(flat.size))
            bounds.append(d)
        summed: dict[str, Any] = {}
        for dt, lst in per_dtype.items():
            cat = jnp.concatenate(lst) if len(lst) > 1 else lst[0]
            if g <= 1:
                summed[dt] = cat
            elif node.op == "all_reduce":
                summed[dt] = lax.psum(cat, AXIS, axis_index_groups=subs)
            else:  # reduce_scatter: real scatter + parity epilogue gather
                total = int(cat.size)
                chunk = -(-total // g)
                padded = (jnp.concatenate(
                    [cat, jnp.zeros((chunk * g - total,), cat.dtype)])
                    if chunk * g != total else cat)
                shard = lax.psum_scatter(padded, AXIS,
                                         axis_index_groups=subs,
                                         tiled=True)
                summed[dt] = lax.all_gather(
                    shard, AXIS, axis_index_groups=subs,
                    tiled=True)[:total]
        for (bkt, accumulated), recipe, d, n_contrib in zip(
                members, recipes, bounds, contrib):
            flats = {dt: summed[dt][off:off + n]
                     for dt, (off, n) in d.items()}
            mean = tree_map(lambda x: x / n_contrib,
                            _unflatten_by_dtype(flats, recipe))
            if bkt in reduced and not accumulated:
                reduced[bkt] = tree_map(jnp.add, reduced[bkt], mean)
                built.reduced_cnt[bkt] += 1
            else:
                reduced[bkt] = mean
                built.reduced_cnt[bkt] = 1
            built.red_group[bkt] = tuple(sorted(group))
            grad_acc.pop(bkt, None)
            grad_cnt.pop(bkt, None)
            acc_devs.pop(bkt, None)

    # ------------------------------------------------------------ run
    def _ensure_built(self, batch) -> _Built:
        key = self._sig(batch)
        if key not in self._built:
            self._built[key] = self._build(batch)
        return self._built[key]

    def run(self, batch: dict[str, Any]) -> RunResult:
        b = self._ensure_built(batch)
        # feeds are re-stacked per call, never cached by signature: a
        # training loop passes same-shaped batches with NEW data every
        # step, so a signature-keyed cache would serve stale values.
        # The stacking is O(batch bytes) of host work — noise next to
        # the device step it feeds.
        feeds = self._stack_feeds(batch)
        out = b.fn(self.params, feeds)
        # loss: mean over per-task loss values in the reference append
        # order (same stack, same op, same element order)
        losses = [out["loss"][(nid, slot)][self._idx[d]]
                  for (nid, slot, d) in b.replay.loss_order]
        loss = float(jnp.mean(jnp.stack(losses)))
        grads: dict[str, Any] = {}
        for bkt, tree in out["reduced"].items():
            own = self._idx[b.red_group[bkt][0]]
            cnt = b.reduced_cnt[bkt]
            grads[bkt] = tree_map(lambda x: x[own] / cnt, tree)
        per_bucket_dev: dict[str, list] = {}
        for (bkt, d) in b.replay.grad_key_order:
            if bkt in grads or bkt not in out["acc"]:
                continue
            i = self._idx[d]
            cnt = b.acc_cnt[bkt]
            per_bucket_dev.setdefault(bkt, []).append(
                tree_map(lambda x: x[i] / cnt, out["acc"][bkt]))
        for bkt, gs in per_bucket_dev.items():
            acc = gs[0]
            for gg in gs[1:]:
                acc = tree_map(jnp.add, acc, gg)
            grads[bkt] = tree_map(lambda x: x / len(gs), acc)
        return RunResult(loss=loss, grads=grads, ledgers={},
                         exec_order=list(b.replay.exec_order),
                         stats={"backend": "spmd", "tasks": b.n_tasks,
                                "losses": len(losses),
                                "devices": self.n})

    def measure(self, batch: dict[str, Any], reps: int = 3,
                warmup: int = 1) -> float:
        """Wall-clock seconds per step of the compiled SPMD program
        (min over ``reps``, after ``warmup`` compile+run calls;
        ``warmup=0`` includes first-dispatch cost)."""
        if reps < 1:
            raise ValueError(f"measure needs reps >= 1, got {reps}")
        b = self._ensure_built(batch)
        feeds = self._stack_feeds(batch)
        for _ in range(max(warmup, 0)):
            jax.block_until_ready(b.fn(self.params, feeds))
        times = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(b.fn(self.params, feeds))
            times.append(time.perf_counter() - t0)
        return min(times)

    # ------------------------------------------------------------ protocol
    @classmethod
    def compile(cls, prog: CompiledProgram,
                params: Optional[dict[str, Any]] = None, *,
                physical_devices: Optional[Sequence[int]] = None,
                **opts) -> "SpmdExecutor":
        return cls(prog, params, physical_devices=physical_devices,
                   **opts)

    def trace_size(self, batch: dict[str, Any]) -> int:
        """Whole-mesh traced program size (total jaxpr equation count,
        sub-jaxprs included) — every device carries this entire trace.
        The MPMD per-rank programs (``MpmdExecutor.trace_sizes``) must
        each come in strictly below it for world >= 4."""
        b = self._ensure_built(batch)
        feeds = self._stack_feeds(batch)
        return jaxpr_eqn_count(
            jax.make_jaxpr(b.traced_sm)(self.params, feeds))
