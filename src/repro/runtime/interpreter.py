"""Strategy-agnostic multi-device interpreter (paper §4.3.2 worker loop).

Executes a compiled ``GlobalPlan`` on simulated devices with real numerics:
each device owns per-stream in-order task queues; a task dispatches when its
dependencies are done AND it is at the head of its stream; collectives
rendezvous across all member devices' stream heads.  If no task can make
progress the interpreter raises — this is the dynamic analogue of the
scheduler's communication-order validation (a mismatched dispatch order on
a shared communicator would hang a real cluster).

Numerics conventions (DESIGN.md §2):
  - DP / EP chunks process per-device input shards; gradient all-reduce
    averages over the replica group; microbatch accumulation averages over
    microbatches (loss = global-batch mean).
  - ZeRO all-gathers/reduce-scatters are numerically transparent (sharding
    is a *placement* of identical math) but fully accounted in the memory
    ledger: temporary full-param and full-grad buffers live exactly from
    materialization to last consumer, as in the paper's buffer management.

This component is how we validate the paper's safety guarantee on CPU:
any directive-transformed DAG must produce the same loss/grads as the
untransformed single-device execution.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..core.compiler import CompiledProgram
from ..core.dag import Node, TrainingDAG
from ..core.plan import (ROLE_COLL,
                         ROLE_RECV,
                         ROLE_SEND,
                         GlobalPlan,
                         Task,
                         TaskKey)
from .executor import register_backend
from .memory import (GRAD_BYTES_PER_ELEM, DeviceLedger,
                     bucket_persistent_bytes, gather_param_bytes)


@dataclass
class RunResult:
    loss: float
    grads: dict[str, Any]
    ledgers: dict[int, DeviceLedger]
    exec_order: list[TaskKey]
    stats: dict[str, Any] = field(default_factory=dict)

    def peak_bytes(self) -> dict[int, int]:
        return {d: l.peak for d, l in self.ledgers.items()}

    def max_peak(self) -> int:
        # the SPMD backend returns no ledgers — the host harness
        # measures time, not device memory (DESIGN.md §12)
        return max((l.peak for l in self.ledgers.values()), default=0)


def tree_nbytes_actual(tree) -> int:
    return sum(l.size * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(tree) if l is not None)


@register_backend("reference")
class Interpreter:
    def __init__(self, prog: CompiledProgram,
                 params: Optional[dict[str, Any]] = None,
                 track_memory: bool = True,
                 gather_limit: Optional[int] = None) -> None:
        """``gather_limit``: max in-flight ZeRO-3 full-param buffers per
        device (FSDP-style rate limiter — without it every all-gather
        would dispatch at t=0 and defeat parameter sharding).  Defaults
        to the overlap engine's prefetch depth when the compiled DAG
        carries one (``dag.meta["gather_limit"]``), else 2."""
        self.prog = prog
        self.dag: TrainingDAG = prog.dag
        self.plan: GlobalPlan = prog.plan
        self.params = params if params is not None else prog.params
        self.track_memory = track_memory
        if gather_limit is None:
            gather_limit = int(self.dag.meta.get("gather_limit", 2))
        self.gather_limit = gather_limit
        # Executor-protocol surface: devices are simulated, so the
        # "physical" ranks are simply the plan's logical device ids
        self.physical_devices = tuple(sorted(self.plan.devices))
        # per-node jitted exec functions (paper: Chunk.exec dispatch) —
        # retracing eagerly per call would dominate dispatch overhead
        self._jit_cache: dict[int, Any] = {}
        # ---- per-run invariants, hoisted so repeated run() calls (the
        # autotuner, parity benches) do not recompute graph-shaped maps;
        # run() copies the mutable ones before consuming them ----------
        self._cons0 = self._consumer_counts()
        self._feed_name: dict[tuple[int, int], str] = {}
        self._feed_left0: dict[tuple[str, int], int] = {}
        for name, (_spec, consumers) in self.dag.inputs.items():
            for (nid, slot) in consumers:
                self._feed_name[(nid, slot)] = name
                for d in self.dag.nodes[nid].devices:
                    k = (name, d)
                    self._feed_left0[k] = self._feed_left0.get(k, 0) + 1
        # ZeRO-3 gather lifetimes: gather node -> consumer chunks
        self._gather_consumers: dict[int, set[int]] = {}
        for n in self.dag.nodes.values():
            g = n.meta.get("param_from_comm")
            if g is not None:
                self._gather_consumers.setdefault(g, set()).add(n.id)
        self._gather_left0 = {g: {(c, d) for c in cs
                                  for d in self.dag.nodes[c].devices}
                              for g, cs in self._gather_consumers.items()}

    @classmethod
    def compile(cls, prog: CompiledProgram,
                params: Optional[dict[str, Any]] = None, *,
                physical_devices: Optional[Any] = None,
                **opts) -> "Interpreter":
        """Executor-protocol front door.  ``physical_devices`` is
        accepted for interface parity (the elastic supervisor passes
        it to every backend) but ignored: the interpreter simulates
        its devices, so any surviving-physical-device mapping is a
        no-op here."""
        return cls(prog, params, **opts)

    # ------------------------------------------------------------------ run
    def run(self, batch: dict[str, Any]) -> RunResult:
        dag, plan = self.dag, self.plan
        devices = plan.devices
        ledgers = {d: DeviceLedger(device=d) for d in devices}

        # ---- persistent model state ---------------------------------------
        for bname, bucket in dag.buckets.items():
            homes = self._bucket_devices(bname)
            for d in homes:
                ledgers[d].alloc_persistent(
                    bucket_persistent_bytes(bucket, d))

        # ---- input distribution -------------------------------------------
        # store: (node, slot, device) -> value
        store: dict[tuple[int, int, int], Any] = {}
        feeds = self._resolve_inputs(batch)
        # graph inputs are charged from first use to last consumer
        # (fresh copies of the hoisted __init__ invariants)
        self._feed_left = dict(self._feed_left0)

        # grads accumulate per (bucket, device)
        grad_acc: dict[tuple[str, int], Any] = {}
        grad_cnt: dict[tuple[str, int], int] = {}
        reduced: dict[str, Any] = {}
        reduced_cnt: dict[str, int] = {}
        losses: list[Any] = []

        # consumer counts for transient frees
        cons = dict(self._cons0)

        # ZeRO-3 gather lifetimes
        gather_consumers = self._gather_consumers
        gather_left = {g: set(s) for g, s in self._gather_left0.items()}

        # ---- scheduling state ----------------------------------------------
        done: set[TaskKey] = set()
        heads: dict[tuple[int, str], int] = {}
        exec_order: list[TaskKey] = []
        queues = {(d, s): list(keys)
                  for d, p in plan.device_plans.items()
                  for s, keys in p.streams.items()}

        def head_task(d, s) -> Optional[Task]:
            q = queues[(d, s)]
            i = heads.get((d, s), 0)
            return None if i >= len(q) else plan.device_plans[d].tasks[q[i]]

        def deps_met(t: Task) -> bool:
            return all(k in done for k in t.deps)

        def at_head(key: TaskKey) -> bool:
            nid, d, role = key
            t = plan.device_plans[d].tasks[key]
            q = queues[(d, t.stream)]
            i = heads.get((d, t.stream), 0)
            return i < len(q) and q[i] == key

        def advance(t: Task) -> None:
            heads[(t.device, t.stream)] = heads.get(
                (t.device, t.stream), 0) + 1
            done.add(t.key)
            exec_order.append(t.key)

        total = sum(p.n_tasks() for p in plan.device_plans.values())
        progress = True
        while len(done) < total:
            if not progress:
                pending = [(d, s, queues[(d, s)][heads.get((d, s), 0)])
                           for (d, s) in queues
                           if heads.get((d, s), 0) < len(queues[(d, s)])]
                raise RuntimeError(
                    "interpreter deadlock — stream heads blocked at: "
                    + "; ".join(f"dev{d}/{s}:{k}" for d, s, k in pending[:8]))
            progress = False
            # comm streams dispatch eagerly (before the default compute
            # stream) — reductions free memory as soon as possible, like
            # the paper's background-thread buffer release.
            sweep = sorted(queues, key=lambda ds: (ds[0],
                                                   ds[1] == "main", ds[1]))
            for (d, s) in sweep:
                t = head_task(d, s)
                if t is None or not deps_met(t):
                    continue
                node = dag.nodes[t.node]
                if t.role == ROLE_COLL:
                    group_tasks = [t] + [
                        plan.device_plans[pd].tasks[pk]
                        for pk in t.peers for pd in [pk[1]]]
                    if not all(deps_met(g) and at_head(g.key)
                               for g in group_tasks):
                        continue
                    if (node.op == "all_gather" and node.payload == "param"
                            and self.track_memory):
                        inflight = max(
                            sum(1 for k in ledgers[g.device].live
                                if k[0] == "fullparam")
                            for g in group_tasks)
                        if inflight >= self.gather_limit:
                            continue  # FSDP-style gather rate limiter
                    self._exec_collective(
                        node, group_tasks, store, grad_acc, grad_cnt,
                        reduced, reduced_cnt, ledgers, cons, gather_left)
                    for g in group_tasks:
                        advance(g)
                elif t.role == ROLE_SEND:
                    self._exec_send(node, t, store, feeds, cons, ledgers)
                    advance(t)
                elif t.role == ROLE_RECV:
                    self._exec_recv(node, t, store, cons, ledgers)
                    advance(t)
                else:
                    self._exec_chunk(
                        node, t, store, feeds, cons, grad_acc, grad_cnt,
                        losses, ledgers, gather_left, gather_consumers)
                    advance(t)
                progress = True

        # ---- results ---------------------------------------------------------
        loss = float(jnp.mean(jnp.stack([jnp.asarray(l) for l in losses])))
        grads = self._final_grads(grad_acc, grad_cnt, reduced, reduced_cnt)
        return RunResult(loss=loss, grads=grads, ledgers=ledgers,
                         exec_order=exec_order,
                         stats={"tasks": total, "losses": len(losses)})

    # ------------------------------------------------------------ internals
    def _bucket_devices(self, bname: str) -> tuple[int, ...]:
        devs: set[int] = set()
        for n in self.dag.nodes.values():
            if n.is_chunk and n.bucket == bname:
                devs.update(n.devices)
        return tuple(sorted(devs)) or (0,)

    def _consumer_counts(self) -> dict[tuple[int, int, int], int]:
        cons: dict[tuple[int, int, int], int] = {}
        for e in self.dag.edges:
            for t_dev in self._value_devices(e.dst):
                cons[(e.src, e.src_out, t_dev)] = cons.get(
                    (e.src, e.src_out, t_dev), 0) + 1
        return cons

    def _value_devices(self, nid: int) -> tuple[int, ...]:
        n = self.dag.nodes[nid]
        if n.is_comm and n.op == "p2p":
            return tuple(s for (s, _) in n.meta["pairs"])
        return n.devices

    def _resolve_inputs(self, batch) -> dict[tuple[str, int, int], Any]:
        """Map (input_name, consumer_node, consumer_slot) unsplit; values
        are sliced per consuming device (DP/EP split along axis 0) and per
        microbatch (Split renamed inputs to name@MBi)."""
        feeds: dict[tuple[int, int, int], Any] = {}
        mb_meta = self.dag.meta.get("microbatch_inputs", {})
        # build values per (possibly microbatched) input name
        values: dict[str, Any] = {}
        for name in self.dag.inputs:
            if name in batch:
                values[name] = batch[name]
        for base, info in mb_meta.items():
            if base not in batch:
                raise KeyError(f"missing batch input {base!r}")
            arr = batch[base]
            k = info["k"]
            if arr.shape[0] % k:
                raise ValueError(f"batch dim {arr.shape[0]} not divisible "
                                 f"by {k} microbatches")
            parts = jnp.split(arr, k, axis=0)
            for i, sub in enumerate(info["names"]):
                values[sub] = parts[i]
        for name, (_spec, consumers) in self.dag.inputs.items():
            if name not in values:
                raise KeyError(f"missing batch input {name!r}")
            arr = values[name]
            for (nid, slot) in consumers:
                node = self.dag.nodes[nid]
                devs = node.devices
                if len(devs) > 1 and node.meta.get("placement_mode") in (
                        "replicate", "shard_expert"):
                    if arr.shape[0] % len(devs):
                        raise ValueError(
                            f"cannot shard input {name!r} batch "
                            f"{arr.shape[0]} over {len(devs)} devices")
                    shards = jnp.split(arr, len(devs), axis=0)
                    for d, sh in zip(devs, shards):
                        feeds[(nid, slot, d)] = sh
                else:
                    for d in devs:
                        feeds[(nid, slot, d)] = arr
        return feeds

    # -- execution of node kinds ---------------------------------------------
    def _gather_chunk_inputs(self, node: Node, t: Task, store, feeds):
        m = node.meta.get("n_inputs", 0)
        args = []
        for slot in range(m):
            key = (node.id, slot, t.device)
            if key in feeds:
                args.append(feeds[key])
                continue
            vals = [store[(e.src, e.src_out, t.device)]
                    for e in self.dag.in_edges(node.id)
                    if e.dst_in == slot]
            if not vals:
                if slot in node.meta.get("zero_cot_slots", []):
                    args.append(None)
                    continue
                if slot in node.meta.get("seed_slots", []):
                    args.append(None)
                    continue
                raise KeyError(
                    f"no value for {node.short()} slot {slot} dev {t.device}")
            args.append(vals[0] if len(vals) == 1 else sum(vals[1:], vals[0]))
        # seed/zero cotangents (bwd input slot m0+j carries the cotangent
        # of forward output j; m0 = n_inputs - n_cots, where n_cots is
        # the forward's ORIGINAL output count — a remat-stashed forward
        # grew extra residual outputs that carry no cotangents)
        if "fwd_node" in node.meta:
            fwd = self.dag.nodes[node.meta["fwd_node"]]
            n_cots = node.meta.get("n_cots", fwd.n_outputs)
            m0 = node.meta["n_inputs"] - n_cots
            for slot in node.meta.get("seed_slots", []):
                s = fwd.out_specs[slot - m0]
                args[slot] = jnp.ones(s.shape, dtype=s.dtype)
            for slot in node.meta.get("zero_cot_slots", []):
                s = fwd.out_specs[slot - m0]
                args[slot] = jnp.zeros(s.shape, dtype=s.dtype)
        return args

    def _exec_chunk(self, node, t, store, feeds, cons, grad_acc, grad_cnt,
                    losses, ledgers, gather_left, gather_consumers) -> None:
        args = self._gather_chunk_inputs(node, t, store, feeds)
        if node.id not in self._jit_cache:
            self._jit_cache[node.id] = jax.jit(node.fn)
        # charge graph inputs (first use) / release (last consumer)
        if self.track_memory:
            led = ledgers[t.device]
            for slot in range(node.meta.get("n_inputs", 0)):
                fkey = (node.id, slot)
                if fkey not in self._feed_name:
                    continue
                name = self._feed_name[fkey]
                v = feeds.get((node.id, slot, t.device))
                if v is not None:
                    led.alloc(("input", name, t.device),
                              v.size * v.dtype.itemsize)
                k = (name, t.device)
                self._feed_left[k] -= 1
                if self._feed_left[k] <= 0:
                    led.free(("input", name, t.device))
        bucket_params = self.params.get(node.bucket) if node.bucket else None
        # EP shard: numerically each device processes its token shard with
        # the full expert stack (identical math to a2a-dispatched experts).
        outs = self._jit_cache[node.id](bucket_params, *args)
        is_bwd = node.meta.get("is_backward", False)
        led = ledgers[t.device]

        if is_bwd:
            bucket_grads = outs[0]
            cots = outs[1:]
            if node.bucket is not None and bucket_grads is not None:
                b = self.dag.bucket_of(node.bucket)
                if self.track_memory and b.shard_grads:
                    # ZeRO-2: one temporary full-grad buffer per bucket,
                    # reused across backward chunks, freed at reduce-scatter
                    led.alloc(("fullgrad", node.bucket, t.device),
                              b.param_elems * GRAD_BYTES_PER_ELEM)
                k = (node.bucket, t.device)
                scaled = bucket_grads
                grad_acc[k] = (scaled if k not in grad_acc else
                               jax.tree_util.tree_map(
                                   jnp.add, grad_acc[k], scaled))
                grad_cnt[k] = grad_cnt.get(k, 0) + 1
            out_vals = cots
            out_slots = list(range(1, 1 + len(cots)))
        else:
            out_vals = outs
            out_slots = list(range(len(outs)))

        discard = set(node.meta.get("discard_out_slots", []))
        for slot, val in zip(out_slots, out_vals):
            if slot in discard:
                continue
            key = (node.id, slot, t.device)
            if cons.get(key):
                store[key] = val
                if self.track_memory:
                    led.alloc(("act",) + key,
                              val.size * val.dtype.itemsize
                              if hasattr(val, "size") else 0)
        # loss outputs
        for (nid, slot) in self.dag.outputs:
            if nid == node.id:
                losses.append(outs[slot])

        self._release_inputs(node, t, store, cons, ledgers)
        # ZeRO-3 full-param buffer lifetime
        g = node.meta.get("param_from_comm")
        if g is not None and g in gather_left:
            gather_left[g].discard((node.id, t.device))
            if self.track_memory and not any(
                    d == t.device for (_, d) in gather_left[g]):
                ledgers[t.device].free(("fullparam", g, t.device))

    def _release_inputs(self, node, t, store, cons, ledgers) -> None:
        for e in self.dag.in_edges(node.id):
            key = (e.src, e.src_out, t.device)
            if key in cons:
                cons[key] -= 1
                if cons[key] <= 0 and key in store:
                    del store[key]
                    if self.track_memory:
                        ledgers[t.device].free(("act",) + key)

    def _exec_send(self, node, t, store, feeds, cons, ledgers) -> None:
        pass  # value moves at recv time (send marks readiness)

    def _exec_recv(self, node, t, store, cons, ledgers) -> None:
        e_in = self.dag.in_edges(node.id)
        assert len(e_in) == 1, f"p2p with {len(e_in)} inputs"
        e = e_in[0]
        # find the pair (src_dev -> this device)
        src_dev = None
        for (s, d) in node.meta["pairs"]:
            if d == t.device:
                src_dev = s
        val = store[(e.src, e.src_out, src_dev)]
        key = (node.id, 0, t.device)
        store[key] = val
        if self.track_memory and cons.get(key):
            ledgers[t.device].alloc(("act",) + key,
                                    val.size * val.dtype.itemsize)
        # release the producer-side value
        pkey = (e.src, e.src_out, src_dev)
        cons[pkey] = cons.get(pkey, 1) - 1
        if cons[pkey] <= 0 and pkey in store:
            del store[pkey]
            ledgers[src_dev].free(("act",) + pkey)

    def _exec_collective(self, node, group_tasks, store, grad_acc, grad_cnt,
                         reduced, reduced_cnt, ledgers, cons,
                         gather_left) -> None:
        op = node.op
        if op in ("all_reduce", "reduce_scatter") and node.payload == "grad":
            # a fused (bucketed) reduction executes its members one by
            # one — identical per-bucket math, shared dispatch; a plain
            # node is a single member (its own meta)
            for member in node.meta.get("fused_members") or [node.meta]:
                # bucket_sz partitions a reduction into parts; numerics
                # (and buffer lifetimes) are handled once, on part 0
                if member.get("part", 0) != 0:
                    continue
                self._reduce_bucket_grads(
                    member["bucket"], bool(member.get("accumulated")),
                    group_tasks, grad_acc, grad_cnt, reduced, reduced_cnt,
                    ledgers)
        elif op == "all_gather" and node.payload == "param":
            if self.track_memory:
                # one buffer per (possibly fused) gather: the ledger
                # charges the fused payload over its true lifetime,
                # i.e. until the last member's last consumer — same
                # sizing rule as the static estimator's
                nbytes = gather_param_bytes(self.dag, node)
                for t in group_tasks:
                    ledgers[t.device].alloc(
                        ("fullparam", node.id, t.device), nbytes)
        elif op in ("d2h", "h2d"):
            # host offload round-trip: the value moves unchanged (bit
            # identity).  d2h parks it in host RAM — the device ledger
            # is NOT charged for its output, and releasing the input
            # frees the device-resident activation; h2d re-charges the
            # device at fetch time.
            for t in group_tasks:
                for e in self.dag.in_edges(node.id):
                    v = store.get((e.src, e.src_out, t.device))
                    if v is None:
                        continue
                    key = (node.id, 0, t.device)
                    if cons.get(key):
                        store[key] = v
                        if op == "h2d" and self.track_memory:
                            ledgers[t.device].alloc(
                                ("act",) + key,
                                v.size * v.dtype.itemsize)
            for t in group_tasks:
                self._release_inputs(node, t, store, cons, ledgers)
        elif op == "all_to_all":
            # EP a2a: numerically transparent (see class docstring);
            # move each device's value through the comm node.
            for t in group_tasks:
                for e in self.dag.in_edges(node.id):
                    v = store.get((e.src, e.src_out, t.device))
                    if v is None:
                        continue
                    key = (node.id, 0, t.device)
                    store[key] = v
                    if self.track_memory and cons.get(key):
                        ledgers[t.device].alloc(
                            ("act",) + key, v.size * v.dtype.itemsize)
            for t in group_tasks:
                self._release_inputs(node, t, store, cons, ledgers)
        else:
            # generic pass-through collective on activations
            for t in group_tasks:
                for e in self.dag.in_edges(node.id):
                    v = store.get((e.src, e.src_out, t.device))
                    if v is not None:
                        store[(node.id, 0, t.device)] = v
            for t in group_tasks:
                self._release_inputs(node, t, store, cons, ledgers)

    def _reduce_bucket_grads(self, bucket, accumulated, group_tasks,
                             grad_acc, grad_cnt, reduced, reduced_cnt,
                             ledgers) -> None:
        b = self.dag.bucket_of(bucket)
        devs = [t.device for t in group_tasks]
        vals, cnts = [], []
        for d in devs:
            k = (bucket, d)
            if k in grad_acc:
                vals.append(grad_acc[k])
                cnts.append(grad_cnt[k])
        if not vals:
            return
        mean = jax.tree_util.tree_map(
            lambda *xs: sum(x / c for x, c in zip(xs, cnts))
            / len(xs), *vals)
        # per-microbatch reduction: contributions accumulate
        if bucket in reduced and not accumulated:
            reduced[bucket] = jax.tree_util.tree_map(
                jnp.add, reduced[bucket], mean)
            reduced_cnt[bucket] += 1
        else:
            reduced[bucket] = mean
            reduced_cnt[bucket] = 1
        # grads on each device were consumed by the reduction
        for d in devs:
            grad_acc.pop((bucket, d), None)
            grad_cnt.pop((bucket, d), None)
            if self.track_memory and b.shard_grads:
                ledgers[d].free(("fullgrad", bucket, d))

    # hook: the schedule-only replay (``_PlanWalker``) overrides the
    # four ``_exec_*`` methods above; everything the dispatch loop itself
    # consults (stream heads, dependency sets, the fullparam live-count
    # rate limiter) must be mirrored there, or the replayed order drifts
    # from the real run's ``RunResult.exec_order``.

    def _final_grads(self, grad_acc, grad_cnt, reduced, reduced_cnt):
        out: dict[str, Any] = {}
        for bucket, g in reduced.items():
            out[bucket] = jax.tree_util.tree_map(
                lambda x: x / reduced_cnt[bucket], g)
        # buckets never reduced (single device, no Replicate):
        per_bucket_dev: dict[str, list] = {}
        for (bucket, d), g in grad_acc.items():
            per_bucket_dev.setdefault(bucket, []).append(
                jax.tree_util.tree_map(
                    lambda x: x / grad_cnt[(bucket, d)], g))
        for bucket, gs in per_bucket_dev.items():
            if bucket in out:
                continue
            acc = gs[0]
            for g in gs[1:]:
                acc = jax.tree_util.tree_map(jnp.add, acc, g)
            out[bucket] = jax.tree_util.tree_map(
                lambda x: x / len(gs), acc)
        return out


# ---------------------------------------------------------------------------
# Schedule-only replay (SPMD executor parity hook)
# ---------------------------------------------------------------------------

@dataclass
class ScheduleReplay:
    """The order-sensitive facts of one interpreter run, recovered
    without executing any chunk math:

    ``exec_order``     the dynamic task dispatch order (equals the real
                       run's ``RunResult.exec_order``);
    ``loss_order``     ``(node, out_slot, device)`` in loss-append order
                       — the element order of the final loss mean;
    ``grad_key_order`` ``(bucket, device)`` in gradient-accumulator
                       insertion order — the device fold order of
                       never-reduced buckets in ``_final_grads``.

    The SPMD executor (``runtime/spmd.py``) mirrors these so its
    epilogue reductions run in exactly the reference order (fp64
    bit-parity needs the same summation sequence, not just the same
    summands)."""
    exec_order: list[TaskKey]
    loss_order: list[tuple[int, int, int]]
    grad_key_order: list[tuple[str, int]]


class _PlanWalker(Interpreter):
    """Schedule-only subclass: runs the worker loop with the four
    ``_exec_*`` methods replaced by bookkeeping stubs.  No chunk fn is
    called and no tensor moves; the only state maintained is what the
    dispatch loop consults — the ZeRO-3 full-param buffer live-counts
    that drive the FSDP-style gather rate limiter, and the gather
    consumer sets that free them."""

    def __init__(self, prog: CompiledProgram,
                 gather_limit: Optional[int] = None) -> None:
        super().__init__(prog, params=prog.params, track_memory=True,
                         gather_limit=gather_limit)
        self.loss_order: list[tuple[int, int, int]] = []
        self.grad_key_order: list[tuple[str, int]] = []

    def replay(self, batch: dict[str, Any]) -> "ScheduleReplay":
        """One replayed dispatch; the order lists reset per call so a
        walker instance can be reused across batch shapes."""
        self.loss_order = []
        self.grad_key_order = []
        res = self.run(batch)
        return ScheduleReplay(exec_order=res.exec_order,
                              loss_order=self.loss_order,
                              grad_key_order=self.grad_key_order)

    def _exec_chunk(self, node, t, store, feeds, cons, grad_acc, grad_cnt,
                    losses, ledgers, gather_left, gather_consumers) -> None:
        if node.meta.get("is_backward") and node.bucket is not None:
            k = (node.bucket, t.device)
            if k not in grad_acc:
                self.grad_key_order.append(k)
            grad_acc[k] = 0.0
            grad_cnt[k] = grad_cnt.get(k, 0) + 1
        for (nid, slot) in self.dag.outputs:
            if nid == node.id:
                self.loss_order.append((node.id, slot, t.device))
                losses.append(jnp.zeros(()))
        g = node.meta.get("param_from_comm")
        if g is not None and g in gather_left:
            gather_left[g].discard((node.id, t.device))
            if not any(d == t.device for (_, d) in gather_left[g]):
                ledgers[t.device].free(("fullparam", g, t.device))

    def _exec_send(self, node, t, store, feeds, cons, ledgers) -> None:
        pass

    def _exec_recv(self, node, t, store, cons, ledgers) -> None:
        pass

    def _exec_collective(self, node, group_tasks, store, grad_acc, grad_cnt,
                         reduced, reduced_cnt, ledgers, cons,
                         gather_left) -> None:
        if node.op == "all_gather" and node.payload == "param":
            for t in group_tasks:
                ledgers[t.device].alloc(
                    ("fullparam", node.id, t.device), 0)
        elif node.op in ("all_reduce", "reduce_scatter") \
                and node.payload == "grad":
            for member in node.meta.get("fused_members") or [node.meta]:
                if member.get("part", 0) != 0:
                    continue
                bkt = member["bucket"]
                if not any((bkt, t.device) in grad_acc
                           for t in group_tasks):
                    continue
                reduced[bkt] = 0.0
                reduced_cnt[bkt] = reduced_cnt.get(bkt, 0) + 1
                for t in group_tasks:
                    grad_acc.pop((bkt, t.device), None)
                    grad_cnt.pop((bkt, t.device), None)
                    b = self.dag.bucket_of(bkt)
                    if b.shard_grads:
                        ledgers[t.device].free(
                            ("fullgrad", bkt, t.device))


def replay_schedule(prog: CompiledProgram, batch: dict[str, Any],
                    gather_limit: Optional[int] = None) -> ScheduleReplay:
    """Replay the interpreter's dispatch loop without executing math;
    see ``ScheduleReplay``.  ``batch`` is only used for input-shape
    resolution (microbatch splitting), never read numerically."""
    return _PlanWalker(prog, gather_limit=gather_limit).replay(batch)
