"""Discrete-event timeline simulator with stream semantics and network
contention (fluid-flow model).

This is the performance half of the runtime story: the interpreter checks
*what* is computed; the simulator predicts *when*, on the target TPU
constants.  It reproduces the paper's scheduling phenomena on CPU:

  - separate streams overlap compute and communication (Fig 3/4),
  - same-stream comms serialize and delay the critical path (Fig 4b),
  - concurrent flows sharing a device's links interfere — background DP
    all-reduces slow EP all-to-alls (the paper measured 1.46x; our fluid
    model shares link bandwidth equally among active flows),
  - partitioned (bucketed) reductions interleave with critical-path
    comms (Fig 4c).

Stream semantics: tasks on one (device, stream) execute in plan order,
serially.  A collective starts when every participant is at its stream
head with dependencies met (communicator rendezvous), then progresses at
``min`` over participants of the per-device fair-share link bandwidth.
"""
from __future__ import annotations

import heapq
import math

EPS = 1e-12  # scheduling-time float tolerance
from dataclasses import dataclass
from typing import Optional

import jax

from ..core.compiler import CompiledProgram
from ..core.plan import ROLE_COMPUTE, GlobalPlan, Task, TaskKey
from .costmodel import CostModel


@dataclass
class Record:
    device: int
    stream: str
    name: str
    kind: str          # "compute" | "comm"
    start: float
    end: float
    node: int


@dataclass
class SimResult:
    makespan: float
    records: list[Record]
    compute_busy: dict[int, float]
    comm_busy: dict[int, float]
    exposed_comm: dict[int, float]

    def throughput(self, tokens_per_step: int) -> float:
        return tokens_per_step / self.makespan

    def busy_fraction(self, device: int) -> float:
        return self.compute_busy.get(device, 0.0) / max(self.makespan, 1e-12)

    def gantt(self, width: int = 100) -> str:
        """ASCII timeline per (device, stream)."""
        lanes: dict[tuple[int, str], list[Record]] = {}
        for r in self.records:
            lanes.setdefault((r.device, r.stream), []).append(r)
        out = []
        scale = width / max(self.makespan, 1e-12)
        for (d, s) in sorted(lanes):
            row = [" "] * width
            for r in lanes[(d, s)]:
                a = min(width - 1, int(r.start * scale))
                b = min(width, max(a + 1, int(r.end * scale)))
                ch = r.name[:1].upper() if r.kind == "compute" else \
                    ("r" if "reduce" in r.name else
                     "a" if "a2a" in r.name or "all_to_all" in r.name else
                     "g" if "gather" in r.name else "p")
                for i in range(a, b):
                    row[i] = ch
            out.append(f"dev{d}/{s:<10}|{''.join(row)}|")
        return "\n".join(out)


@dataclass
class _Flow:
    node: int
    keys: list[TaskKey]
    devices: list[int]
    remaining: float          # wire bytes per participant
    start: float
    records: list[Record]
    rate: float = 0.0
    start_progress: float = 0.0


class TimelineSimulator:
    def __init__(self, prog: CompiledProgram, cost: Optional[CostModel] = None,
                 params: Optional[dict] = None,
                 device_slowdown: Optional[dict[int, float]] = None,
                 chunk_seconds_override=None) -> None:
        self.prog = prog
        self.dag = prog.dag
        self.plan: GlobalPlan = prog.plan
        self.cost = cost or CostModel()
        self.params = params if params is not None else prog.params
        self.slow = device_slowdown or {}
        self.chunk_seconds_override = chunk_seconds_override
        self._chunk_cost_cache: dict[int, float] = {}

    # ---------------- chunk cost ------------------------------------------
    def _chunk_seconds(self, node) -> float:
        if node.id in self._chunk_cost_cache:
            return self._chunk_cost_cache[node.id]
        if self.chunk_seconds_override is not None:
            t = self.chunk_seconds_override(node)
        else:
            sample = self._sample_inputs(node)
            t = self.cost.chunk_seconds(node, self.params, sample)
        self._chunk_cost_cache[node.id] = t
        return t

    def _sample_inputs(self, node) -> list:
        m = node.meta.get("n_inputs", 0)
        specs: list = [None] * m
        for e in self.dag.in_edges(node.id):
            if 0 <= e.dst_in < m:
                specs[e.dst_in] = jax.ShapeDtypeStruct(
                    e.spec.shape, e.spec.dtype)
        for (spec, consumers) in self.dag.inputs.values():
            for (nid, slot) in consumers:
                if nid == node.id and 0 <= slot < m:
                    shape = spec.shape
                    if len(node.devices) > 1 and node.meta.get(
                            "placement_mode") in ("replicate",
                                                  "shard_expert"):
                        shape = (max(1, shape[0] // len(node.devices)),
                                 ) + tuple(shape[1:])
                    specs[slot] = jax.ShapeDtypeStruct(shape, spec.dtype)
        if "fwd_node" in node.meta:
            fwd = self.dag.nodes[node.meta["fwd_node"]]
            # n_cots = the forward's ORIGINAL output count (a remat-
            # stashed forward grew residual outputs carrying no cots)
            n_cots = node.meta.get("n_cots", fwd.n_outputs)
            m0 = m - n_cots
            for slot in range(m0, m):
                if specs[slot] is None:
                    s = fwd.out_specs[slot - m0]
                    specs[slot] = jax.ShapeDtypeStruct(s.shape, s.dtype)
        return specs

    def _comm_wire_bytes(self, node) -> float:
        # fused (bucketed) collectives carry one spec per member; the
        # wire moves the whole fused payload in one rendezvous
        nbytes = node.total_out_bytes()
        group = len(node.group) if node.group else 2
        if node.op == "p2p":
            group = 2
        if node.op in ("d2h", "h2d") and node.meta.get("offload_static"):
            # batch-static residual (stashed weights): each replica
            # round-trips a FULL copy, not a 1/group batch shard
            group = 1
        return max(1.0, self.cost.comm_bytes_on_wire(
            node.op, nbytes, group))

    # ---------------- event loop --------------------------------------------
    def run(self) -> SimResult:
        plan, dag = self.plan, self.dag
        queues = {(d, s): list(keys)
                  for d, p in plan.device_plans.items()
                  for s, keys in p.streams.items()}
        heads: dict[tuple[int, str], int] = {k: 0 for k in queues}
        # stream free time (in-order lanes)
        stream_free: dict[tuple[int, str], float] = {k: 0.0 for k in queues}
        end_time: dict[TaskKey, float] = {}
        records: list[Record] = []
        compute_heap: list[tuple[float, TaskKey]] = []
        flows: list[_Flow] = []
        in_flight: set[TaskKey] = set()
        now = 0.0
        total = sum(p.n_tasks() for p in plan.device_plans.values())
        n_done = 0

        def head_task(d, s) -> Optional[Task]:
            q = queues[(d, s)]
            i = heads[(d, s)]
            return None if i >= len(q) else plan.device_plans[d].tasks[q[i]]

        def deps_ready(t: Task) -> bool:
            return all(k in end_time for k in t.deps)

        def deps_time(t: Task) -> float:
            return max([end_time[k] for k in t.deps], default=0.0)

        def at_head(key: TaskKey) -> bool:
            nid, d, role = key
            t = plan.device_plans[d].tasks[key]
            return head_task(d, t.stream) is not None and \
                head_task(d, t.stream).key == key

        def recompute_rates() -> None:
            active_per_dev: dict[int, int] = {}
            for f in flows:
                for d in set(f.devices):
                    active_per_dev[d] = active_per_dev.get(d, 0) + 1
            for f in flows:
                f.rate = min(self.cost.ici_bw / active_per_dev[d]
                             for d in set(f.devices))

        def advance_flows(to_time: float) -> None:
            for f in flows:
                f.remaining -= f.rate * (to_time - f.start_progress)
                f.start_progress = to_time

        def try_start() -> bool:
            nonlocal n_done
            started = False
            for (d, s) in sorted(queues, key=lambda k: (k[0],
                                                        k[1] == "main",
                                                        k[1])):
                t = head_task(d, s)
                if t is None or t.key in in_flight or not deps_ready(t):
                    continue
                # float-accumulation tolerance: a stream freed at
                # now+1e-18 must not stall the lane forever
                if (deps_time(t) > now + EPS
                        or stream_free[(d, s)] > now + EPS):
                    continue
                node = dag.nodes[t.node]
                if t.role == ROLE_COMPUTE:
                    dur = self._chunk_seconds(node) * self.slow.get(d, 1.0)
                    end = now + dur
                    in_flight.add(t.key)
                    stream_free[(d, s)] = end
                    heapq.heappush(compute_heap, (end, t.key))
                    records.append(Record(d, s, node.name, "compute",
                                          now, end, node.id))
                    started = True
                else:
                    # rendezvous: every participant must be at its head
                    group = [t] + [plan.device_plans[pk[1]].tasks[pk]
                                   for pk in t.peers]
                    gkeys = {g.key for g in group}

                    def member_ready(g):
                        deps = [k for k in g.deps if k not in gkeys]
                        return (all(k in end_time for k in deps)
                                and max([end_time[k] for k in deps],
                                        default=0.0) <= now + EPS
                                and at_head(g.key)
                                and stream_free[(g.device,
                                                 g.stream)] <= now + EPS
                                and g.key not in in_flight)

                    if not all(member_ready(g) for g in group):
                        continue
                    wire = self._comm_wire_bytes(node)
                    f = _Flow(node=node.id, keys=[g.key for g in group],
                              devices=[g.device for g in group],
                              remaining=wire + self.cost.comm_latency
                              * self.cost.ici_bw,
                              start=now, records=[])
                    f.start_progress = now
                    for g in group:
                        in_flight.add(g.key)
                    flows.append(f)
                    recompute_rates()
                    started = True
            return started

        while n_done < total:
            while try_start():
                pass
            if not compute_heap and not flows:
                raise RuntimeError(
                    f"simulator deadlock at t={now}: {n_done}/{total} done")
            # next event time
            t_flow = math.inf
            for f in flows:
                if f.rate > 0:
                    t_flow = min(t_flow, f.start_progress
                                 + f.remaining / f.rate)
            t_comp = compute_heap[0][0] if compute_heap else math.inf
            t_next = min(t_flow, t_comp)
            advance_flows(t_next)
            now = t_next
            # complete compute
            while compute_heap and compute_heap[0][0] <= now + 1e-15:
                _, key = heapq.heappop(compute_heap)
                end_time[key] = now
                in_flight.discard(key)
                nid, d, _ = key
                t = plan.device_plans[d].tasks[key]
                heads[(d, t.stream)] += 1
                n_done += 1
            # complete flows (threshold is rate-relative: residual bytes
            # that would take < 1ps to move are float noise, not payload)
            done_flows = [f for f in flows
                          if f.remaining <= max(1e-9, f.rate * 1e-12)]
            if done_flows:
                for f in done_flows:
                    flows.remove(f)
                    for key in f.keys:
                        end_time[key] = now
                        in_flight.discard(key)
                        nid, d, _ = key
                        t = plan.device_plans[d].tasks[key]
                        heads[(d, t.stream)] += 1
                        stream_free[(d, t.stream)] = now
                        n_done += 1
                        node = dag.nodes[nid]
                        records.append(Record(
                            d, t.stream, node.name, "comm", f.start, now,
                            nid))
                recompute_rates()

        makespan = now
        compute_busy: dict[int, float] = {}
        comm_busy: dict[int, float] = {}
        for r in records:
            if r.kind == "compute":
                compute_busy[r.device] = compute_busy.get(r.device, 0.0) \
                    + (r.end - r.start)
            else:
                comm_busy[r.device] = comm_busy.get(r.device, 0.0) \
                    + (r.end - r.start)
        # exposed comm: comm intervals not covered by compute on the device
        exposed: dict[int, float] = {}
        for d in {r.device for r in records}:
            comp = sorted([(r.start, r.end) for r in records
                           if r.device == d and r.kind == "compute"])
            comm = [(r.start, r.end) for r in records
                    if r.device == d and r.kind == "comm"]
            exposed[d] = sum(_uncovered(c, comp) for c in comm)
        return SimResult(makespan=makespan, records=records,
                         compute_busy=compute_busy, comm_busy=comm_busy,
                         exposed_comm=exposed)


def _uncovered(interval: tuple[float, float],
               cover: list[tuple[float, float]]) -> float:
    a, b = interval
    t = a
    total = 0.0
    for (s, e) in cover:
        if e <= t:
            continue
        if s >= b:
            break
        if s > t:
            total += s - t
        t = max(t, e)
        if t >= b:
            break
    if t < b:
        total += b - t
    return total
