"""The unified ``Executor`` API: one front door to every runtime.

The strategy/IR layer is runtime-agnostic (the paper's core claim); what
varies is how a compiled ``GlobalPlan`` is *executed*.  The repo now has
three runtimes — the reference ``Interpreter`` (simulated devices, oracle
numerics + memory ledgers), the ``SpmdExecutor`` (one whole-mesh
``jax.jit``+``shard_map`` program with ``lax.cond`` rank gating) and the
``MpmdExecutor`` (per-rank programs dispatched by a multi-controller over
an async transport, DESIGN.md §17) — and every launcher, supervisor and
benchmark used to pick between them with ``args.backend == "spmd"``
string chains.  This module replaces those with a registry:

  ``get_backend(name)``        resolve a backend (lazy import)
  ``list_backends()``          names, for --help and error messages
  ``make_executor(name, prog, params=..., physical_devices=...)``
                               compile a plan on a backend -> executor
  ``executor_factory(name)``   the ``ElasticSupervisor`` runner-factory
                               shape: ``(prog, params, devices) -> ex``
  ``@register_backend(name)``  add a backend (third-party runtimes too)

Every backend implements the same protocol (``Executor``):

  ``compile(prog, params=None, *, physical_devices=None, **opts)``
      classmethod: validate the plan against this runtime and return a
      ready executor (the "handle") — tracing/thread spin-up may be
      deferred to the first ``run``.
  ``run(batch) -> RunResult``  one training step (loss + grads, the
      reference contract every backend is bit-checked against)
  ``params``                   settable: swap weights without retracing
      (the elastic-resume contract)
  ``physical_devices``         the physical device indices the logical
      plan ranks landed on (simulated ranks for the interpreter)
  ``backend_name`` / ``capabilities``
      registry identity + honest feature flags (see
      ``BackendCapabilities``); capability flags — not backend-name
      string compares — are how callers branch on behavior.

Capabilities are declared HERE, in the builtin spec table, so callers
(e.g. ``launch/train.py`` deciding whether to fake host devices before
jax initializes) can consult them without importing a jax-heavy backend
module.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Protocol, runtime_checkable

__all__ = [
    "BackendCapabilities", "BackendSpec", "Executor",
    "UnknownBackendError", "executor_factory", "get_backend",
    "get_backend_spec", "jaxpr_eqn_count", "list_backends",
    "make_executor", "register_backend",
]


@dataclass(frozen=True)
class BackendCapabilities:
    """Honest feature flags per backend — what a caller may rely on.

    ``real_xla``        executes on real XLA devices (the launcher must
                        fake host devices for the plan's world size
                        BEFORE jax initializes);
    ``memory_ledgers``  ``RunResult.ledgers`` is populated (per-device
                        peak-memory accounting);
    ``measured_time``   ``measure(batch)`` returns meaningful wall-clock
                        step time for the compiled program;
    ``per_rank_trace``  each rank carries only its own traced program
                        (no whole-mesh trace on every device);
    ``multi_controller`` ranks are dispatched by independent controllers
                        over an async transport (MPMD dispatch model);
    ``elastic``         honors ``physical_devices`` rank->device mapping
                        (the elastic shrink/regrow resume path).
    """
    real_xla: bool = False
    memory_ledgers: bool = False
    measured_time: bool = False
    per_rank_trace: bool = False
    multi_controller: bool = False
    elastic: bool = True


@dataclass
class BackendSpec:
    """Registry entry: identity + capabilities + a lazy class locator
    (``module:Class``), so consulting the registry never imports a
    jax-heavy runtime module."""
    name: str
    locator: str                      # "package.module:ClassName"
    capabilities: BackendCapabilities
    summary: str = ""
    cls: Optional[type] = None        # resolved lazily / by decorator

    def load(self) -> type:
        if self.cls is None:
            mod_name, _, cls_name = self.locator.partition(":")
            self.cls = getattr(importlib.import_module(mod_name),
                               cls_name)
        return self.cls


class UnknownBackendError(ValueError):
    """Raised for a backend name the registry does not know; the message
    always lists the registered names."""


_REGISTRY: dict[str, BackendSpec] = {}


def _builtin(name: str, locator: str, caps: BackendCapabilities,
             summary: str) -> None:
    _REGISTRY[name] = BackendSpec(name, locator, caps, summary)


_builtin(
    "reference", "repro.runtime.interpreter:Interpreter",
    BackendCapabilities(real_xla=False, memory_ledgers=True,
                        measured_time=False, per_rank_trace=False,
                        multi_controller=False, elastic=True),
    "oracle interpreter on simulated devices (numerics + memory ledgers)")
_builtin(
    "spmd", "repro.runtime.spmd:SpmdExecutor",
    BackendCapabilities(real_xla=True, memory_ledgers=False,
                        measured_time=True, per_rank_trace=False,
                        multi_controller=False, elastic=True),
    "one jit+shard_map whole-mesh program on real XLA devices")
_builtin(
    "mpmd", "repro.runtime.mpmd:MpmdExecutor",
    BackendCapabilities(real_xla=True, memory_ledgers=False,
                        measured_time=True, per_rank_trace=True,
                        multi_controller=True, elastic=True),
    "per-rank programs, multi-controller dispatch, async transport")


def register_backend(name: str,
                     capabilities: Optional[BackendCapabilities] = None,
                     summary: str = "") -> Callable[[type], type]:
    """Class decorator registering an ``Executor`` implementation.

    Builtin names bind the decorated class to their pre-declared spec
    (capabilities live in this module's table, the single source of
    truth); new names must supply ``capabilities``.  The decorator
    stamps ``backend_name`` and ``capabilities`` onto the class."""
    def deco(cls: type) -> type:
        spec = _REGISTRY.get(name)
        if spec is None:
            if capabilities is None:
                raise ValueError(
                    f"register_backend({name!r}) needs capabilities= "
                    "for a non-builtin backend")
            spec = BackendSpec(name, f"{cls.__module__}:{cls.__name__}",
                               capabilities, summary, cls=cls)
            _REGISTRY[name] = spec
        else:
            spec.cls = cls
        cls.backend_name = name
        cls.capabilities = spec.capabilities
        return cls
    return deco


def get_backend_spec(name: str) -> BackendSpec:
    """The registry entry for ``name`` (import-free: capabilities and
    summary are available without loading the backend class)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownBackendError(
            f"unknown backend {name!r}; registered backends: "
            f"{', '.join(list_backends())}") from None


def get_backend(name: str) -> type:
    """Resolve a backend name to its executor class (imports it)."""
    return get_backend_spec(name).load()


def list_backends() -> tuple[str, ...]:
    """Registered backend names, registration order."""
    return tuple(_REGISTRY)


def backends_help() -> str:
    """One line per backend — the --help / error-message rendering."""
    return "; ".join(f"'{s.name}': {s.summary}"
                     for s in _REGISTRY.values())


def make_executor(name: str, prog, params: Optional[dict] = None, *,
                  physical_devices: Optional[Any] = None, **opts):
    """Compile ``prog`` on backend ``name`` -> a ready executor handle.
    The single front door ``--backend``, ``ElasticSupervisor`` and the
    benchmarks select runtimes through."""
    return get_backend(name).compile(
        prog, params=params, physical_devices=physical_devices, **opts)


def executor_factory(name: str, **opts) -> Callable:
    """A runner factory in the ``ElasticSupervisor`` contract shape:
    ``factory(prog, params, physical_devices) -> executor``.  Resolves
    the backend lazily, at first build (so the caller can fake host
    devices in between)."""
    get_backend_spec(name)   # fail fast on unknown names

    def factory(prog, params, physical_devices):
        return make_executor(name, prog, params=params,
                             physical_devices=physical_devices, **opts)
    factory.backend_name = name
    return factory


@runtime_checkable
class Executor(Protocol):
    """Structural protocol every registered backend satisfies
    (tests/test_executor_api.py runs the conformance suite against all
    registered names)."""
    backend_name: str
    capabilities: BackendCapabilities
    params: Any
    physical_devices: Any

    @classmethod
    def compile(cls, prog, params: Optional[dict] = None, *,
                physical_devices: Optional[Any] = None,
                **opts) -> "Executor":
        ...

    def run(self, batch: dict[str, Any]):
        ...


# ---------------------------------------------------------------------------
# trace-size accounting (the MPMD acceptance metric)
# ---------------------------------------------------------------------------

def jaxpr_eqn_count(closed_jaxpr) -> int:
    """Total equation count of a (closed) jaxpr, recursing into every
    sub-jaxpr (cond branches, scan bodies, pjit calls, custom-vjp
    closures) — the apples-to-apples "traced program size" both the
    SPMD whole-mesh trace and the MPMD per-rank traces report
    (``SpmdExecutor.trace_size`` / ``MpmdExecutor.trace_sizes``)."""
    jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)

    def count(j) -> int:
        n = 0
        for eqn in j.eqns:
            n += 1
            for sub in _sub_jaxprs(eqn.params):
                n += count(sub)
        return n
    return count(jaxpr)


def _sub_jaxprs(params: dict):
    for v in params.values():
        for j in _jaxprs_in(v):
            yield j


def _jaxprs_in(v):
    # params hold jaxprs directly, closed, or in tuples/lists of either
    if hasattr(v, "eqns"):
        yield v
    elif hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
        yield v.jaxpr
    elif isinstance(v, (tuple, list)):
        for x in v:
            yield from _jaxprs_in(x)
