"""Cost model for the timeline simulator (TPU v5e target constants).

Chunk compute cost comes from XLA itself: each chunk's exec function is
lowered once on CPU and ``cost_analysis()`` supplies FLOPs and bytes
accessed — the same source the dry-run roofline uses.  Comm cost uses
standard ring/all-to-all models over ICI.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax

# TPU v5e (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link (task spec: ~50 GB/s/link)
ICI_LAT = 1e-6                  # s per hop
DCN_BW = 25e9                   # B/s per host, cross-pod
DMA_BW = 25e9                   # B/s host<->device (offload round-trips)


@dataclass
class CostModel:
    peak_flops: float = PEAK_FLOPS_BF16
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW
    dcn_bw: float = DCN_BW
    dma_bw: float = DMA_BW       # host DMA for d2h/h2d offload nodes
    mfu: float = 0.55            # achievable fraction of peak on chunks
    comm_latency: float = ICI_LAT

    # ---------------- chunk costs -----------------------------------------
    def chunk_seconds(self, node, params, sample_inputs) -> float:
        """Roofline max(compute, memory) time for a chunk exec function."""
        flops, bytes_ = analyze_fn(node.fn, params.get(node.bucket)
                                   if node.bucket else None, sample_inputs)
        t_c = flops / (self.peak_flops * self.mfu)
        t_m = bytes_ / self.hbm_bw
        return max(t_c, t_m, 1e-7)

    # ---------------- comm costs (size only; contention in simulator) -----
    def comm_bytes_on_wire(self, op: str, nbytes: int, group: int) -> int:
        """Bytes each participant moves over its link.  d2h/h2d offload
        round-trips move each device's shard over the host DMA link —
        expressed in ICI-equivalent bytes so the simulator's fluid-flow
        rate (``ici_bw`` fair-share) yields ``shard_bytes / dma_bw``."""
        if op in ("d2h", "h2d"):
            shard = nbytes / max(group, 1)
            return int(shard * (self.ici_bw / self.dma_bw))
        if group <= 1:
            return 0
        n = group
        if op == "all_reduce":
            return int(2 * nbytes * (n - 1) / n)
        if op in ("all_gather", "reduce_scatter"):
            return int(nbytes * (n - 1) / n)
        if op == "all_to_all":
            return int(nbytes * (n - 1) / n)
        if op == "p2p":
            return int(nbytes)
        return int(nbytes)

    def link_bw(self, cross_pod: bool = False) -> float:
        return self.dcn_bw if cross_pod else self.ici_bw


_ANALYSIS_CACHE: dict[Any, tuple[float, float]] = {}


def analyze_fn(fn, bucket_params, sample_inputs) -> tuple[float, float]:
    """(flops, bytes_accessed) of a chunk exec function via XLA CPU
    cost analysis.  Cached on (fn identity, input avals)."""
    avals = tuple(
        (tuple(x.shape), str(x.dtype)) for x in sample_inputs
        if x is not None)
    key = (id(fn), avals)
    if key in _ANALYSIS_CACHE:
        return _ANALYSIS_CACHE[key]
    try:
        specs = [jax.ShapeDtypeStruct(x.shape, x.dtype)
                 if x is not None else None for x in sample_inputs]
        pspec = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), bucket_params)

        def wrapped(p, *ins):
            return fn(p, *ins)

        lowered = jax.jit(wrapped).lower(pspec, *specs)
        ca = lowered.compile().cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        flops = float(ca.get("flops", 0.0))
        nbytes = float(ca.get("bytes accessed", 0.0))
    except Exception:
        # fall back to a crude estimate from input/param sizes
        nbytes = sum(x.size * x.dtype.itemsize for x in sample_inputs
                     if x is not None)
        if bucket_params is not None:
            nbytes += sum(l.size * l.dtype.itemsize for l in
                          jax.tree_util.tree_leaves(bucket_params))
        flops = 2.0 * nbytes
    _ANALYSIS_CACHE[key] = (flops, nbytes)
    return flops, nbytes
