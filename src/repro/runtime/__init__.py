"""Piper strategy-agnostic runtime: interpreter + timeline simulator +
the SPMD and MPMD executors that run compiled plans on real XLA devices.

Backend selection goes through ``runtime.executor`` — the registry
(``get_backend`` / ``list_backends`` / ``make_executor`` /
``executor_factory``) is the ONE front door ``--backend``, the elastic
supervisor, and the benchmarks use; see docs/backends.md.

``spmd`` and ``mpmd`` are imported lazily: each pulls in heavyweight
tracing machinery only ``--backend {spmd,mpmd}`` callers need, and the
registry resolves them on demand.
"""
from .executor import (BackendCapabilities, Executor, UnknownBackendError,
                       executor_factory, get_backend, list_backends,
                       make_executor, register_backend)
from .interpreter import (Interpreter, RunResult, ScheduleReplay,
                          replay_schedule)
from .memory import (DeviceLedger, bucket_persistent_bytes,
                     timeline_peak_bytes)

__all__ = ["Interpreter", "RunResult", "ScheduleReplay",
           "replay_schedule", "DeviceLedger", "bucket_persistent_bytes",
           "timeline_peak_bytes", "SpmdExecutor", "SpmdBackendError",
           "MpmdExecutor", "MpmdBackendError", "MpmdHandshakeError",
           "MpmdTransportError", "BackendCapabilities", "Executor",
           "UnknownBackendError", "executor_factory", "get_backend",
           "list_backends", "make_executor", "register_backend"]

_LAZY = {
    "SpmdExecutor": "spmd", "SpmdBackendError": "spmd",
    "MpmdExecutor": "mpmd", "MpmdBackendError": "mpmd",
    "MpmdHandshakeError": "mpmd", "MpmdTransportError": "mpmd",
}


def __getattr__(name):
    mod = _LAZY.get(name)
    if mod is not None:
        import importlib
        return getattr(importlib.import_module(f".{mod}", __name__), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
