"""Piper strategy-agnostic runtime: interpreter + timeline simulator."""
from .interpreter import Interpreter, RunResult
from .memory import (DeviceLedger, bucket_persistent_bytes,
                     timeline_peak_bytes)

__all__ = ["Interpreter", "RunResult", "DeviceLedger",
           "bucket_persistent_bytes", "timeline_peak_bytes"]
