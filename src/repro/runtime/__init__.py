"""Piper strategy-agnostic runtime: interpreter + timeline simulator +
the SPMD executor that runs compiled plans on real XLA devices.

``spmd`` is imported lazily: the executor pulls in ``shard_map`` and is
only needed by ``--backend spmd`` callers, who import it explicitly
(``from repro.runtime.spmd import SpmdExecutor``) or via this package's
``SpmdExecutor`` re-export.
"""
from .interpreter import (Interpreter, RunResult, ScheduleReplay,
                          replay_schedule)
from .memory import (DeviceLedger, bucket_persistent_bytes,
                     timeline_peak_bytes)

__all__ = ["Interpreter", "RunResult", "ScheduleReplay",
           "replay_schedule", "DeviceLedger", "bucket_persistent_bytes",
           "timeline_peak_bytes", "SpmdExecutor", "SpmdBackendError"]


def __getattr__(name):
    if name in ("SpmdExecutor", "SpmdBackendError"):
        from . import spmd
        return getattr(spmd, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
