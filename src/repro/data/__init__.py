"""Deterministic, shardable, checkpointable token data pipeline."""
from .pipeline import DataState, MemmapTokenSource, SyntheticTokenSource, \
    TokenLoader

__all__ = ["DataState", "MemmapTokenSource", "SyntheticTokenSource",
           "TokenLoader"]
