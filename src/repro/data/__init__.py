"""Deterministic, shardable, checkpointable data pipelines (token and
vector streams share one resumable-state contract)."""
from .pipeline import (DataState, MemmapTokenSource, SyntheticTokenSource,
                       SyntheticVectorSource, TokenLoader, VectorLoader)

__all__ = ["DataState", "MemmapTokenSource", "SyntheticTokenSource",
           "SyntheticVectorSource", "TokenLoader", "VectorLoader"]
