"""Token data pipeline: deterministic, shardable, exactly resumable.

Sources produce a (batch, seq+1) token block for a given global step;
``TokenLoader`` slices it into (tokens, labels), shards it per host, and
carries a checkpointable ``DataState`` so a restore resumes mid-epoch at
the exact same sample order (fault-tolerance requirement).
"""
from __future__ import annotations

import dataclasses
import hashlib
import pathlib
from dataclasses import dataclass
from typing import Optional

import numpy as np


@dataclass
class DataState:
    step: int = 0
    epoch: int = 0
    seed: int = 0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "DataState":
        return DataState(**d)


class SyntheticTokenSource:
    """Deterministic synthetic tokens: block(step) is a pure function of
    (seed, step) — identical across hosts, so each host slices its shard
    without communication.

    Sequences follow a noisy affine recurrence t_{n+1} = (a*t_n + c)
    mod V with flip probability ``noise`` — a learnable next-token
    structure, so training-loss decrease is a meaningful signal (pure
    uniform tokens would pin the loss at ln V)."""

    def __init__(self, vocab: int, seed: int = 0,
                 noise: float = 0.15) -> None:
        self.vocab = vocab
        self.seed = seed
        self.noise = noise

    def block(self, step: int, batch: int, seq: int) -> np.ndarray:
        rng = np.random.Generator(np.random.Philox(
            key=self.seed, counter=[0, 0, 0, step]))
        v = self.vocab
        out = np.empty((batch, seq + 1), dtype=np.int32)
        out[:, 0] = rng.integers(0, v, size=batch)
        flips = rng.random((batch, seq)) < self.noise
        rand = rng.integers(0, v, size=(batch, seq), dtype=np.int32)
        a, c = 5, 17
        for t in range(seq):
            nxt = (out[:, t] * a + c) % v
            out[:, t + 1] = np.where(flips[:, t], rand[:, t], nxt)
        return out


class MemmapTokenSource:
    """Flat binary token file (uint16/uint32).  Blocks are strided
    deterministically; wraps around at the end (epoch += 1)."""

    def __init__(self, path: str, vocab: int,
                 dtype: str = "uint16") -> None:
        self.path = pathlib.Path(path)
        self.vocab = vocab
        self.tokens = np.memmap(self.path, dtype=np.dtype(dtype),
                                mode="r")

    def block(self, step: int, batch: int, seq: int) -> np.ndarray:
        n = len(self.tokens)
        span = seq + 1
        out = np.empty((batch, span), dtype=np.int32)
        for i in range(batch):
            start = ((step * batch + i) * span) % max(n - span, 1)
            out[i] = self.tokens[start:start + span].astype(np.int32)
        return np.clip(out, 0, self.vocab - 1)


class SyntheticVectorSource:
    """Deterministic synthetic (x, y) regression batches for the
    annotated-MLP models the tests and benches train: ``block(step)`` is
    a pure function of (seed, step), and y is a fixed random linear map
    of x plus noise — learnable, so losses move and elastic-resume
    parity is a meaningful bit-level claim."""

    def __init__(self, d: int, seed: int = 0, noise: float = 0.1) -> None:
        self.d = d
        self.seed = seed
        self.noise = noise
        w_rng = np.random.Generator(np.random.Philox(
            key=seed, counter=[0, 0, 0, 0xE1A57]))
        self._w = w_rng.standard_normal((d, d)).astype(np.float32) \
            / np.sqrt(d)

    def block(self, step: int, batch: int) -> tuple[np.ndarray, np.ndarray]:
        rng = np.random.Generator(np.random.Philox(
            key=self.seed, counter=[0, 0, 1, step]))
        x = rng.standard_normal((batch, self.d)).astype(np.float32)
        eps = rng.standard_normal((batch, self.d)).astype(np.float32)
        y = np.tanh(x @ self._w) + self.noise * eps
        return x, y.astype(np.float32)


class VectorLoader:
    """``TokenLoader``'s sibling for (x, y) vector batches: same
    deterministic, host-shardable, exactly-resumable stream contract
    (``state_dict``/``load_state_dict``/``fingerprint``), so the elastic
    supervisor can checkpoint and restore its position."""

    def __init__(self, source: SyntheticVectorSource, batch: int,
                 host_id: int = 0, n_hosts: int = 1,
                 state: Optional[DataState] = None) -> None:
        assert batch % n_hosts == 0, (batch, n_hosts)
        self.source = source
        self.batch = batch
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.state = state or DataState(seed=getattr(source, "seed", 0))

    def next_batch(self) -> dict:
        x, y = self.source.block(self.state.step, self.batch)
        per = self.batch // self.n_hosts
        sl = slice(self.host_id * per, (self.host_id + 1) * per)
        self.state.step += 1
        return {"x": x[sl].copy(), "y": y[sl].copy()}

    def state_dict(self) -> dict:
        return self.state.to_dict()

    def load_state_dict(self, d: dict) -> None:
        self.state = DataState.from_dict(d)

    def fingerprint(self) -> str:
        x, y = self.source.block(self.state.step, self.batch)
        return hashlib.sha256(x.tobytes() + y.tobytes()).hexdigest()[:16]


class TokenLoader:
    def __init__(self, source, batch: int, seq: int,
                 host_id: int = 0, n_hosts: int = 1,
                 state: Optional[DataState] = None) -> None:
        assert batch % n_hosts == 0, (batch, n_hosts)
        self.source = source
        self.batch = batch
        self.seq = seq
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.state = state or DataState(seed=getattr(source, "seed", 0))

    def next_batch(self) -> dict:
        blk = self.source.block(self.state.step, self.batch, self.seq)
        per = self.batch // self.n_hosts
        mine = blk[self.host_id * per:(self.host_id + 1) * per]
        self.state.step += 1
        return {"tokens": mine[:, :-1].copy(),
                "labels": mine[:, 1:].copy()}

    # -- checkpointing --------------------------------------------------
    def state_dict(self) -> dict:
        return self.state.to_dict()

    def load_state_dict(self, d: dict) -> None:
        self.state = DataState.from_dict(d)

    def fingerprint(self) -> str:
        """Digest of the next batch — used by resume tests to prove
        exact continuation."""
        blk = self.source.block(self.state.step, self.batch, self.seq)
        return hashlib.sha256(blk.tobytes()).hexdigest()[:16]
