"""SPMD pipeline parallelism over a ("pipe", …) mesh axis.

MPMD -> SPMD adaptation (DESIGN.md §2): every rank runs the SAME jitted
program; a ``lax.scan`` over M + R - 1 steps shifts stage-boundary
activations to the next rank with ``lax.ppermute`` each step, and a rank
is "active" when its microbatch index t - r lands in [0, M).  Autodiff
through the scan + ppermute yields the exact reverse pipeline, so one
forward definition gives training with GPipe semantics (all-forward /
all-backward, boundary activations stashed per microbatch).

Arbitrary static tables (1F1B / interleaved / DualPipeV) are executed by
the Piper runtime from per-device plans (core/schedules.py + the
interpreter) and modelled by the timeline simulator; this module is the
single-program lane that proves pipeline placement composes with the
production mesh's data/model axes (launch/dryrun has a --pp lane).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_apply(stage_fn: Callable, params_stacked, x_microbatches,
                   *, mesh: Mesh, axis: str = "pipe"):
    """Run a pipeline of R = mesh.shape[axis] stages.

    stage_fn(stage_params, x) -> y          (same shape as x)
    params_stacked: pytree with leading dim R (stage-major), sharded so
      each pipe rank holds its stage (P(axis, ...)).
    x_microbatches: (M, mb, ...) inputs (replicated along the pipe axis).
    Returns (M, mb, ...) outputs of the LAST stage (valid on every rank;
    produced on rank R-1 and broadcast back via ppermute ring-shift).
    """
    R = mesh.shape[axis]
    M = x_microbatches.shape[0]
    steps = M + R - 1
    fwd_perm = [(i, (i + 1) % R) for i in range(R)]

    def per_rank(params, x_mb):
        # params: stage params with leading dim 1 (this rank's stage)
        params = jax.tree_util.tree_map(lambda a: a[0], params)
        r = jax.lax.axis_index(axis)
        mb_shape = x_mb.shape[1:]
        y_acc = jnp.zeros((M,) + mb_shape, x_mb.dtype)

        def step(carry, t):
            prev_out, y_acc = carry
            # receive boundary activation from the left neighbour
            recv = jax.lax.ppermute(prev_out, axis, fwd_perm)
            my_mb = t - r
            active = (my_mb >= 0) & (my_mb < M)
            x_first = x_mb[jnp.clip(my_mb, 0, M - 1)]
            x_in = jnp.where(r == 0, x_first, recv)
            out = stage_fn(params, x_in)
            out = jnp.where(active, out, jnp.zeros_like(out))
            # last stage banks its result
            is_last = r == R - 1
            y_acc = jax.lax.cond(
                active & is_last,
                lambda acc: acc.at[jnp.clip(my_mb, 0, M - 1)].set(out),
                lambda acc: acc, y_acc)
            return (out, y_acc), None

        init = (jnp.zeros(mb_shape, x_mb.dtype), y_acc)
        (last_out, y_acc), _ = jax.lax.scan(
            step, init, jnp.arange(steps))
        # broadcast the last rank's outputs to all ranks (psum of the
        # one-hot contribution)
        contrib = jnp.where(r == R - 1, y_acc, jnp.zeros_like(y_acc))
        return jax.lax.psum(contrib, axis)

    f = shard_map(
        per_rank, mesh=mesh,
        in_specs=(jax.tree_util.tree_map(
            lambda a: P(*([axis] + [None] * (a.ndim - 1))),
            params_stacked), P()),
        out_specs=P(),
        check_rep=False,
    )
    return f(params_stacked, x_microbatches)


def pipeline_loss(stage_fn, loss_fn, params_stacked, x_mb, y_mb, *,
                  mesh, axis="pipe"):
    """Mean loss over microbatches through the pipeline (differentiable:
    jax.grad of this yields the reverse pipeline)."""
    out = pipeline_apply(stage_fn, params_stacked, x_mb,
                         mesh=mesh, axis=axis)
    return loss_fn(out, y_mb)
