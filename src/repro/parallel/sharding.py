"""SPMD sharding rules for the production mesh (DESIGN.md §5).

Maps every parameter / activation / cache tensor to a NamedSharding over
the required meshes:
  single-pod (16, 16)  axes ("data", "model")
  multi-pod  (2,16,16) axes ("pod", "data", "model")

Strategy (the Piper high-level plan lowered to pjit):
  - batch over ("pod","data") — DP;
  - tensor parallelism over "model": attention heads / FFN columns /
    expert dimension (EP) / vocab;
  - ZeRO over "data": stage 1/2 shard optimizer state, stage 3 also
    shards parameters (FSDP-style) — XLA inserts the all-gathers /
    reduce-scatters the Piper IR makes explicit in the interpreter path;
  - decode caches shard the sequence dim over "model" (works for every
    kv-head count incl. MQA) and batch over "data".
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class ShardingRules:
    """The spmd backend's internal sharding rules — the lowered form of
    a first-class ``core.strategy.Strategy`` (``from_core`` is the only
    supported way in).  Known until PR 10 as ``parallel.sharding.
    Strategy``; that import still works behind a DeprecationWarning
    (module ``__getattr__`` below), erroring under pytest."""
    dp_axes: tuple = ("data",)       # + ("pod",) on the multi-pod mesh
    tp_axis: str = "model"
    zero_stage: int = 3              # 1 | 2 | 3
    shard_activations: bool = True
    # sequence/context parallelism: layer-boundary activations and
    # attention q shard their seq dim over this axis (Megatron-SP +
    # context-parallel attention) — the main activation-memory lever
    seq_axis: Optional[str] = "model"
    # attention sharding: "cp" = q over seq (works for any head count),
    # "tp" = heads over the model axis (needs head counts divisible by
    # the axis; avoids the CP dk/dv reductions)
    attn_mode: str = "cp"
    # MoE dispatch: "grouped" (pjit-auto) | "a2a" (shard_map all-to-all)
    moe_impl: str = "grouped"
    remat: str = "full"

    def batch_spec(self) -> P:
        ax = self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]
        return P(ax)

    @property
    def fsdp_axis(self) -> Optional[str]:
        return "data" if self.zero_stage >= 3 else None

    @staticmethod
    def from_core(strat, mesh, **overrides) -> "ShardingRules":
        """Derive the SPMD-lowering strategy from a first-class
        ``core.strategy.Strategy`` — the single source of truth both
        execution worlds now share.  The mapping:

          ZeRO fragment stage   -> ``zero_stage`` (absent -> 0: plain
                                   replicated DP, grads all-reduced)
          Remat fragment policy -> ``remat`` ("selective" has no pjit
                                   analogue and maps to "full")
          ExpertParallel        -> ``moe_impl="a2a"`` (explicit
                                   shard_map dispatch, the Piper-IR
                                   semantics) vs pjit-auto "grouped"
          mesh axes             -> ``dp_axes`` (("pod","data") on the
                                   multi-pod mesh)

        ``mesh`` is the *jax* device mesh the shardings target;
        ``overrides`` pass through remaining knobs (attn_mode,
        seq_axis, ...)."""
        from ..launch.mesh import dp_axes_for  # single source of truth
        kw: dict = {"dp_axes": dp_axes_for(mesh) or ("data",)}
        zero = strat.zero
        kw["zero_stage"] = zero.stage if zero is not None else 0
        rm = strat.remat
        if rm is not None:
            kw["remat"] = rm.policy if rm.policy != "selective" else "full"
        if strat.expert_parallel is not None:
            kw["moe_impl"] = "a2a"
        kw.update(overrides)
        return ShardingRules(**kw)


def _dim_ok(shape, dim, mesh, axis) -> bool:
    if axis is None:
        return True
    size = int(np.prod([mesh.shape[a] for a in
                        (axis if isinstance(axis, tuple) else (axis,))]))
    return shape[dim] % size == 0


def _spec(mesh, shape, *axes):
    """Build a PartitionSpec, dropping axes that don't divide."""
    out = []
    for dim, ax in enumerate(axes):
        if ax is not None and _dim_ok(shape, dim, mesh, ax):
            out.append(ax)
        else:
            out.append(None)
    return P(*out)


# param-name classification -------------------------------------------------

_COL = {"wq", "wk", "wv", "w_up", "w_gate", "in_proj", "lm_head"}
_ROW = {"wo", "w_down", "out_proj"}
_EXPERT = {"we_up", "we_down", "we_gate"}
# SSM projections: d_inner is tp-sharded by in_proj, so everything that
# CONSUMES d_inner (bc_proj/x_proj/dt_proj2: (d_inner, small)) is
# row-parallel, and dt_proj ((dt_rank, d_inner)) is column-parallel.
# (Getting these backwards costs a full-activation gather per layer —
# 233 GB/step of all-reduce on zamba2; see EXPERIMENTS.md §Perf.)
_SSM_COL = {"dt_proj"}
_SSM_ROW = {"bc_proj", "x_proj", "dt_proj2"}


def param_spec(path: tuple, shape: tuple, mesh: Mesh,
               strat: ShardingRules) -> P:
    """Sharding rule for one parameter.  ``path`` is the flattened dict
    path, e.g. ("layers", "attn", "wq"); stacked layer params carry a
    leading n_layers axis which stays unsharded."""
    name = path[-1]
    tp = strat.tp_axis
    fsdp = strat.fsdp_axis
    stacked = path[0] in ("layers", "enc_layers", "cross_layers") \
        and len(shape) >= 2
    lead = (None,) if stacked else ()
    body = shape[1:] if stacked else shape

    def spec(*axes):
        return _spec(mesh, shape, *(lead + axes))

    if name in ("embed",):
        return _spec(mesh, shape, tp, fsdp)         # vocab x d_model
    if name == "lm_head":
        return _spec(mesh, shape, fsdp, tp)         # d_model x vocab
    if name in _EXPERT:
        # (E, d_in, d_out): experts over tp; the ZeRO shard goes on the
        # OUTPUT dim — sharding d_in would put the einsum contraction on
        # a sharded dim and psum ~GB activation outputs per layer, while
        # gathering f-sharded weights costs ~25 MB (EXPERIMENTS §Perf D3)
        return spec(tp, None, fsdp)
    if name == "router":
        return spec(None, None)
    if name in _COL or name in _SSM_COL:
        if len(body) == 1:                          # bias
            return spec(tp)
        return spec(fsdp, tp)
    if name in _ROW or name in _SSM_ROW:
        if len(body) == 1:
            return spec(None)
        return spec(tp, fsdp)
    if name in ("bq", "bk", "bv"):
        return spec(tp)
    if name in ("conv_w",):                         # (K, d_inner)
        return spec(None, tp)
    if name in ("conv_b", "dt_bias", "D"):
        return spec(tp) if len(body) == 1 else spec(None)
    if name == "A_log":
        if len(body) == 2:                          # (d_inner, state)
            return spec(tp, None)
        return spec(tp)
    # norms and anything else: replicated
    return P(*([None] * len(shape)))


def params_shardings(params_avals, mesh: Mesh, strat: ShardingRules):
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_avals)
    out = []
    for kpath, leaf in flat:
        path = tuple(getattr(k, "key", getattr(k, "idx", None))
                     for k in kpath)
        spec = param_spec(path, leaf.shape, mesh, strat)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def opt_state_shardings(params_avals, mesh: Mesh, strat: ShardingRules):
    """AdamW m/v: ZeRO>=1 shards over 'data' on the largest divisible
    dim (in addition to the param's own sharding)."""
    p_sh = params_shardings(params_avals, mesh, strat)

    def widen(leaf_aval, sh):
        spec = list(sh.spec) + [None] * (len(leaf_aval.shape)
                                         - len(sh.spec))
        if strat.zero_stage >= 1:
            used = {a for s in spec if s
                    for a in (s if isinstance(s, tuple) else (s,))}
            if "data" not in used:
                # shard the largest unsharded divisible dim over data
                cand = sorted(range(len(spec)),
                              key=lambda d: -leaf_aval.shape[d])
                for d in cand:
                    if spec[d] is None and _dim_ok(leaf_aval.shape, d,
                                                   mesh, "data"):
                        spec[d] = "data"
                        break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map(widen, params_avals, p_sh)


def batch_shardings(batch_avals, mesh: Mesh, strat: ShardingRules):
    def one(aval):
        if not aval.shape:
            return NamedSharding(mesh, P())
        ax = strat.dp_axes if len(strat.dp_axes) > 1 else strat.dp_axes[0]
        if not _dim_ok(aval.shape, 0, mesh, ax):
            return NamedSharding(mesh, P())
        rest = [None] * (len(aval.shape) - 1)
        # mrope positions: (3, B, S) — batch is dim 1
        if len(aval.shape) == 3 and aval.shape[0] == 3 and \
                _dim_ok(aval.shape, 1, mesh, ax):
            return NamedSharding(mesh, P(None, ax, None))
        return NamedSharding(mesh, P(ax, *rest))
    return jax.tree_util.tree_map(one, batch_avals)


def cache_shardings(cache_avals, mesh: Mesh, strat: ShardingRules):
    """Decode caches: batch over dp axes, long dims over the tp axis.
    k/v: (L, B, Hkv, S, D) -> seq over tp; ssm: (L, B, …, N) -> d_inner
    (or heads) over tp; conv: (L, B, K-1, di) -> di over tp."""
    dp = strat.dp_axes if len(strat.dp_axes) > 1 else strat.dp_axes[0]
    tp = strat.tp_axis

    def one_path(kpath, aval):
        name = getattr(kpath[-1], "key", "")
        shape = aval.shape
        if name == "len" or not shape:
            return NamedSharding(mesh, P())
        if name in ("k", "v", "cross_k", "cross_v"):
            return NamedSharding(mesh, _spec(
                mesh, shape, None, dp, None, tp, None))
        if name == "ssm":
            if len(shape) == 4:   # (L, B, d_inner, N)
                return NamedSharding(mesh, _spec(
                    mesh, shape, None, dp, tp, None))
            return NamedSharding(mesh, _spec(  # (L, B, H, P, N)
                mesh, shape, None, dp, tp, None, None))
        if name == "conv":
            return NamedSharding(mesh, _spec(
                mesh, shape, None, dp, None, tp))
        if name == "enc_out":
            return NamedSharding(mesh, _spec(
                mesh, shape, dp, None, None))
        specs = [None] * len(shape)
        return NamedSharding(mesh, P(*specs))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_avals)
    out = [one_path(kp, leaf) for kp, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def __getattr__(name: str):
    if name == "Strategy":
        import warnings
        warnings.warn(
            "parallel.sharding.Strategy is deprecated: the class is an "
            "internal detail of the spmd backend, renamed ShardingRules."
            "  Describe parallelism with the first-class "
            "core.strategy.Strategy and let the backend derive its "
            "rules (launch.steps.strategy_for(core=...))",
            DeprecationWarning, stacklevel=2)
        return ShardingRules
    raise AttributeError(
        f"module {__name__!r} has no attribute {name!r}")
