"""SPMD lowering of Piper strategies: shardings, ZeRO, EP, pipeline."""
from .sharding import (Strategy, batch_shardings, cache_shardings,
                       opt_state_shardings, params_shardings)

__all__ = ["Strategy", "batch_shardings", "cache_shardings",
           "opt_state_shardings", "params_shardings"]
