"""SPMD lowering of Piper strategies: shardings, ZeRO, EP, pipeline."""
from .sharding import (ShardingRules, batch_shardings, cache_shardings,
                       opt_state_shardings, params_shardings)

__all__ = ["ShardingRules", "batch_shardings", "cache_shardings",
           "opt_state_shardings", "params_shardings"]


def __getattr__(name: str):
    if name == "Strategy":
        # route through the sharding module's shim so both import
        # spellings warn identically (and error under pytest)
        from . import sharding
        return sharding.Strategy
    raise AttributeError(
        f"module {__name__!r} has no attribute {name!r}")
