"""Piper reproduction on JAX — public package surface.

The declarative Strategy API is the front door for distributed training
plans; everything else (IR, runtime, tuner) is reachable through the
subpackages:

    from repro import Mesh, Pipeline, Strategy, ZeRO, compile_training

    strat = Strategy(Mesh(pp=4, dp=2),
                     Pipeline("1f1b", n_mb=8) | ZeRO(stage=3))
    prog = compile_training(forward, params, inputs, strategy=strat)
"""
from .core import compile_training
from .core.strategy import (SCHEMA_VERSION, ExpertParallel, Mesh,
                            Offload, Overlap, Pipeline, RawDirectives,
                            Remat, Strategy, StrategyError, ZeRO)

__all__ = [
    "ExpertParallel", "Mesh", "Offload", "Overlap", "Pipeline",
    "RawDirectives", "Remat", "SCHEMA_VERSION", "Strategy",
    "StrategyError", "ZeRO", "compile_training",
]
