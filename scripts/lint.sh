#!/usr/bin/env bash
# Static verification, locally reproducing the CI tier1-lint job:
#   1. ruff check src tests        (rule set in ruff.toml)
#   2. the typed deep plan-lint grid — 12 configs x (6 schedule/ZeRO
#      cells + 3 remat/offload memory cells), shape/dtype/shard
#      typechecker and per-rank interface signatures included
#      (the MPMD-readiness gate; see docs/lint.md)
#
# No XLA execution anywhere: plans are compiled at reduced size and
# analyzed structurally, so the whole thing finishes in seconds.
#
# Usage: scripts/lint.sh [extra lint-grid args, e.g. --arch qwen3-1b]
#   LINT_TIMEOUT=60  hard wall-clock cap for the grid (default 60)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

if command -v ruff > /dev/null 2>&1; then
    ruff check src tests
elif python -c "import ruff" > /dev/null 2>&1; then
    python -m ruff check src tests
else
    echo "lint.sh: ruff not installed, skipping the style leg" >&2
fi

exec timeout "${LINT_TIMEOUT:-60}" \
    python -m repro.launch.lint --grid --depth deep "$@"
