#!/usr/bin/env bash
# Examples smoke loop (CI job `examples-smoke`): runs the runnable
# examples that exercise the public Strategy API end-to-end, so an API
# regression in the examples fails CI even when unit tests still pass.
# Each example gets the same hard wall-clock cap as the tier-1 loop.
#
# Usage: scripts/examples_smoke.sh
#   EXAMPLES_TIMEOUT=300  per-example cap in seconds (default 300)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
CAP="${EXAMPLES_TIMEOUT:-300}"

echo "== examples/quickstart.py (Strategy JSON -> train --strategy) =="
timeout "$CAP" python examples/quickstart.py

echo "== examples/autotune.py --fast (search -> strategy round-trip) =="
timeout "$CAP" python examples/autotune.py --fast

echo "== examples/dualpipe_moe.py (DualPipeV x EP strategy) =="
timeout "$CAP" python examples/dualpipe_moe.py

echo "examples smoke: OK"
