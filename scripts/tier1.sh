#!/usr/bin/env bash
# Fast tier-1 verification loop: the full suite's heavyweight modules
# (arch smoke sweep, kernel grids, multi-device subprocess tests) are
# marked `slow` and skipped here, so this finishes in well under the
# 120s the slow modules alone take.  The canonical full run stays
#
#   PYTHONPATH=src python -m pytest -x -q
#
# Usage: scripts/tier1.sh [extra pytest args]
#   TIER1_TIMEOUT=300  hard wall-clock cap in seconds (default 300)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
exec timeout "${TIER1_TIMEOUT:-300}" \
    python -m pytest -x -q -m "not slow" "$@"
