"""Paper Fig. 7: PP x EP throughput for 1F1B / interleaved-1F1B /
DualPipeV, dense and MoE, on the timeline simulator with v5e constants.

The paper's numbers (A100s): Piper-interleaved +5% over Piper-1F1B;
Piper-DualPipeV +13% (1B) / +10% (9B) over its interleaved baseline.
We report makespan and tokens/s at two comm/compute ratios."""
from __future__ import annotations

import jax

from repro.runtime.costmodel import CostModel
from repro.runtime.simulator import TimelineSimulator

from .common import build_pp_program, emit

T_CHUNK = 10e-3


def const_cost(node):
    if node.dims.get("PASS") in ("Bi", "Bw"):
        return T_CHUNK / 2
    return T_CHUNK


def run(kind, R, n_mb, batch, experts_every, ici_bw, dp=1):
    prog, _ = build_pp_program(kind, R, n_mb, batch,
                               dp_per_rank=dp,
                               experts_every=experts_every)
    cost = CostModel(ici_bw=ici_bw, comm_latency=0.0)
    sim = TimelineSimulator(prog, cost, chunk_seconds_override=const_cost)
    return sim.run()


def main() -> None:
    R, n_mb, batch = 2, 8, 32
    for tag, every, bw in [
            ("dense_fastnet", 0, 1e9),
            ("moe_fastnet", 2, 1e9),
            ("moe_slownet", 2, 2.5e4)]:
        base = None
        for kind in ("1f1b", "interleaved_1f1b", "dualpipev"):
            r = (R if kind == "1f1b" else R)
            res = run(kind, r, n_mb, batch, every, bw, dp=2)
            tput = batch / res.makespan
            if base is None:
                base = res.makespan
            emit(f"fig7_{tag}_{kind}", res.makespan * 1e6,
                 f"tokens_per_s={tput:.0f};vs_1f1b="
                 f"{base/res.makespan:.3f}x")
    # headline: DualPipeV gain over interleaved at EP-bound ratio
    t_i = run("interleaved_1f1b", 2, 8, 32, 2, 2.5e4, dp=2).makespan
    t_d = run("dualpipev", 2, 8, 32, 2, 2.5e4, dp=2).makespan
    emit("fig7_dualpipev_gain_vs_interleaved", t_d * 1e6,
         f"gain={100*(1-t_d/t_i):.1f}%;paper=10-13%")


if __name__ == "__main__":
    main()
