"""Measured-vs-predicted step times for the SPMD plan executor.

For each (schedule x ZeRO x remat) cell: compile the Piper-IR program,
predict its step time on the timeline simulator (v5e CostModel, XLA
chunk cost analysis), execute it for REAL on faked host XLA devices via
``runtime.spmd.SpmdExecutor``, assert loss/grad bit-parity against the
reference interpreter, and record the measured/predicted ratio.  The
per-cell table + the ``tune.calibrate`` summary (median ratio folded
into the cost model's mfu, dispersion = honest simulator error bar on
this host) land in ``benchmarks/results/spmd/spmd_parity.json``.

Host-harness caveat (DESIGN.md §12): host cores are not v5e chips, so
the ABSOLUTE ratio is machine-specific and never CI-gated; only the
deterministic simulated headline ratios are (benchmarks/smoke.py).

Standalone:
  PYTHONPATH=src python -m benchmarks.bench_spmd_parity [--smoke]
(fakes its own host devices before jax initializes; --smoke drops to
1 measurement rep — what the bench-smoke CI job runs)
"""
from __future__ import annotations

import json
import pathlib
import sys

RESULTS = pathlib.Path(__file__).parent / "results" / "spmd"

# (schedule, zero, remat) cells; pp2 x dp2 = 4 devices keeps the
# host-device fan-out and compile times CI-friendly
CELLS = [
    ("1f1b", 1, "full"),
    ("1f1b", 3, "full"),
    ("gpipe", 3, "full"),
    ("dualpipev", 1, "none"),
]
PP, MB, BATCH = 2, 4, 32


def main(smoke: bool = False) -> None:
    import jax
    import numpy as np

    n_dev = 2 * PP
    if len(jax.devices()) < n_dev:
        print(f"# bench_spmd_parity SKIPPED: needs {n_dev} XLA devices, "
              f"have {len(jax.devices())} (run standalone: PYTHONPATH=src "
              "python -m benchmarks.bench_spmd_parity)")
        return

    from repro import tune
    from repro.core import Remat
    from repro.runtime import Interpreter
    from repro.runtime.costmodel import CostModel
    from repro.runtime.simulator import TimelineSimulator
    from repro.runtime.spmd import SpmdExecutor

    from .common import D, build_pp_program, emit

    cost = CostModel()
    reps = 1 if smoke else 3
    cells, rows, parity_all = [], [], True
    for (kind, zero, rm) in CELLS:
        label = f"{kind}/z{zero}/rm-{rm}"
        prog, params = build_pp_program(
            kind, PP, MB, BATCH, dp_per_rank=2, zero=zero,
            remat=Remat(policy=rm) if rm != "full" else None)
        batch = {
            "x": jax.random.normal(jax.random.PRNGKey(1), (BATCH, D)),
            "y": jax.random.normal(jax.random.PRNGKey(2), (BATCH, D))}
        predicted = TimelineSimulator(prog, cost).run().makespan
        ex = SpmdExecutor(prog)
        got = ex.run(batch)
        ref = Interpreter(prog).run(batch)
        parity = np.float64(ref.loss).tobytes() == \
            np.float64(got.loss).tobytes()
        for bkt in ref.grads:
            leaves_r = jax.tree_util.tree_leaves(ref.grads[bkt])
            leaves_g = jax.tree_util.tree_leaves(got.grads[bkt])
            parity = parity and len(leaves_r) == len(leaves_g) and all(
                np.asarray(a).tobytes() == np.asarray(b).tobytes()
                for a, b in zip(leaves_r, leaves_g))
        parity_all = parity_all and parity
        measured = ex.measure(batch, reps=reps)
        cell = tune.MeasuredCell(label=label, predicted_seconds=predicted,
                                 measured_seconds=measured)
        cells.append(cell)
        rows.append({**cell.to_dict(), "parity": bool(parity),
                     "tasks": got.stats["tasks"]})
        emit(f"spmd_parity[{label}]", measured * 1e6,
             f"pred={predicted*1e3:.2f}ms ratio={cell.ratio:.1f} "
             f"parity={'OK' if parity else 'FAIL'}")

    cal = tune.calibrate(cost, cells)
    emit("spmd_calibration", 0.0,
         f"scale={cal.scale:.1f} dispersion={cal.dispersion:.2f} "
         f"mfu={cal.cost.mfu:.2e}")
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = {"cells": rows, "calibration": cal.to_dict(),
           "parity_all": bool(parity_all),
           "mesh": {"pp": PP, "dp": 2}, "n_mb": MB, "batch": BATCH,
           "note": "measured on faked host devices; ratios are "
                   "calibration inputs, not absolute perf claims"}
    path = RESULTS / "spmd_parity.json"
    path.write_text(json.dumps(out, indent=1))
    print(f"# results -> {path}")
    if not parity_all:
        raise AssertionError("spmd/interpreter bit-parity FAILED")


if __name__ == "__main__":
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]
                           / "src"))
    from repro.launch.hostdevices import ensure_host_devices
    ensure_host_devices(2 * PP, verify=False)
    main(smoke="--smoke" in sys.argv[1:])
