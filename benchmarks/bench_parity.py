"""Paper Table 2: DP ZeRO-1 parity.  All systems in the paper land
within noise of each other; here the comparison is (a) a plain jitted
JAX train step vs (b) the same model compiled through the full Piper
IR -> plans -> interpreter path, plus (c) the interpreter's per-task
dispatch overhead — the runtime's 'minimal scheduling overhead' claim."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import (F, RawDirectives, Replicate, Strategy,
                        compile_training)
from repro.runtime import Interpreter

from .common import D, emit, loss_fn, make_forward, make_params, stage_fn

S, BATCH = 4, 64


def main() -> None:
    params = make_params(S, D)
    fwd = make_forward(S)
    x = jax.random.normal(jax.random.PRNGKey(1), (BATCH, D))
    y = jax.random.normal(jax.random.PRNGKey(2), (BATCH, D))

    # (a) plain jitted step (the lower bound)
    def full(params):
        h = x
        for i in range(S - 1):
            h = stage_fn(params[f"stage{i}"], h)
        return loss_fn(params[f"stage{S-1}"], h, y)
    vg = jax.jit(jax.value_and_grad(full))
    vg(params)[0].block_until_ready()
    t0 = time.perf_counter()
    n = 20
    for _ in range(n):
        l, g = vg(params)
    jax.block_until_ready((l, g))
    t_jit = (time.perf_counter() - t0) / n

    # (b) Piper DP ZeRO-1 via interpreter (2 simulated devices)
    sched = [Replicate(F(), devices=[0, 1], reduce_stream="dp")]
    prog = compile_training(fwd, params, {"x": ((BATCH, D), "float32"),
                                          "y": ((BATCH, D), "float32")},
                            strategy=Strategy(
                                None, RawDirectives(tuple(sched))))
    interp = Interpreter(prog, track_memory=False)
    interp.run({"x": x, "y": y})  # warm caches
    t0 = time.perf_counter()
    for _ in range(5):
        res = interp.run({"x": x, "y": y})
    t_piper = (time.perf_counter() - t0) / 5
    n_tasks = res.stats["tasks"]

    emit("table2_plain_jax_step", t_jit * 1e6,
         f"tokens_per_s={BATCH/t_jit:.0f}")
    emit("table2_piper_interp_step", t_piper * 1e6,
         f"tokens_per_s={BATCH/t_piper:.0f};tasks={n_tasks}")
    emit("table2_dispatch_overhead", (t_piper - t_jit) / n_tasks * 1e6,
         f"us_per_task;n_tasks={n_tasks}")


if __name__ == "__main__":
    main()
