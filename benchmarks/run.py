"""Benchmark aggregator — one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines.  Every Piper-IR program
the sections compile goes through the declarative Strategy API
(``common.build_pp_strategy`` / ``tune.candidate_strategy``).

  PYTHONPATH=src python -m benchmarks.run            # full suite
  PYTHONPATH=src python -m benchmarks.run --smoke    # CI headline
      ratios only (tiny shapes, 1 rep, deterministic) — optionally
      --smoke-out PATH to write the fresh headline JSON elsewhere
      (the bench-smoke CI job diffs it against the committed baseline
      via benchmarks/check_smoke.py)
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="compute only the deterministic headline "
                    "ratios (benchmarks/smoke.py): tiny shapes, 1 rep")
    ap.add_argument("--smoke-out", default=None, metavar="PATH",
                    help="where --smoke writes the fresh headline JSON "
                    "(default: refresh the committed baseline in "
                    "results/smoke/)")
    args = ap.parse_args(argv)

    # the spmd parity (4) and elastic recovery (8) sections need real
    # (faked-host) XLA devices; the flag must be set before jax's
    # backend first initializes.  Extra host devices are inert for the
    # simulator/interpreter sections.
    from repro.launch.hostdevices import ensure_host_devices
    ensure_host_devices(8, verify=False)

    import jax
    jax.config.update("jax_platform_name", "cpu")

    if args.smoke:
        from . import smoke
        smoke.main(args.smoke_out)
        return

    from . import (bench_chaos, bench_elastic, bench_kernels,
                   bench_mpmd_parity, bench_overlap, bench_parity,
                   bench_pp_schedules, bench_pp_zero, bench_remat,
                   bench_scaling, bench_spmd_parity)
    sections = [
        ("Fig7: PP x EP schedules (1F1B/interleaved/DualPipeV)",
         bench_pp_schedules.main),
        ("PR2: overlap engine on/off (ZeRO-3 x PP, DualPipeV)",
         bench_overlap.main),
        ("PR4: Remat/Offload memory-throughput frontier",
         bench_remat.main),
        ("PR5: SPMD executor measured-vs-predicted + bit-parity",
         bench_spmd_parity.main),
        ("PR10: MPMD executor measured-vs-predicted + trace economics",
         bench_mpmd_parity.main),
        ("PR6: elastic recovery steps-lost / wall-time grid",
         bench_elastic.main),
        ("PR7: chaos soak — fault-schedule recovery accounting",
         bench_chaos.main),
        ("Table1+Fig8: PP x ZeRO support + peak memory",
         bench_pp_zero.main),
        ("Table2: DP ZeRO-1 parity + dispatch overhead",
         bench_parity.main),
        ("Fig9: PP x DP scaling", bench_scaling.main),
        ("Kernels: Pallas vs oracle + v5e roofline", bench_kernels.main),
    ]
    failures = 0
    print("name,us_per_call,derived")
    for title, fn in sections:
        print(f"# --- {title} ---")
        t0 = time.time()
        try:
            fn()
        except Exception:
            failures += 1
            print(f"# SECTION FAILED: {title}", file=sys.stderr)
            traceback.print_exc()
        print(f"# ({time.time()-t0:.1f}s)")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
