"""Benchmark aggregator — one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines.  Every Piper-IR program
the sections compile goes through the declarative Strategy API
(``common.build_pp_strategy`` / ``tune.candidate_strategy``).

  PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    import jax
    jax.config.update("jax_platform_name", "cpu")
    sections = []
    from . import (bench_kernels, bench_overlap, bench_parity,
                   bench_pp_schedules, bench_pp_zero, bench_remat,
                   bench_scaling)
    sections = [
        ("Fig7: PP x EP schedules (1F1B/interleaved/DualPipeV)",
         bench_pp_schedules.main),
        ("PR2: overlap engine on/off (ZeRO-3 x PP, DualPipeV)",
         bench_overlap.main),
        ("PR4: Remat/Offload memory-throughput frontier",
         bench_remat.main),
        ("Table1+Fig8: PP x ZeRO support + peak memory",
         bench_pp_zero.main),
        ("Table2: DP ZeRO-1 parity + dispatch overhead",
         bench_parity.main),
        ("Fig9: PP x DP scaling", bench_scaling.main),
        ("Kernels: Pallas vs oracle + v5e roofline", bench_kernels.main),
    ]
    failures = 0
    print("name,us_per_call,derived")
    for title, fn in sections:
        print(f"# --- {title} ---")
        t0 = time.time()
        try:
            fn()
        except Exception:
            failures += 1
            print(f"# SECTION FAILED: {title}", file=sys.stderr)
            traceback.print_exc()
        print(f"# ({time.time()-t0:.1f}s)")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
