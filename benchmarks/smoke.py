"""CI smoke bench: deterministic headline speedup ratios, tiny shapes,
one rep (``python -m benchmarks.run --smoke``).

The full bench suite takes minutes and its committed results rot
silently: nothing failed a PR that quietly halved the overlap engine's
speedup.  This module recomputes the HEADLINE RATIOS through the same
machinery the real benches use — ArchConfig proxy programs, the
analytic chunk roofline (``tune.make_chunk_cost``) and the timeline
simulator, so every number is bit-deterministic across hosts — and
``check_smoke.py`` diffs them against the committed baseline with a
±15% tolerance in CI (job ``bench-smoke``).

Headlines (all dimensionless step-time ratios, qwen3-1b proxy):
  overlap_speedup_1f1b       ZeRO-3 overlap engine off / on, 1f1b
  overlap_speedup_dualpipev  ZeRO-3 overlap engine off / on, dualpipev
  remat_speedup              remat full / none (stash), 1f1b
  microbatch_bubble_ratio    1f1b mb=2 / mb=16 (the pipeline-bubble
                             fraction the schedule amortizes)

Measured SPMD wall-clock is deliberately NOT here — it is
machine-specific and lives un-gated in results/spmd/ (see
bench_spmd_parity.py).
"""
from __future__ import annotations

import json
import pathlib

BASELINE = pathlib.Path(__file__).parent / "results" / "smoke" / \
    "headline.json"

CONFIG = "qwen3-1b"
TOKENS = 16384   # tiny: smoke runs in seconds, not the bench's minutes


def _step_seconds(cand, mesh, overlap) -> float:
    from repro.configs import get_config
    from repro.runtime.costmodel import CostModel
    from repro.runtime.simulator import TimelineSimulator
    from repro.tune.proxy import build_candidate_program, make_chunk_cost

    cfg = get_config(CONFIG)
    prog, sm = build_candidate_program(cfg, mesh, cand, TOKENS,
                                       overlap=overlap)
    cost = CostModel()
    return TimelineSimulator(
        prog, cost, chunk_seconds_override=make_chunk_cost(
            sm, TOKENS, cand.n_mb, cost)).run().makespan


def compute_headlines() -> dict:
    from repro.core import OverlapConfig
    from repro.tune import Candidate, MeshSpec

    on = OverlapConfig(bucket_bytes=256 << 20, prefetch=4)
    off = OverlapConfig.off()
    z3 = MeshSpec(pp=2, dp=2)
    pp = MeshSpec(pp=2, dp=1)

    def span(kind, zero=3, mesh=z3, overlap=off, remat="full"):
        return _step_seconds(
            Candidate(kind=kind, n_mb=2 * mesh.pp, zero=zero,
                      remat=remat), mesh, overlap)

    return {
        "overlap_speedup_1f1b":
            span("1f1b") / span("1f1b", overlap=on),
        "overlap_speedup_dualpipev":
            span("dualpipev") / span("dualpipev", overlap=on),
        "remat_speedup":
            span("1f1b", zero=0, mesh=pp)
            / span("1f1b", zero=0, mesh=pp, remat="none"),
        "microbatch_bubble_ratio":
            _step_seconds(Candidate(kind="1f1b", n_mb=2, zero=0), pp, off)
            / _step_seconds(Candidate(kind="1f1b", n_mb=16, zero=0),
                            pp, off),
    }


def main(out_path: pathlib.Path | str | None = None) -> dict:
    headlines = compute_headlines()
    doc = {"headlines": headlines,
           "config": {"arch": CONFIG, "tokens": TOKENS},
           "tolerance": 0.15}
    path = pathlib.Path(out_path) if out_path else BASELINE
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(doc, indent=1, sort_keys=True))
    for k, v in sorted(headlines.items()):
        print(f"smoke[{k}],0.0,{v:.4f}")
    print(f"# smoke headlines -> {path}")
    return doc


if __name__ == "__main__":
    import sys
    main(sys.argv[1] if len(sys.argv) > 1 else None)
