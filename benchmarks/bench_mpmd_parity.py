"""Measured-vs-predicted step times for the MPMD multi-controller
executor, plus the per-rank trace-size column.

For each (schedule x ZeRO) cell: compile the Piper-IR program, predict
its step time on the timeline simulator (v5e CostModel), execute it for
REAL as per-rank jit programs dispatched by N controller threads over
the async transport (``runtime.mpmd.MpmdExecutor``), assert loss/grad
bit-parity against the reference interpreter, and record

  - measured/predicted ratio (same caveat as the SPMD table: host
    cores are not v5e chips, so the ratio is a calibration input, not
    an absolute-perf claim);
  - trace economics — max per-rank jaxpr equation count vs the SPMD
    whole-mesh trace of the same plan.  The recorded (and CI-tested,
    tests/test_mpmd_executor.py) claim is per_rank_max < spmd_eqns for
    world >= 4: MPMD ranks never trace chunks they do not execute.

Results land in ``benchmarks/results/mpmd/mpmd_parity.json``.

Standalone:
  PYTHONPATH=src python -m benchmarks.bench_mpmd_parity [--smoke]
(fakes its own host devices before jax initializes; --smoke drops to
1 measurement rep and the first two cells)
"""
from __future__ import annotations

import json
import pathlib
import sys

RESULTS = pathlib.Path(__file__).parent / "results" / "mpmd"

# (schedule, zero) cells; pp2 x dp2 = 4 devices = 4 controller threads
# keeps host-device fan-out and per-rank compile times CI-friendly
CELLS = [
    ("1f1b", 0),
    ("1f1b", 3),
    ("gpipe", 3),
    ("dualpipev", 3),
]
PP, MB, BATCH = 2, 4, 32


def main(smoke: bool = False) -> None:
    import jax
    import numpy as np

    n_dev = 2 * PP
    if len(jax.devices()) < n_dev:
        print(f"# bench_mpmd_parity SKIPPED: needs {n_dev} XLA devices, "
              f"have {len(jax.devices())} (run standalone: PYTHONPATH=src "
              "python -m benchmarks.bench_mpmd_parity)")
        return

    from repro.runtime import Interpreter
    from repro.runtime.costmodel import CostModel
    from repro.runtime.simulator import TimelineSimulator
    from repro.runtime.executor import make_executor
    from repro.runtime.spmd import SpmdExecutor

    from .common import D, build_pp_program, emit

    cost = CostModel()
    reps = 1 if smoke else 3
    rows, parity_all, trace_all = [], True, True
    for (kind, zero) in (CELLS[:2] if smoke else CELLS):
        label = f"{kind}/z{zero}"
        mb = 2 * MB if kind == "dualpipev" else MB
        prog, params = build_pp_program(kind, PP, mb, BATCH,
                                        dp_per_rank=2, zero=zero)
        batch = {
            "x": jax.random.normal(jax.random.PRNGKey(1), (BATCH, D)),
            "y": jax.random.normal(jax.random.PRNGKey(2), (BATCH, D))}
        predicted = TimelineSimulator(prog, cost).run().makespan
        ex = make_executor("mpmd", prog)
        got = ex.run(batch)
        ref = Interpreter(prog).run(batch)
        parity = np.float64(ref.loss).tobytes() == \
            np.float64(got.loss).tobytes()
        for bkt in ref.grads:
            leaves_r = jax.tree_util.tree_leaves(ref.grads[bkt])
            leaves_g = jax.tree_util.tree_leaves(got.grads[bkt])
            parity = parity and len(leaves_r) == len(leaves_g) and all(
                np.asarray(a).tobytes() == np.asarray(b).tobytes()
                for a, b in zip(leaves_r, leaves_g))
        parity_all = parity_all and parity
        measured = ex.measure(batch, reps=reps)
        per_rank = ex.trace_sizes(batch)
        spmd_eqns = SpmdExecutor(prog).trace_size(batch)
        trace_ok = max(per_rank.values()) < spmd_eqns
        trace_all = trace_all and trace_ok
        ex.close()
        rows.append({
            "label": label,
            "predicted_seconds": predicted,
            "measured_seconds": measured,
            "ratio": measured / max(predicted, 1e-12),
            "parity": bool(parity),
            "tasks": got.stats["tasks"],
            "per_rank_eqns": {str(r): n for r, n in sorted(
                per_rank.items())},
            "per_rank_eqns_max": max(per_rank.values()),
            "spmd_whole_mesh_eqns": spmd_eqns,
            "trace_shrink": round(
                max(per_rank.values()) / spmd_eqns, 4)})
        emit(f"mpmd_parity[{label}]", measured * 1e6,
             f"pred={predicted*1e3:.2f}ms "
             f"ratio={measured / max(predicted, 1e-12):.1f} "
             f"parity={'OK' if parity else 'FAIL'} "
             f"trace={max(per_rank.values())}/{spmd_eqns}eqns")

    RESULTS.mkdir(parents=True, exist_ok=True)
    out = {"cells": rows,
           "parity_all": bool(parity_all),
           "per_rank_trace_below_spmd_all": bool(trace_all),
           "mesh": {"pp": PP, "dp": 2}, "n_mb": MB, "batch": BATCH,
           "world": n_dev,
           "note": "measured on faked host devices (controller threads "
                   "+ inproc transport); ratios are calibration inputs, "
                   "not absolute perf claims — the reproducible claims "
                   "are bit-parity and per-rank-trace < whole-mesh-trace"}
    path = RESULTS / "mpmd_parity.json"
    path.write_text(json.dumps(out, indent=1))
    print(f"# results -> {path}")
    if not parity_all:
        raise AssertionError("mpmd/interpreter bit-parity FAILED")
    if not trace_all:
        raise AssertionError(
            "per-rank trace not below SPMD whole-mesh trace")


if __name__ == "__main__":
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]
                           / "src"))
    from repro.launch.hostdevices import ensure_host_devices
    ensure_host_devices(2 * PP, verify=False)
    main(smoke="--smoke" in sys.argv[1:])
