"""Assemble EXPERIMENTS.md from the dry-run result JSONs (both meshes,
plus tagged hillclimb variants) and the hand-maintained narrative.

  PYTHONPATH=src python -m benchmarks.make_experiments > EXPERIMENTS.md
"""
from __future__ import annotations

import json
import pathlib
import sys

RESULTS = pathlib.Path(__file__).parent / "results" / "dryrun"

ARCHS = ["minicpm-2b", "qwen1.5-0.5b", "qwen2.5-32b", "granite-20b",
         "dbrx-132b", "deepseek-moe-16b", "falcon-mamba-7b",
         "whisper-large-v3", "qwen2-vl-7b", "zamba2-2.7b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

MOVE_DOWN = {
    "minicpm-2b": "tied-embedding CE dominates bytes; fuse logits+CE "
                  "and drop remat on the small stack",
    "qwen1.5-0.5b": "CP attention dk/dv all-reduce + CE bytes; head-TP "
                    "attention (kv=16 divides) removes the reductions",
    "qwen2.5-32b": "remat recompute bytes; selective (attention-only) "
                   "remat would cut ~30% of t_mem",
    "granite-20b": "MQA replicates kv — CP already optimal; bytes from "
                   "remat recompute",
    "dbrx-132b": "MoE dispatch token copies are replicated over tp; a "
                 "shard_map all-to-all dispatch removes the xt "
                 "replication (biggest single lever)",
    "deepseek-moe-16b": "64-expert dispatch buffers; same shard_map a2a "
                        "lever as dbrx",
    "falcon-mamba-7b": "SP boundary forces per-layer seq<->channel "
                       "regathers; keep activations channel-sharded "
                       "(seq_axis=None) for SSM archs",
    "whisper-large-v3": "encoder runs unsharded seq 1500 (odd size); "
                        "pad-to-divisible would let SP shard it",
    "qwen2-vl-7b": "M-RoPE tables recomputed per layer under remat; "
                   "hoist cos/sin outside the scan",
    "zamba2-2.7b": "SSD chunk-state copies dominate bytes; larger "
                   "ssm_chunk + head-TP attention on the shared block",
}


def load(arch, shape, mesh, tag=""):
    name = f"{arch}__{shape}__{mesh}" + (f"__{tag}" if tag else "")
    p = RESULTS / f"{name}.json"
    return json.loads(p.read_text()) if p.exists() else None


def gb(x):
    return f"{x/2**30:.2f}" if x else "-"


def sec(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def dryrun_table(mesh):
    rows = [f"| arch | shape | status | compile | mem/dev | HLO GFLOP/dev "
            f"| HLO GB/dev | coll GB/dev | collectives |",
            "|---|---|---|---|---|---|---|---|---|"]
    for a in ARCHS:
        for s in SHAPES:
            r = load(a, s, mesh)
            if r is None:
                rows.append(f"| {a} | {s} | MISSING | | | | | | |")
                continue
            if r["status"] != "ok":
                rows.append(f"| {a} | {s} | {r['status']} | | | | | | |")
                continue
            coll = r.get("collective", {})
            kinds = ",".join(f"{k}:{v}" for k, v in sorted(
                coll.get("per_kind_count", {}).items()))
            rows.append(
                f"| {a} | {s} | ok | {r.get('compile_s', '-')}s "
                f"| {r.get('memory', {}).get('per_device_total_gb', '-')}GB "
                f"| {r.get('flops', 0)/1e9:.0f} "
                f"| {gb(r.get('bytes_accessed', 0))} "
                f"| {gb(coll.get('total_bytes', 0))} "
                f"| {kinds} |")
    return "\n".join(rows)


def roofline_table(mesh="pod1"):
    rows = ["| arch | shape | t_compute | t_memory | t_collective | "
            "dominant | MODEL_FLOPs/HLO_FLOPs | to move the dominant "
            "term down |",
            "|---|---|---|---|---|---|---|---|"]
    for a in ARCHS:
        for s in SHAPES:
            r = load(a, s, mesh)
            if r is None or r["status"] != "ok":
                continue
            rf = r.get("roofline", {})
            note = MOVE_DOWN.get(a, "") if s == "train_4k" else ""
            rows.append(
                f"| {a} | {s} | {sec(rf.get('t_compute_s'))} "
                f"| {sec(rf.get('t_memory_s'))} "
                f"| {sec(rf.get('t_collective_s'))} "
                f"| {rf.get('dominant')} "
                f"| {r.get('useful_flops_ratio', '-')} | {note} |")
    return "\n".join(rows)


def perf_variant_row(arch, shape, tag, label):
    r = load(arch, shape, "pod1", tag)
    if r is None or r.get("status") != "ok":
        return f"| {label} | (failed/missing) | | | | |"
    rf = r["roofline"]
    mem = r.get("memory", {}).get("per_device_total_gb", "-")
    dom = max(rf["t_compute_s"], rf["t_memory_s"], rf["t_collective_s"])
    return (f"| {label} | {sec(rf['t_compute_s'])} "
            f"| {sec(rf['t_memory_s'])} | {sec(rf['t_collective_s'])} "
            f"| {mem}GB | {sec(dom)} |")


def perf_table(arch, shape, variants):
    rows = ["| variant | t_compute | t_memory | t_collective | mem/dev "
            "| dominant term |",
            "|---|---|---|---|---|---|",
            perf_variant_row(arch, shape, "", "baseline (paper-faithful)")]
    for tag, label in variants:
        rows.append(perf_variant_row(arch, shape, tag, label))
    return "\n".join(rows)


def main():
    out = TEMPLATE.format(
        dryrun_pod1=dryrun_table("pod1"),
        dryrun_pod2=dryrun_table("pod2"),
        roofline=roofline_table(),
        perf_zamba=perf_table("zamba2-2.7b", "train_4k", [
            ("attn_tp", "B1: head-TP shared-attention (refuted)"),
            ("rowfix", "B2: row-parallel SSM projections"),
            ("best", "B3 = B2 + ssm_chunk 512 (best)"),
        ]),
        perf_falcon=perf_table("falcon-mamba-7b", "prefill_32k", [
            ("nosp", "C1: drop SP boundary (refuted)"),
            ("rowfix", "C2: row-parallel SSM projections (mixed)"),
            ("best", "C3 = C2 + ssm_chunk 512"),
        ]),
        perf_dbrx=perf_table("dbrx-132b", "train_4k", [
            ("attn_tp", "D1: head-TP attention (refuted)"),
            ("lc1024", "D2: loss_chunk 1024 (refuted)"),
            ("expertfix", "D3: expert ZeRO on output dim (refuted)"),
            ("moe_a2a", "D4: shard_map all-to-all EP dispatch (best)"),
        ]),
    )
    sys.stdout.write(out)


TEMPLATE = open(pathlib.Path(__file__).parent /
                "experiments_template.md").read()

if __name__ == "__main__":
    main()
