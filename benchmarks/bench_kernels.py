"""Kernel microbenchmarks: validate Pallas kernels against oracles at a
few shapes and report the TPU-target roofline prediction per kernel
(this container is CPU-only — interpret-mode wall time is not kernel
performance; the derived column carries the v5e-roofline estimate)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_fwd_pallas
from repro.kernels.moe_gmm import moe_gmm_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas

from .common import emit

PEAK = 197e12
HBM = 819e9


def roofline_us(flops, nbytes):
    return max(flops / PEAK, nbytes / HBM) * 1e6


def main() -> None:
    # rmsnorm: (4096, 4096) bf16
    n, d = 4096, 4096
    x = jax.random.normal(jax.random.PRNGKey(0), (n, d), jnp.bfloat16)
    w = jnp.ones((d,), jnp.bfloat16)
    got = rmsnorm_pallas(x[:128], w, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref.rmsnorm_ref(x[:128], w),
                                          np.float32), atol=3e-2,
                               rtol=3e-2)
    emit("kernel_rmsnorm_4096x4096", roofline_us(4 * n * d, 4 * n * d),
         f"v5e_roofline;bytes={4*n*d}")

    # flash attention fwd: b1 h8 s2048 d128
    b, h, s, dd = 1, 8, 2048, 128
    q = jax.random.normal(jax.random.PRNGKey(1), (b, h, 256, dd),
                          jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(2), (b, h, 256, dd),
                          jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(3), (b, h, 256, dd),
                          jnp.bfloat16)
    got = flash_attention_fwd_pallas(q, k, v, causal=True, block_q=128,
                                     block_kv=128, interpret=True)
    want = ref.naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=3e-2,
                               rtol=3e-2)
    fl = 4 * b * h * s * s * dd // 2  # causal
    byt = 2 * b * h * s * dd * 4
    emit("kernel_flash_fwd_b1h8s2048d128", roofline_us(fl, byt),
         f"v5e_roofline;flops={fl}")

    # grouped matmul: E16 cap512 d1024 f2816
    e, cap, d1, f = 16, 512, 1024, 2816
    fl = 2 * e * cap * d1 * f
    byt = 2 * (e * cap * d1 + e * d1 * f + e * cap * f)
    emit("kernel_moe_gmm_e16", roofline_us(fl, byt),
         f"v5e_roofline;arith_intensity={fl/byt:.1f}")

    # mamba scan: B8 S2048 C8192 N16 — memory bound elementwise
    bm, sm, cm, nm = 8, 2048, 8192, 16
    fl = 6 * bm * sm * cm * nm
    byt = 4 * bm * sm * cm * 3
    emit("kernel_mamba_scan", roofline_us(fl, byt),
         f"v5e_roofline;arith_intensity={fl/byt:.2f}")


if __name__ == "__main__":
    main()
