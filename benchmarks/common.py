"""Shared benchmark utilities: the Piper-IR MoE pipeline model used by
the schedule/memory benches (stage granularity mirrors the paper's
Qwen3 experiments at interpreter scale), plus CSV emit helpers.
Programs compile through the declarative Strategy API
(``core.strategy``)."""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp

from repro.core import (ExpertParallel, Mesh, Overlap, Pipeline, Strategy,
                        ZeRO, compile_training)
# re-exported for benches composing activation-memory fragments
from repro.core import Offload, Remat  # noqa: F401

D = 32


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def stage_fn(p, x):
    h = jnp.tanh(x @ p["w1"])
    return jnp.tanh(h @ p["w2"])


def loss_fn(p, x, y):
    return jnp.mean((stage_fn(p, x) - y) ** 2)


def make_params(n_stage, d=D, experts_every=0, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4 * n_stage)
    params = {}
    for i in range(n_stage):
        params[f"stage{i}"] = {
            "w1": jax.random.normal(ks[4 * i], (d, d)) * 0.1,
            "w2": jax.random.normal(ks[4 * i + 1], (d, d)) * 0.1}
        if experts_every and i % experts_every == 1 and i < n_stage - 1:
            params[f"exp{i}"] = {
                "w1": jax.random.normal(ks[4 * i + 2], (d, d)) * 0.1,
                "w2": jax.random.normal(ks[4 * i + 3], (d, d)) * 0.1}
    return params


def make_forward(n_stage, experts_every=0):
    def forward(rec, tvs):
        h = tvs["x"]
        for i in range(n_stage - 1):
            with rec.annotate("pp"):
                h = rec.region(stage_fn, f"stage{i}", name=f"s{i}")(h)
                if experts_every and i % experts_every == 1:
                    with rec.annotate("ep"):
                        h = rec.region(stage_fn, f"exp{i}",
                                       name=f"e{i}")(h)
        with rec.annotate("pp"):
            loss = rec.region(loss_fn, f"stage{n_stage-1}",
                              name="head")(h, tvs["y"])
        return loss
    return forward


def build_pp_strategy(kind: str, n_ranks: int, n_mb: int,
                      dp_per_rank: int = 1, experts_every: int = 0,
                      zero: int = 0, overlap=None, remat=None,
                      offload=None) -> Strategy:
    """The declarative strategy the benches run: PP(kind) x
    DP(dp_per_rank) x optional EP, ZeRO level on the DP groups, the
    optional overlap engine (``overlap``: an ``OverlapConfig`` or
    None), and the optional activation-memory fragments (``remat``:
    a ``Remat``; ``offload``: an ``Offload``)."""
    frags = [Pipeline(kind, n_mb=n_mb)]
    if dp_per_rank > 1 or zero:
        frags.append(ZeRO(stage=zero))
    if experts_every:
        frags.append(ExpertParallel())
    if overlap is not None:
        frags.append(Overlap.from_config(overlap))
    if remat is not None:
        frags.append(remat)
    if offload is not None:
        frags.append(offload)
    return Strategy(Mesh(pp=n_ranks, dp=dp_per_rank), tuple(frags))


def build_pp_program(kind: str, n_ranks: int, n_mb: int, batch: int,
                     dp_per_rank: int = 1, experts_every: int = 0,
                     zero: int = 0, d=D, seed=0, overlap=None,
                     remat=None, offload=None):
    """Compile a Piper program through the Strategy front door:
    PP(kind) x DP(dp_per_rank) x optional EP, with ZeRO level on the DP
    groups.  Every schedule kind runs the SAME 2R-stage model
    (1f1b/gpipe place two consecutive stages per rank) so throughput
    comparisons are apples-to-apples."""
    S = 2 * n_ranks
    params = make_params(S, d, experts_every, seed)
    fwd = make_forward(S, experts_every)
    strat = build_pp_strategy(kind, n_ranks, n_mb, dp_per_rank,
                              experts_every, zero, overlap,
                              remat=remat, offload=offload)
    inputs = {"x": ((batch, d), "float32"), "y": ((batch, d), "float32")}
    prog = compile_training(fwd, params, inputs, strategy=strat)
    return prog, params
