"""Elastic recovery cost grid: steps-lost and recovery wall time.

For each (schedule x ZeRO) cell: train the bench MoE-free pipeline
model on 8 faked host XLA devices (Mesh(pp=4, dp=2)) through
``ft.elastic.ElasticSupervisor``, kill rank 3 mid-run, and record the
``RecoveryReport`` — steps lost (bounded by the checkpoint interval),
recovery wall time, and its compile share.  Each cell runs twice: cold
(the shrunk plan is compiled inside the recovery window) and prewarmed
(``prewarm()`` compiled it ahead of time, so recovery pays only
restore + executor rebuild) — the delta is the price of plan
compilation as a runtime event, and the case for the plan cache.

Results land in ``benchmarks/results/elastic/elastic.json``.  Host
wall-clock is machine-specific: the JSON is a recorded artifact and a
shape check (steps_lost <= checkpoint interval; prewarm removes the
compile share), never an absolute-performance CI gate.

Standalone:
  PYTHONPATH=src python -m benchmarks.bench_elastic [--smoke]
(fakes its own host devices before jax initializes; --smoke runs a
single cell)
"""
from __future__ import annotations

import json
import pathlib
import sys
import tempfile
import time

RESULTS = pathlib.Path(__file__).parent / "results" / "elastic"

# (schedule, zero) cells on Mesh(pp=4, dp=2); a lost rank shrinks dp
# 2 -> 1, so zero=3 also exercises the checkpoint shard remap (2 -> 1)
CELLS = [
    ("1f1b", 0),
    ("1f1b", 3),
    ("gpipe", 0),
    ("gpipe", 3),
]
PP, DP, MB, BATCH = 4, 2, 4, 16
N_STEPS, CKPT_EVERY, FAIL_AT, KILL_RANK = 10, 4, 6, 3


def _run_cell(kind: str, zero: int, *, prewarm: bool) -> dict:
    import jax

    from repro.checkpoint import CheckpointManager
    from repro.data import SyntheticVectorSource, VectorLoader
    from repro.ft import ElasticSupervisor, RankFailureInjector
    from repro.runtime.executor import executor_factory

    from .common import D, build_pp_program

    prog, params = build_pp_program(kind, PP, MB, BATCH,
                                    dp_per_rank=DP, zero=zero, d=D)

    factory = executor_factory("spmd")

    with tempfile.TemporaryDirectory() as td:
        loader = VectorLoader(SyntheticVectorSource(D, seed=11),
                              batch=BATCH)
        sup = ElasticSupervisor(
            prog, CheckpointManager(pathlib.Path(td), keep=10,
                                    async_save=False),
            loader, runner_factory=factory,
            checkpoint_every=CKPT_EVERY,
            injector=RankFailureInjector({FAIL_AT: KILL_RANK}))
        prewarm_seconds = 0.0
        if prewarm:
            t0 = time.time()
            sup.prewarm(1)
            prewarm_seconds = time.time() - t0
        t0 = time.time()
        sup.run(params, N_STEPS, log_every=0)
        wall = time.time() - t0
        assert len(sup.reports) == 1, sup.reports
        r = sup.reports[0]
        assert 0 < r.steps_lost <= CKPT_EVERY, r.steps_lost
        if prewarm:
            assert r.cache_hit and r.compile_seconds == 0.0
        return {"schedule": kind, "zero": zero, "prewarmed": prewarm,
                "prewarm_seconds": round(prewarm_seconds, 4),
                "run_wall_seconds": round(wall, 4),
                **{k: (round(v, 4) if isinstance(v, float) else v)
                   for k, v in r.to_dict().items()}}


def main(smoke: bool = False) -> None:
    import jax

    n_dev = PP * DP
    if len(jax.devices()) < n_dev:
        print(f"# bench_elastic SKIPPED: needs {n_dev} XLA devices, "
              f"have {len(jax.devices())} (run standalone: PYTHONPATH=src "
              "python -m benchmarks.bench_elastic)")
        return

    from .common import emit

    cells = CELLS[:1] if smoke else CELLS
    rows = []
    for kind, zero in cells:
        for prewarm in (False, True):
            row = _run_cell(kind, zero, prewarm=prewarm)
            rows.append(row)
            emit(f"elastic[{kind}/z{zero}"
                 f"{'/prewarm' if prewarm else ''}]",
                 row["recovery_seconds"] * 1e6,
                 f"steps_lost={row['steps_lost']} "
                 f"compile={row['compile_seconds']:.2f}s "
                 f"cache_hit={row['cache_hit']}")
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = {"cells": rows,
           "mesh": {"pp": PP, "dp": DP}, "n_mb": MB, "batch": BATCH,
           "n_steps": N_STEPS, "checkpoint_every": CKPT_EVERY,
           "fail_at": FAIL_AT, "kill_rank": KILL_RANK,
           "note": "recovery wall time measured on faked host devices; "
                   "a recorded artifact, not an absolute-perf gate — "
                   "steps_lost and the cold-vs-prewarmed compile share "
                   "are the reproducible claims"}
    path = RESULTS / "elastic.json"
    path.write_text(json.dumps(out, indent=1))
    print(f"# results -> {path}")


if __name__ == "__main__":
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]
                           / "src"))
    from repro.launch.hostdevices import ensure_host_devices
    ensure_host_devices(PP * DP, verify=False)
    main(smoke="--smoke" in sys.argv[1:])
