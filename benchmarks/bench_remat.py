"""Remat/Offload memory-throughput frontier (PR-4 acceptance).

For each (schedule x remat policy) point on a real ArchConfig proxy,
reports the simulator-predicted step time (the analytic chunk roofline
is remat-aware: a stashed backward skips the forward re-run) and the
static per-device peak estimate (``timeline_peak_bytes`` charges the
stashed residuals over their true forward->backward lifetimes).  The
frontier is the tentpole claim made measurable: ``Remat(policy="none")``
buys step time with activation memory, ``"selective"`` sits between,
and ``Offload`` pulls the peak back down for a DMA-time price.

Budget section: the autotuner sweep over the ``Candidate.remat`` axis
under a per-device memory budget midway between the full/none peaks —
it must reject the over-budget remat=none candidate and select the
feasible full-remat one (the ``--memory-budget`` flag of
``launch/train.py`` drives the same constraint).

Parity section: an interpreter-scale MLP program checks that
``Remat("full")`` is bit-identical to the undeclared default and that
``Offload`` round-trips are bit-identical to the non-offloaded plan.

A JSON summary lands in benchmarks/results/remat/ (layout documented in
benchmarks/README.md).

  PYTHONPATH=src python -m benchmarks.bench_remat
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from repro.configs import get_config
from repro.core import Offload, Remat, Strategy
from repro.runtime import Interpreter
from repro.runtime.costmodel import CostModel
from repro.runtime.memory import timeline_peak_bytes
from repro.runtime.simulator import TimelineSimulator
from repro.tune import (Candidate, MeshSpec, SearchSpace,
                        build_candidate_program, make_chunk_cost, search)

from .common import build_pp_program, emit

TOKENS = 16384
CONFIG = "qwen3-1b"
KINDS = ("1f1b", "gpipe", "dualpipev")
POLICIES = ("full", "selective", "none")


def _score(cfg, mesh, cand, offload=None):
    strat = cand.to_strategy(mesh)
    if offload is not None:
        strat = strat | offload
    from repro.tune.proxy import build_strategy_program
    prog, sm = build_strategy_program(cfg, strat, TOKENS)
    cost = CostModel()
    override = make_chunk_cost(sm, TOKENS, cand.n_mb, cost)
    res = TimelineSimulator(prog, cost,
                           chunk_seconds_override=override).run()
    peaks = timeline_peak_bytes(prog, res.records)
    return {"strategy": strat.label(), "step_seconds": res.makespan,
            "peak_bytes": max(peaks.values())}


def frontier(cfg, mesh):
    rows = []
    for kind in KINDS:
        base = {}
        for policy in POLICIES:
            cand = Candidate(kind, n_mb=2 * mesh.pp, remat=policy)
            row = _score(cfg, mesh, cand)
            base[policy] = row
            emit(f"remat_frontier_{kind}_{policy}",
                 row["step_seconds"] * 1e6,
                 f"peak_gib={row['peak_bytes'] / 2**30:.3f}")
        off = _score(cfg, mesh, Candidate(kind, n_mb=2 * mesh.pp,
                                          remat="none"),
                     offload=Offload(depth=2))
        emit(f"remat_frontier_{kind}_none_offload",
             off["step_seconds"] * 1e6,
             f"peak_gib={off['peak_bytes'] / 2**30:.3f}")
        speedup = base["full"]["step_seconds"] / \
            base["none"]["step_seconds"]
        mem_ratio = base["none"]["peak_bytes"] / \
            base["full"]["peak_bytes"]
        ok = (base["none"]["step_seconds"] < base["full"]["step_seconds"]
              and base["none"]["peak_bytes"] > base["full"]["peak_bytes"])
        emit(f"remat_tradeoff_{kind}", 0.0,
             f"speedup_none={speedup:.3f}x;mem_x={mem_ratio:.3f};"
             f"{'OK' if ok else 'FAIL'}")
        rows.append({"kind": kind, "policies": base,
                     "none_offload": off,
                     "speedup_none_vs_full": speedup,
                     "mem_ratio_none_vs_full": mem_ratio, "ok": ok})
    # Offload must win back peak memory where the stash windows are deep
    # (gpipe holds every microbatch; dualpipev's V placement stalls the
    # tail).  1f1b's short windows can LOSE to offload when the DMA
    # round-trips become the bottleneck — reported, not asserted.
    deep = {r["kind"]: r for r in rows if r["kind"] != "1f1b"}
    off_ok = all(r["none_offload"]["peak_bytes"]
                 < r["policies"]["none"]["peak_bytes"]
                 for r in deep.values())
    emit("remat_offload_acceptance", 0.0,
         ";".join(f"{k}_saved_gib="
                  f"{(r['policies']['none']['peak_bytes'] - r['none_offload']['peak_bytes']) / 2**30:.3f}"
                  for k, r in deep.items())
         + (";OK" if off_ok else ";FAIL"))
    return {"rows": rows, "offload_ok": off_ok}


def budget_section(cfg, mesh):
    """Budget-constrained tuning over the remat axis."""
    space = SearchSpace(kinds=("1f1b",), mb_multipliers=(2,),
                        remat_policies=("full", "none"))
    free = search(cfg, mesh, None, tokens=TOKENS, space=space,
                  use_cache=False)
    budget = int((free.predicted_peak_bytes
                  + free.baseline.peak_bytes) // 2) \
        if free.candidate.remat == "none" else None
    capped = search(cfg, mesh, budget, tokens=TOKENS, space=space,
                    use_cache=False)
    ok = (free.candidate.remat == "none"
          and capped.candidate.remat == "full"
          and capped.n_rejected >= 1)
    emit("remat_budget_acceptance", 0.0,
         f"free={free.candidate.label()};"
         f"capped={capped.candidate.label()};"
         f"rejected={capped.n_rejected};{'OK' if ok else 'FAIL'}")
    return {"free_winner": free.candidate.label(),
            "budget_bytes": budget,
            "capped_winner": capped.candidate.label(),
            "n_rejected": capped.n_rejected, "ok": ok}


def parity_section():
    """Interpreter-scale bit-identity checks."""
    batch = 32
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, 32))
    y = jax.random.normal(jax.random.PRNGKey(2), (batch, 32))
    out = {}

    def run(remat=None, offload=None):
        prog, _ = build_pp_program("1f1b", 2, 4, batch, remat=remat,
                                   offload=offload)
        return Interpreter(prog).run({"x": x, "y": y}), prog

    base, _ = run()
    full, _ = run(remat=Remat("full"))
    none, _ = run(remat=Remat("none"))
    none_off, prog_off = run(remat=Remat("none"), offload=Offload(depth=1))

    def identical(a, b):
        if a.loss != b.loss:
            return False
        for bucket in a.grads:
            for u, v in zip(jax.tree_util.tree_leaves(a.grads[bucket]),
                            jax.tree_util.tree_leaves(b.grads[bucket])):
                if not np.array_equal(np.asarray(u), np.asarray(v)):
                    return False
        return True

    out["full_vs_default"] = identical(base, full)
    out["offload_vs_none"] = identical(none, none_off)
    out["none_peak_higher"] = none.max_peak() > full.max_peak()
    out["offload_peak_lower"] = none_off.max_peak() < none.max_peak()
    out["offload_pairs"] = prog_off.dag.meta["offload"]["pairs"]
    ok = all(v for k, v in out.items() if k != "offload_pairs")
    emit("remat_parity", 0.0,
         ";".join(f"{k}={v}" for k, v in out.items())
         + (";OK" if ok else ";FAIL"))
    out["ok"] = ok
    return out


def main() -> None:
    jax.config.update("jax_platform_name", "cpu")
    cfg = get_config(CONFIG)
    mesh = MeshSpec(pp=2, dp=1)
    summary = {
        "config": CONFIG, "tokens": TOKENS,
        "mesh": {"pp": mesh.pp, "dp": mesh.dp},
        "frontier": frontier(cfg, mesh),
        "budget": budget_section(cfg, mesh),
        "parity": parity_section(),
    }
    outdir = os.path.join(os.path.dirname(__file__), "results", "remat")
    os.makedirs(outdir, exist_ok=True)
    path = os.path.join(outdir, "remat_frontier.json")
    with open(path, "w") as f:
        json.dump(summary, f, indent=1, sort_keys=True)
    emit("remat_results_json", 0.0, path)


if __name__ == "__main__":
    main()
