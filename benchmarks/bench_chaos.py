"""Chaos soak accounting: ChaosReport JSON for scripted + seeded-random
fault schedules.

Each scenario trains the bench pipeline model on 8 faked host XLA
devices (Mesh(pp=4, dp=2)) through ``ft.elastic.ElasticSupervisor``
with a ``ChaosInjector`` driving a ``FaultSchedule``:

  - ``scripted`` — the canonical kill -> arrive/regrow -> straggle ->
    rebalance -> corrupt -> nan_spike storyline (the soak test's
    timeline, tests/test_chaos.py);
  - ``random-s<seed>`` — ``FaultSchedule.random`` draws, demonstrating
    that ANY seeded schedule document replays deterministically.

The recorded claims are structural, not wall-clock: every fault
recovers, steps lost per fault stay bounded by the checkpoint interval,
regrowth restores the full world at zero lost steps, and the whole run
serializes to one ``ChaosReport``.  Results land in
``benchmarks/results/chaos/chaos.json``.

Standalone:
  PYTHONPATH=src python -m benchmarks.bench_chaos [--smoke]
(fakes its own host devices before jax initializes; --smoke runs only
the scripted scenario)
"""
from __future__ import annotations

import json
import pathlib
import sys
import tempfile
import time

RESULTS = pathlib.Path(__file__).parent / "results" / "chaos"

PP, DP, MB, BATCH = 4, 2, 4, 16
N_STEPS, CKPT_EVERY = 20, 4
RANDOM_SEEDS = (1, 2)


def _scripted_schedule():
    from repro.ft import FaultEvent, FaultSchedule
    return FaultSchedule((
        FaultEvent(step=5, kind="kill", rank=3),
        FaultEvent(step=8, kind="arrive", devices=(3,)),
        # covers every post-regrowth step, so the watchdog's per-rank
        # ratios are exact and the rebalance proposal is stable
        FaultEvent(step=8, kind="straggle", rank=2, factor=3.0,
                   duration=N_STEPS - 8),
        FaultEvent(step=12, kind="corrupt", flips=8),
        FaultEvent(step=14, kind="nan_spike"),
    ), seed=23)


def _run_scenario(name: str, schedule) -> dict:
    from repro.checkpoint import CheckpointManager
    from repro.data import SyntheticVectorSource, VectorLoader
    from repro.ft import ChaosInjector, ElasticSupervisor
    from repro.runtime.executor import executor_factory

    from .common import D, build_pp_program

    prog, params = build_pp_program("1f1b", PP, MB, BATCH,
                                    dp_per_rank=DP, zero=3, d=D)

    factory = executor_factory("spmd")

    with tempfile.TemporaryDirectory() as td:
        loader = VectorLoader(SyntheticVectorSource(D, seed=11),
                              batch=BATCH)
        sup = ElasticSupervisor(
            prog, CheckpointManager(pathlib.Path(td), keep=10,
                                    async_save=False),
            loader, runner_factory=factory,
            checkpoint_every=CKPT_EVERY,
            injector=ChaosInjector(schedule),
            rebalance=True, rebalance_patience=2,
            rebalance_cooldown=CKPT_EVERY)
        t0 = time.time()
        sup.run(params, N_STEPS, log_every=0)
        report = sup.chaos_report(N_STEPS,
                                  wall_seconds=time.time() - t0)
    # the recorded structural claims: bounded steps-lost per fault, and
    # (scripted scenario) full-world regrowth at zero lost steps
    for rec in report.recoveries:
        n_stacked = 1 + (1 if rec["failed_rank"] < 0
                         and report.corrupt_detected else 0)
        assert rec["steps_lost"] <= n_stacked * CKPT_EVERY, rec
    for g in report.growths:
        assert g["steps_lost"] == 0, g
    return {"scenario": name, **report.to_dict()}


def main(smoke: bool = False) -> None:
    import jax

    n_dev = PP * DP
    if len(jax.devices()) < n_dev:
        print(f"# bench_chaos SKIPPED: needs {n_dev} XLA devices, "
              f"have {len(jax.devices())} (run standalone: PYTHONPATH=src "
              "python -m benchmarks.bench_chaos)")
        return

    from repro.ft import FaultSchedule

    from .common import emit

    # random draws exclude kill: its paired arrival brings a NEW device
    # index (>= world), which the 8-device host cannot back — the
    # scripted scenario covers the kill/arrive/regrow path
    scenarios = [("scripted", _scripted_schedule())]
    if not smoke:
        scenarios += [
            (f"random-s{seed}",
             FaultSchedule.random(seed, n_steps=N_STEPS, world=n_dev,
                                  kinds=("straggle", "corrupt",
                                         "nan_spike"),
                                  n_events=3))
            for seed in RANDOM_SEEDS]

    rows = []
    for name, schedule in scenarios:
        row = _run_scenario(name, schedule)
        rows.append(row)
        emit(f"chaos[{name}]", row["wall_seconds"] * 1e6,
             f"events={row['n_events']} "
             f"steps_lost={row['steps_lost_total']} "
             f"growths={len(row['growths'])} "
             f"rebalances={len(row['rebalances'])} "
             f"final_world={row['final_world']}")

    RESULTS.mkdir(parents=True, exist_ok=True)
    out = {"scenarios": rows,
           "mesh": {"pp": PP, "dp": DP}, "n_mb": MB, "batch": BATCH,
           "n_steps": N_STEPS, "checkpoint_every": CKPT_EVERY,
           "note": "chaos soak accounting on faked host devices; "
                   "wall-clock is machine-specific — the reproducible "
                   "claims are the fault counts, bounded steps-lost, "
                   "zero-loss regrowth and the serialized schedule "
                   "round-trip"}
    path = RESULTS / "chaos.json"
    path.write_text(json.dumps(out, indent=1))
    print(f"# results -> {path}")


if __name__ == "__main__":
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]
                           / "src"))
    from repro.launch.hostdevices import ensure_host_devices
    ensure_host_devices(PP * DP, verify=False)
    main(smoke="--smoke" in sys.argv[1:])
