"""Overlap engine on/off sweep (the PR-2 joint-scheduling claim).

For each composed strategy (ZeRO-3 x PP and DualPipeV x ZeRO-3) on real
ArchConfig proxies, compares three plans on the timeline simulator with
the analytic cost model:

  legacy — no overlap engine (pre-PR-2 plans: per-bucket collectives,
           simulator blind to the gather rate limiter; reported for
           reference only — its optimism is exactly what the engine's
           prefetch gates remove),
  off    — OverlapConfig.off(): honest just-in-time baseline (prefetch
           1, no fusion, no bubble-aware scheduling),
  on     — bucketed collectives + lookahead prefetch + bubble-aware
           scheduling.

Reported per config: simulated step time, max exposed comm over
devices, estimated peak bytes, and the on-vs-off speedup.  Only the
acceptance config (qwen3-1b x 1f1b) is required to fit BUDGET_BYTES
(the per-device budget the autotuner would enforce) — the qwen3-9b
rows exceed a single v5e's HBM even with overlap *off* (the model
needs a bigger mesh; they isolate the joint-scheduling effect, not
placement feasibility), and DualPipeV's deeper in-flight window
trades memory for its larger win.  The ``overlap_acceptance`` line
FAILs if the acceptance config stops being >=10% faster within
budget.  The interpreter parity section re-runs an interpreter-scale
MLP program and checks the overlapped plan's loss/grads are
bit-identical to the non-overlapped plan.

A JSON summary lands in benchmarks/results/overlap/ (layout documented
in benchmarks/README.md).

All programs compile through the Strategy front door
(``compile_training(strategy=...)``): the candidate's fragments with
the Overlap fragment swapped per column (absent = legacy, enabled=False
= off, enabled = on); per-row ``strategy`` labels land in the JSON.

  PYTHONPATH=src python -m benchmarks.bench_overlap
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from repro.configs import get_config
from repro.core import OverlapConfig
from repro.runtime import Interpreter
from repro.runtime.costmodel import CostModel
from repro.runtime.memory import timeline_peak_bytes
from repro.runtime.simulator import TimelineSimulator
from repro.tune import Candidate, MeshSpec
from repro.tune.proxy import build_candidate_program, make_chunk_cost

from .common import build_pp_program, emit

# per-device budget the autotuner would enforce (TPU v5e HBM)
BUDGET_BYTES = 16 << 30
TOKENS = 16384
# v5e-scale proxy buckets are GB-sized, so the win is prefetch (the
# 256 MiB fusion budget correctly refuses to merge bandwidth-bound
# giant gathers); the latency-bound section below is where bucketing
# itself pays
ON = OverlapConfig(bucket_bytes=256 << 20, prefetch=4)

SWEEP = [
    ("qwen3-1b", MeshSpec(pp=2, dp=2), "1f1b"),
    ("qwen3-1b", MeshSpec(pp=2, dp=2), "dualpipev"),
    ("qwen3-9b", MeshSpec(pp=2, dp=2), "1f1b"),
    ("qwen3-9b", MeshSpec(pp=2, dp=2), "dualpipev"),
]


def simulate(name: str, mesh: MeshSpec, kind: str, overlap):
    cfg = get_config(name)
    cand = Candidate(kind=kind, n_mb=2 * mesh.pp, zero=3)
    prog, sm = build_candidate_program(cfg, mesh, cand, TOKENS,
                                       overlap=overlap)
    cost = CostModel()
    res = TimelineSimulator(
        prog, cost,
        chunk_seconds_override=make_chunk_cost(sm, TOKENS, cand.n_mb,
                                               cost)).run()
    peaks = timeline_peak_bytes(prog, res.records)
    return {
        "strategy": prog.strategy.label(),
        "step_seconds": res.makespan,
        "exposed_comm_seconds": max(res.exposed_comm.values(), default=0.0),
        "peak_bytes": max(peaks.values()),
        "fused_gathers": prog.dag.meta.get("fused_gathers", 0),
        "fused_reduce_scatters":
            prog.dag.meta.get("fused_reduce_scatters", 0),
    }


def latency_bound_regime() -> dict:
    """DDP-style bucketing pays where collectives are small and
    dispatch latency dominates wire time: an interpreter-scale MLP with
    20us collective latency.  Reports off / prefetch-only / +fusion."""
    def makespan(ov):
        prog, _ = build_pp_program("1f1b", 2, 8, 32, dp_per_rank=2,
                                   zero=3, overlap=ov)
        cost = CostModel(comm_latency=20e-6)
        return TimelineSimulator(
            prog, cost, chunk_seconds_override=lambda n: 40e-6
        ).run().makespan

    t_off = makespan(OverlapConfig.off())
    t_pf = makespan(OverlapConfig(bucket_bytes=0, prefetch=4))
    t_fused = makespan(OverlapConfig(bucket_bytes=1 << 20, prefetch=4))
    return {"off_s": t_off, "prefetch_s": t_pf, "fused_s": t_fused,
            "speedup_prefetch": t_off / t_pf,
            "speedup_fused": t_off / t_fused,
            "fusion_on_top": t_pf / t_fused}


def parity_check(kind: str) -> bool:
    """Interpreter loss/grads of the overlapped plan must be
    bit-identical to the non-overlapped plan."""
    batch = 16
    runs = {}
    for tag, ov in (("off", OverlapConfig.off()), ("on", ON)):
        prog, _ = build_pp_program(kind, 2, 4, batch, dp_per_rank=2,
                                   zero=3, overlap=ov)
        x = jax.random.normal(jax.random.PRNGKey(1), (batch, 32))
        y = jax.random.normal(jax.random.PRNGKey(2), (batch, 32))
        runs[tag] = Interpreter(prog).run({"x": x, "y": y})
    a, b = runs["off"], runs["on"]
    if a.loss != b.loss or set(a.grads) != set(b.grads):
        return False
    return all(
        np.array_equal(u, v)
        for k in a.grads
        for u, v in zip(jax.tree_util.tree_leaves(a.grads[k]),
                        jax.tree_util.tree_leaves(b.grads[k])))


def main() -> None:
    out = {"budget_bytes": BUDGET_BYTES, "tokens": TOKENS,
           "overlap_on": ON.to_dict(), "sweep": []}
    for name, mesh, kind in SWEEP:
        row = {"config": name, "pp": mesh.pp, "dp": mesh.dp, "kind": kind}
        for tag, ov in (("legacy", None), ("off", OverlapConfig.off()),
                        ("on", ON)):
            row[tag] = simulate(name, mesh, kind, ov)
        speedup = row["off"]["step_seconds"] / row["on"]["step_seconds"]
        row["speedup_on_vs_off"] = speedup
        row["within_budget"] = (row["on"]["peak_bytes"] <= BUDGET_BYTES
                                and row["off"]["peak_bytes"]
                                <= BUDGET_BYTES)
        out["sweep"].append(row)
        label = f"overlap_{name}_pp{mesh.pp}dp{mesh.dp}_{kind}"
        emit(f"{label}_off", row["off"]["step_seconds"] * 1e6,
             f"peak_bytes={row['off']['peak_bytes']}")
        emit(f"{label}_on", row["on"]["step_seconds"] * 1e6,
             f"speedup={speedup:.3f}x "
             f"fused={row['on']['fused_gathers']}"
             f"+{row['on']['fused_reduce_scatters']} "
             f"peak_bytes={row['on']['peak_bytes']} "
             f"within_budget={row['within_budget']}")
    lat = latency_bound_regime()
    out["latency_bound"] = lat
    emit("overlap_latency_regime", lat["fused_s"] * 1e6,
         f"speedup_prefetch={lat['speedup_prefetch']:.3f}x "
         f"speedup_fused={lat['speedup_fused']:.3f}x "
         f"fusion_on_top={lat['fusion_on_top']:.3f}x")
    for kind in ("1f1b", "dualpipev"):
        ok = parity_check(kind)
        emit(f"overlap_parity_{kind}", 0.0,
             "bit_identical" if ok else "PARITY-MISMATCH")
        out[f"parity_{kind}"] = ok

    best = max(out["sweep"], key=lambda r: r["speedup_on_vs_off"])
    emit("overlap_best", 0.0,
         f"{best['config']}/{best['kind']} "
         f"speedup={best['speedup_on_vs_off']:.3f}x")
    # ISSUE-2 acceptance: >= 10% step-time reduction within the
    # autotuner budget on a composed ZeRO-3 x PP config
    acc = next(r for r in out["sweep"]
               if r["config"] == "qwen3-1b" and r["kind"] == "1f1b")
    ok = acc["speedup_on_vs_off"] >= 1.10 and acc["within_budget"]
    out["acceptance_ok"] = ok
    emit("overlap_acceptance", 0.0,
         ("ok" if ok else "FAIL")
         + f" qwen3-1b/1f1b speedup={acc['speedup_on_vs_off']:.3f}x"
         f" within_budget={acc['within_budget']}")
    results_dir = os.path.join(os.path.dirname(__file__), "results",
                               "overlap")
    os.makedirs(results_dir, exist_ok=True)
    path = os.path.join(results_dir, "overlap_sweep.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"# wrote {path}")


if __name__ == "__main__":
    jax.config.update("jax_platform_name", "cpu")
    main()
