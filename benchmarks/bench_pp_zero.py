"""Paper Table 1 + Fig. 8: PP x ZeRO support matrix and peak memory.

Piper reshards ZeRO-2/3 buffers between microbatches (reduce per
backward, free full-param gathers after last consumer); the
'no-reshard' variant emulates the TorchTitan behaviour the paper
measured (full parameter/gradient buffers stay live across
microbatches), which defeats the sharding.  We sweep the global batch
and report peak bytes/device and the largest batch fitting a fixed
budget — the paper saw 8x (ZeRO-2) / 3.3x (ZeRO-3) larger batches for
Piper."""
from __future__ import annotations

import jax

from repro.runtime import Interpreter

from .common import build_pp_program, emit

import jax.numpy as jnp

# width 160: parameter state dominates small-batch activations, as in
# the paper's Qwen3-9B setting (at D=32 activations dominate and the
# ZeRO-2 window savings vanish)
R, N_MB, D = 4, 8, 160


def peak_for(zero: int, batch: int, hold: bool) -> int:
    prog, params = build_pp_program("1f1b", R, N_MB, batch, dp_per_rank=2,
                                    zero=zero, d=D)
    interp = Interpreter(prog)
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, D))
    y = jax.random.normal(jax.random.PRNGKey(2), (batch, D))
    if hold:
        # emulate no-resharding (TorchTitan behaviour in the paper):
        # full param/grad buffers are never released between microbatches
        from repro.runtime.memory import DeviceLedger
        interp.gather_limit = 10 ** 9
        orig = DeviceLedger.free

        def hold_free(self, key):
            if isinstance(key, tuple) and key[0] in ("fullparam",
                                                     "fullgrad"):
                return
            orig(self, key)
        DeviceLedger.free = hold_free
        try:
            res = interp.run({"x": x, "y": y})
        finally:
            DeviceLedger.free = orig
    else:
        res = interp.run({"x": x, "y": y})
    return res.max_peak()


def main() -> None:
    # Table 1: support matrix — Piper compiles and runs all PP x ZeRO
    for zero in (1, 2, 3):
        try:
            peak_for(zero, 32, hold=False)
            ok = "supported"
        except Exception as e:  # pragma: no cover
            ok = f"FAILED:{type(e).__name__}"
        emit(f"table1_pp_zero{zero}", 0.0, ok)

    # Fig 8: peak memory vs batch, proper resharding vs no-reshard.
    # Budget per ZeRO level = the smallest no-reshard peak (the paper's
    # smallest-batch-that-TorchTitan-fits framing).
    for zero in (2, 3):
        fits = {"piper": 0, "noreshard": 0}
        budget = None
        for batch in (32, 64, 128, 256, 512, 1024, 2048):
            p_proper = peak_for(zero, batch, hold=False)
            p_hold = peak_for(zero, batch, hold=True)
            emit(f"fig8_zero{zero}_batch{batch}_piper", 0.0,
                 f"peak_bytes={p_proper}")
            emit(f"fig8_zero{zero}_batch{batch}_noreshard", 0.0,
                 f"peak_bytes={p_hold}")
            if budget is None:
                budget = p_hold  # smallest no-reshard peak
            if p_proper <= budget:
                fits["piper"] = batch
            if p_hold <= budget:
                fits["noreshard"] = batch
        ratio = (fits["piper"] / fits["noreshard"]
                 if fits["noreshard"] else float("inf"))
        emit(f"fig8_zero{zero}_max_batch_ratio", 0.0,
             f"piper={fits['piper']};noreshard={fits['noreshard']};"
             f"ratio={ratio:.1f}x;paper=8x(z2)/3.3x(z3)")


if __name__ == "__main__":
    main()
