"""Paper Fig. 9: PP x DP scalability.  Global batch scales linearly with
PP x DP; the simulator's throughput should track the linear-scaling
line (the paper 'shows that Piper scales reasonably')."""
from __future__ import annotations

from repro.runtime.costmodel import CostModel
from repro.runtime.simulator import TimelineSimulator

from .common import build_pp_program, emit

T_CHUNK = 5e-3


def const_cost(node):
    if node.dims.get("PASS") in ("Bi", "Bw"):
        return T_CHUNK / 2
    return T_CHUNK


def main() -> None:
    # weak scaling: the model itself grows with the PP degree (2*pp
    # stages), so the linear reference is dp-scaling within each PP
    # degree (the paper's Fig 9 scales global batch with PP x DP)
    for pp in (2, 4, 8):
        base_tput = None
        for dp in (1, 2, 4):
            n_mb = 4 * pp  # keep the bubble fraction ~constant
            batch = n_mb * dp * 2
            prog, _ = build_pp_program("1f1b", pp, n_mb, batch,
                                       dp_per_rank=dp)
            res = TimelineSimulator(
                prog, CostModel(ici_bw=1e9, comm_latency=0.0),
                chunk_seconds_override=const_cost).run()
            tput = batch / res.makespan
            if base_tput is None:
                base_tput = tput / dp
            linear = base_tput * dp
            emit(f"fig9_pp{pp}_dp{dp}", res.makespan * 1e6,
                 f"tokens_per_s={tput:.0f};linear={linear:.0f};"
                 f"dp_scaling_efficiency={tput/linear:.2f}")


if __name__ == "__main__":
    main()
