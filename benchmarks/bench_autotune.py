"""Autotuner accuracy + win-rate: predicted vs measured step time.

For three configs from ``src/repro/configs`` the tuner's *predicted*
step time (analytic roofline chunk costs, the search's scoring path) is
compared against a *measured* step time: the same candidate re-simulated
with chunk costs taken from XLA's own ``cost_analysis`` of the lowered
proxy exec functions (the repo's ground-truth cost source on CPU; on
real hardware this column is replaced by wall-clock).  Also reports the
winner's predicted speedup over the default 1F1B baseline — the
autotuner's reason to exist.

    PYTHONPATH=src python -m benchmarks.bench_autotune
"""
from __future__ import annotations

import time

from repro import tune
from repro.configs import get_config

from .common import emit

CONFIGS = ("qwen3-1b", "qwen3-9b", "deepseek-moe-16b")
TOKENS = 16384
MESH = tune.MeshSpec(pp=2, dp=2)


def main() -> None:
    for name in CONFIGS:
        cfg = get_config(name)
        t0 = time.time()
        plan = tune.search(cfg, MESH, budget=None, tokens=TOKENS,
                           use_cache=False)
        search_s = time.time() - t0
        # measured: XLA cost_analysis-backed simulation of winner+baseline
        meas_win = tune.score_candidate(
            cfg, MESH, plan.candidate, tokens=TOKENS, use_xla_cost=True)
        meas_base = tune.score_candidate(
            cfg, MESH, plan.baseline.candidate, tokens=TOKENS,
            use_xla_cost=True)
        pred = plan.predicted_step_seconds
        meas = meas_win.step_seconds
        # the winner as a canonical Strategy document (what the plan
        # cache stores and launch/train.py --strategy replays)
        emit(f"autotune_{name}_strategy", 0.0,
             plan.strategy().label().replace(",", ";"))
        emit(f"autotune_{name}_winner_pred", pred * 1e6,
             f"cand={plan.candidate.label()};peak_gib="
             f"{plan.predicted_peak_bytes/2**30:.2f};"
             f"search_s={search_s:.1f};n={plan.n_evaluated}")
        emit(f"autotune_{name}_winner_meas", meas * 1e6,
             f"pred_over_meas={pred/meas:.3f}x")
        emit(f"autotune_{name}_baseline_meas",
             meas_base.step_seconds * 1e6,
             f"pred={plan.baseline.step_seconds*1e6:.1f};"
             f"win_meas_speedup={meas_base.step_seconds/meas:.3f}x;"
             f"win_pred_speedup={plan.speedup_vs_baseline():.3f}x")


if __name__ == "__main__":
    main()
