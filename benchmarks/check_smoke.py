"""Diff fresh smoke headlines against the committed baseline (CI job
``bench-smoke``): a simulated-perf regression beyond the tolerance
fails the PR instead of rotting silently.

  python benchmarks/check_smoke.py FRESH.json [BASELINE.json]

Exit 0 when every headline ratio is within the baseline's tolerance
(default ±15%, relative); exit 1 with a per-headline report otherwise.
Headline sets must match exactly — adding a headline means refreshing
the committed baseline in the same PR (see benchmarks/README.md).
"""
from __future__ import annotations

import json
import pathlib
import sys

DEFAULT_BASELINE = pathlib.Path(__file__).parent / "results" / "smoke" / \
    "headline.json"


def check(fresh_path, baseline_path=DEFAULT_BASELINE) -> int:
    fresh = json.loads(pathlib.Path(fresh_path).read_text())
    base = json.loads(pathlib.Path(baseline_path).read_text())
    tol = float(base.get("tolerance", 0.15))
    fh, bh = fresh["headlines"], base["headlines"]
    failures = []
    if set(fh) != set(bh):
        failures.append(f"headline sets differ: fresh={sorted(fh)} "
                        f"baseline={sorted(bh)} — refresh the baseline "
                        "(python -m benchmarks.run --smoke) and commit it")
    for k in sorted(set(fh) & set(bh)):
        f, b = float(fh[k]), float(bh[k])
        rel = abs(f - b) / max(abs(b), 1e-12)
        status = "ok" if rel <= tol else "DRIFT"
        print(f"{k}: baseline={b:.4f} fresh={f:.4f} "
              f"rel={rel*100:.1f}% [{status}]")
        if rel > tol:
            failures.append(
                f"{k} drifted {rel*100:.1f}% (> {tol*100:.0f}%): "
                f"baseline {b:.4f} -> fresh {f:.4f}")
    if failures:
        print("\nbench-smoke FAILED:")
        for f in failures:
            print(f"  - {f}")
        print("(intentional perf change? refresh the baseline: "
              "PYTHONPATH=src python -m benchmarks.run --smoke, "
              "commit results/smoke/headline.json)")
        return 1
    print("bench-smoke OK")
    return 0


if __name__ == "__main__":
    if len(sys.argv) < 2:
        print(__doc__)
        sys.exit(2)
    sys.exit(check(sys.argv[1], *(sys.argv[2:3] or [DEFAULT_BASELINE])))
