"""Roofline analysis over the dry-run results (task spec: ROOFLINE
ANALYSIS).  Reads benchmarks/results/dryrun/*.json and renders:

  - the three terms t_compute / t_memory / t_collective per cell,
  - the dominant bottleneck,
  - MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) vs HLO FLOPs,
  - per-device memory.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--csv] [--mesh pod1]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

RESULTS = pathlib.Path(__file__).parent / "results" / "dryrun"

ARCH_ORDER = ["minicpm-2b", "qwen1.5-0.5b", "qwen2.5-32b", "granite-20b",
              "dbrx-132b", "deepseek-moe-16b", "falcon-mamba-7b",
              "whisper-large-v3", "qwen2-vl-7b", "zamba2-2.7b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str = "pod1", tag: str = "") -> list[dict]:
    rows = []
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            name = f"{arch}__{shape}__{mesh}"
            if tag:
                name += f"__{tag}"
            p = RESULTS / f"{name}.json"
            if p.exists():
                rows.append(json.loads(p.read_text()))
            else:
                rows.append({"arch": arch, "shape": shape, "mesh": mesh,
                             "status": "missing"})
    return rows


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def render(rows, csv: bool = False) -> str:
    out = []
    if csv:
        out.append("arch,shape,mesh,status,t_compute_s,t_memory_s,"
                   "t_collective_s,dominant,mem_gb,flops,bytes,"
                   "coll_bytes,useful_ratio")
    else:
        hdr = (f"{'arch':<18}{'shape':<13}{'status':<10}{'t_comp':>9}"
               f"{'t_mem':>9}{'t_coll':>9} {'dominant':<11}"
               f"{'mem/dev':>8}{'useful':>8}")
        out.append(hdr)
        out.append("-" * len(hdr))
    for r in rows:
        rf = r.get("roofline", {})
        mem = r.get("memory", {}).get("per_device_total_gb")
        if csv:
            coll = r.get("collective", {}).get("total_bytes", "")
            out.append(
                f"{r['arch']},{r['shape']},{r.get('mesh')},{r['status']},"
                f"{rf.get('t_compute_s','')},{rf.get('t_memory_s','')},"
                f"{rf.get('t_collective_s','')},{rf.get('dominant','')},"
                f"{mem or ''},{r.get('flops','')},"
                f"{r.get('bytes_accessed','')},{coll},"
                f"{r.get('useful_flops_ratio','')}")
        else:
            if r["status"] != "ok":
                out.append(f"{r['arch']:<18}{r['shape']:<13}"
                           f"{r['status']:<10}")
                continue
            out.append(
                f"{r['arch']:<18}{r['shape']:<13}{r['status']:<10}"
                f"{fmt_s(rf.get('t_compute_s')):>9}"
                f"{fmt_s(rf.get('t_memory_s')):>9}"
                f"{fmt_s(rf.get('t_collective_s')):>9} "
                f"{rf.get('dominant',''):<11}"
                f"{(f'{mem:.1f}GB' if mem is not None else '-'):>8}"
                f"{(str(r.get('useful_flops_ratio','-'))):>8}")
    return "\n".join(out)


def roofline_fraction(r) -> float:
    """useful model-flops time / max(three terms) — the score we climb."""
    rf = r.get("roofline", {})
    mf = r.get("model_flops_per_device")
    if not mf or not rf:
        return float("nan")
    t_model = mf / 197e12
    t_actual = max(rf["t_compute_s"], rf["t_memory_s"],
                   rf["t_collective_s"])
    return t_model / t_actual if t_actual else float("nan")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod1")
    ap.add_argument("--csv", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args(argv)
    rows = load(args.mesh, args.tag)
    print(render(rows, args.csv))
    fracs = [(f"{r['arch']}/{r['shape']}", roofline_fraction(r))
             for r in rows if r["status"] == "ok"
             and r.get("model_flops_per_device")]
    fracs = [x for x in fracs if x[1] == x[1]]
    if fracs:
        print("\nroofline fraction (model-flops time / dominant term):")
        for name, f in sorted(fracs, key=lambda x: x[1]):
            print(f"  {name:<32} {f:6.3f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
