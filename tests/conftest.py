"""Suite-wide defaults.

``REPRO_CHECK_PASSES=1`` re-validates the DAG at every compiler pass
boundary (``passes.run_all``) so a pass that corrupts the graph fails
at its own boundary instead of three passes later.  On by default for
the whole suite; export ``REPRO_CHECK_PASSES=0`` to opt out.
"""
import os

os.environ.setdefault("REPRO_CHECK_PASSES", "1")
