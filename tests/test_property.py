"""Property-based tests (hypothesis) for system invariants:

- any random combination of (DP degree, microbatches, ZeRO level, PP
  split) compiles to a deadlock-free plan whose numerics equal the
  plain-JAX oracle;
- filter algebra: '*' / '-' / omission semantics;
- schedule generators: every generated table respects the pipeline data
  dependencies for random (kind, R, M);
- elastic recovery: any surviving-rank subset that admits a shrunk mesh
  yields a plan that passes validate_comm_order; the ZeRO checkpoint
  shard remap round-trips bit-exactly across random degree changes;
- chaos/rebalance (PR 7): rebalance_microbatches conserves the
  microbatch count, respects the uniform guard, and is a no-op for a
  uniform fleet; a shrink-then-regrow ZeRO reshard chain is bit-exact;
  FaultSchedule JSON round-trips any random schedule byte-stably.
"""
import jax
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: E402

from helpers import (assert_grads_close, inputs_spec, make_batch,
                     make_mlp_forward, make_mlp_params, mlp_oracle,
                     raw_strategy)
from repro.core import F, Place, Replicate, Split, compile_training
from repro.core.dag import Node
from repro.core.schedules import PipeOp, build_rank_sequences
from repro.runtime import Interpreter

jax.config.update("jax_platform_name", "cpu")


class TestFilterAlgebra:
    def mk(self, **dims):
        return Node(id=0, kind="chunk", dims=dims)

    @given(idx=st.integers(0, 5), other=st.integers(0, 5))
    @settings(max_examples=25, deadline=None)
    def test_exact_match(self, idx, other):
        n = self.mk(pp=idx)
        assert F(pp=idx).matches(n)
        assert F(pp=other).matches(n) == (idx == other)

    def test_star_and_minus(self):
        tagged = self.mk(pp=1, ep=0)
        untagged = self.mk(pp=1)
        assert F(ep="*").matches(tagged)
        assert not F(ep="*").matches(untagged)
        assert F(ep="-").matches(untagged)
        assert not F(ep="-").matches(tagged)
        # omission matches both
        assert F(pp=1).matches(tagged) and F(pp=1).matches(untagged)


class TestScheduleGeneratorProperties:
    @given(kind=st.sampled_from(["gpipe", "1f1b", "interleaved_1f1b",
                                 "dualpipev"]),
           R=st.sampled_from([2, 4]),
           M=st.sampled_from([4, 8, 12]))
    @settings(max_examples=20, deadline=None)
    def test_dependency_respecting(self, kind, R, M):
        S = {"gpipe": R, "1f1b": R}.get(kind, 2 * R)
        seqs = build_rank_sequences(kind, R, M, S)
        split = kind == "dualpipev"
        b_tag = "Bi" if split else "B"
        # replay as synchronous rounds and check each op's deps done
        done = set()
        queues = [list(s) for s in seqs]
        idx = [0] * R
        while any(i < len(q) for i, q in zip(idx, queues)):
            progressed = False
            fired = []
            for r in range(R):
                if idx[r] >= len(queues[r]):
                    continue
                ops = queues[r][idx[r]]
                ops = ops if isinstance(ops, tuple) else (ops,)

                def ready(op):
                    if op.pas == "F":
                        return op.stage == 0 or \
                            PipeOp(op.stage - 1, op.mb, "F") in done
                    if op.pas == "Bw":
                        return PipeOp(op.stage, op.mb, b_tag) in done
                    if PipeOp(op.stage, op.mb, "F") not in done:
                        return False
                    return op.stage == S - 1 or \
                        PipeOp(op.stage + 1, op.mb, b_tag) in done
                if all(ready(op) for op in ops):
                    fired.extend(ops)
                    idx[r] += 1
                    progressed = True
            assert progressed, f"stalled schedule {kind} R={R} M={M}"
            done.update(fired)


class TestRandomStrategyNumerics:
    @given(dp=st.sampled_from([1, 2]),
           n_mb=st.sampled_from([1, 2, 4]),
           zero=st.sampled_from([1, 2, 3]),
           pp=st.booleans())
    @settings(max_examples=10, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    def test_composed_strategy_matches_oracle(self, dp, n_mb, zero, pp):
        """The paper's safety guarantee, property-tested: any composition
        of Place/Replicate/Split preserves loss and grads."""
        S, batch = 2, 16
        params = make_mlp_params(jax.random.PRNGKey(0), S)
        fwd = make_mlp_forward(S)
        sched = []
        if pp:
            g0 = list(range(0, dp))
            g1 = list(range(dp, 2 * dp))
            sched += [Place(F(pp=0), devices=g0, stream="pp"),
                      Place(F(pp=1), devices=g1, stream="pp")]
            groups = [g0, g1]
        else:
            groups = [list(range(dp))] * S
        if dp > 1 or zero > 1:
            for s_i in range(S):
                sched.append(Replicate(
                    F(pp=s_i), devices=groups[s_i],
                    reduce_stream="dp", gather_stream="ag",
                    shard_grads=zero >= 2, shard_params=zero >= 3))
        if n_mb > 1:
            sched.append(Split(F(), dim="MB", num_microbatches=n_mb))
        prog = compile_training(fwd, params, inputs_spec(batch),
                                strategy=raw_strategy(sched))
        b = make_batch(batch)
        res = Interpreter(prog).run(b)
        l, g = mlp_oracle(params, b["x"], b["y"], S)
        assert res.loss == pytest.approx(l, abs=1e-6)
        assert_grads_close(res.grads, g)


class TestElasticProperties:
    @given(pp=st.sampled_from([2, 4]),
           dp=st.sampled_from([1, 2]),
           zero=st.sampled_from([0, 1, 2, 3]),
           sched=st.sampled_from(["gpipe", "1f1b"]),
           n_lost=st.integers(1, 6),
           data=st.data())
    @settings(max_examples=12, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    def test_any_valid_survivor_subset_compiles_clean(
            self, pp, dp, zero, sched, n_lost, data):
        """Elastic safety: for ANY random subset of surviving ranks the
        planner either refuses (ElasticError) or produces a strategy
        whose recompiled plan passes validate_comm_order — a shrunk
        world can never be handed a plan that would deadlock."""
        from repro.core.scheduler import validate_comm_order
        from repro.core.strategy import Mesh, Pipeline, Strategy, ZeRO
        from repro.ft import ElasticError, shrink_for_survivors

        world = pp * dp
        n_lost = min(n_lost, world - 1)
        lost = data.draw(st.sets(st.integers(0, world - 1),
                                 min_size=n_lost, max_size=n_lost))
        survivors = sorted(set(range(world)) - lost)
        mesh = Mesh(pp=pp, dp=dp)
        strat = Strategy(mesh, Pipeline(sched, n_mb=2)
                         | ZeRO(stage=zero)).validate()
        try:
            plan = shrink_for_survivors(strat, survivors)
        except ElasticError:
            return  # refusing is always safe
        assert plan.new_mesh.n_devices <= len(survivors)
        S_mlp = 2 * pp  # stage count pinned under the OLD mesh
        params = make_mlp_params(jax.random.PRNGKey(0), S_mlp)
        prog = compile_training(make_mlp_forward(S_mlp), params,
                                inputs_spec(8), strategy=strat)
        shrunk = prog.recompile(strategy=plan.strategy)
        validate_comm_order(shrunk.dag, shrunk.plan)   # raises on hang
        assert len(shrunk.plan.devices) == plan.new_mesh.n_devices

    @given(shape=st.sampled_from([(1,), (3,), (7, 5), (2, 3, 4), (16,),
                                  (1, 1)]),
           dtype=st.sampled_from(["float32", "float64", "int32",
                                  "uint8"]),
           old=st.integers(1, 8),
           new=st.integers(1, 8))
    @settings(max_examples=40, deadline=None)
    def test_zero_shard_remap_roundtrips_bitexact(self, shape, dtype,
                                                  old, new):
        """Resharding a checkpoint across ZeRO degrees is a placement
        change, never a numerics change: remap old->new->reassemble must
        reproduce the original leaf bit for bit (including shapes the
        degree does not divide, where the codec pads)."""
        from repro.checkpoint import (remap_shards, shard_leaf,
                                      unshard_leaf)
        rng = np.random.default_rng(hash((shape, dtype, old, new))
                                    & 0xFFFF)
        if np.issubdtype(np.dtype(dtype), np.integer):
            arr = rng.integers(0, 100, size=shape).astype(dtype)
        else:
            arr = rng.standard_normal(shape).astype(dtype)
        remapped = remap_shards(shard_leaf(arr, old), new, arr.size)
        assert len(remapped) == new
        back = unshard_leaf(remapped, arr.shape, arr.dtype)
        assert back.tobytes() == arr.tobytes()
        assert back.dtype == arr.dtype and back.shape == arr.shape

    @given(old=st.integers(1, 6), new=st.integers(1, 6))
    @settings(max_examples=15, deadline=None)
    def test_reshard_tree_roundtrips_bitexact(self, old, new):
        from repro.checkpoint import reshard_tree
        tree = make_mlp_params(jax.random.PRNGKey(7), 3)
        out = reshard_tree(tree, old, new)   # verify=True self-checks
        la = jax.tree_util.tree_leaves(tree)
        lb = jax.tree_util.tree_leaves(out)
        assert all(np.asarray(a).tobytes() == np.asarray(b).tobytes()
                   for a, b in zip(la, lb))

    @given(shape=st.sampled_from([(5,), (16,), (3, 7), (2, 3, 4)]),
           dtype=st.sampled_from(["float32", "float64", "int32"]),
           down=st.integers(1, 8),
           up=st.integers(1, 8))
    @settings(max_examples=30, deadline=None)
    def test_shrink_then_grow_reshard_roundtrips(self, shape, dtype,
                                                 down, up):
        """The PR 7 regrowth contract: mapping a checkpoint DOWN in ZeRO
        degree at shrink time and back UP at regrowth time (through any
        intermediate degree) reproduces every leaf bit for bit."""
        from repro.checkpoint import reshard_tree
        rng = np.random.default_rng(hash((shape, dtype, down, up))
                                    & 0xFFFF)
        if np.issubdtype(np.dtype(dtype), np.integer):
            leaf = rng.integers(-50, 50, size=shape).astype(dtype)
        else:
            leaf = rng.standard_normal(shape).astype(dtype)
        tree = {"stage0": {"w": leaf, "b": leaf.ravel()[:1]}}
        out = reshard_tree(reshard_tree(tree, up, down), down, up)
        la = jax.tree_util.tree_leaves(tree)
        lb = jax.tree_util.tree_leaves(out)
        assert all(np.asarray(a).tobytes() == np.asarray(b).tobytes()
                   for a, b in zip(la, lb))


class TestRebalanceProperties:
    """Invariants of tune.rebalance.rebalance_microbatches — the
    proposal the chaos supervisor consumes as a mid-run recompile."""

    @staticmethod
    def _slowdowns(data, n_ranks, spread):
        return {r: data.draw(st.floats(1.0, spread))
                for r in range(n_ranks)}

    @given(n_mb=st.integers(0, 32),
           n_ranks=st.integers(1, 8),
           data=st.data())
    @settings(max_examples=40, deadline=None)
    def test_microbatch_count_conserved(self, n_mb, n_ranks, data):
        """The split re-assigns microbatches, it never changes their
        number — the invariant Pipeline.validate also enforces."""
        from repro.tune.rebalance import rebalance_microbatches
        slow = self._slowdowns(data, n_ranks, 8.0)
        split = rebalance_microbatches(n_mb, slow)
        assert sum(split.values()) == n_mb
        assert set(split) == set(slow)
        assert all(c >= 0 for c in split.values())

    @given(n_mb=st.integers(1, 32),
           n_ranks=st.integers(1, 8),
           data=st.data())
    @settings(max_examples=40, deadline=None)
    def test_uniform_guard(self, n_mb, n_ranks, data):
        """Fleets whose spread stays within the guard threshold get an
        exactly uniform split — EMA noise must never skew assignment."""
        from repro.tune.rebalance import rebalance_microbatches
        slow = self._slowdowns(data, n_ranks, 1.25)
        split = rebalance_microbatches(n_mb, slow, threshold=1.25)
        assert max(split.values()) - min(split.values()) <= \
            (0 if n_mb % n_ranks == 0 else 1)

    @given(n_mb=st.integers(1, 32), n_ranks=st.integers(1, 8),
           pace=st.floats(0.5, 4.0))
    @settings(max_examples=40, deadline=None)
    def test_idempotent_on_uniform_fleet(self, n_mb, n_ranks, pace):
        """All ranks at the same pace (whatever it is) always yields the
        same canonical uniform split — so consuming a proposal on a
        healthy fleet is a fixed point, never a recompile loop."""
        from repro.tune.rebalance import rebalance_microbatches
        slow = {r: pace for r in range(n_ranks)}
        a = rebalance_microbatches(n_mb, slow)
        b = rebalance_microbatches(n_mb, slow)
        assert a == b
        assert max(a.values()) - min(a.values()) <= \
            (0 if n_mb % n_ranks == 0 else 1)


class TestFaultScheduleProperties:
    @given(seed=st.integers(0, 2**31 - 1),
           n_steps=st.integers(2, 50),
           world=st.integers(1, 16),
           n_events=st.integers(1, 8))
    @settings(max_examples=40, deadline=None)
    def test_random_schedule_roundtrips_byte_stable(self, seed, n_steps,
                                                    world, n_events):
        """Any random FaultSchedule serializes to canonical JSON that
        parses back to an equal schedule and re-serializes to the SAME
        bytes — the Strategy-document contract, for faults."""
        from repro.ft import FaultSchedule
        sched = FaultSchedule.random(seed, n_steps=n_steps, world=world,
                                     n_events=n_events)
        doc = sched.to_json()
        again = FaultSchedule.from_json(doc)
        assert again == sched
        assert again.to_json() == doc
