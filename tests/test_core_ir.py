"""Core IR tests: tracing, autodiff, directives, scheduling, interpreter
numerics vs a plain-JAX oracle.  This is the paper's safety guarantee:
every directive-transformed DAG computes the same loss/grads as the
untransformed model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import raw_strategy
from repro.core import (F, Order, Place, Replicate, Shard, Split,
                        compile_training)
from repro.runtime import Interpreter

jax.config.update("jax_platform_name", "cpu")

D = 16


def make_params(key, n_stage=2):
    ks = jax.random.split(key, 2 * n_stage)
    params = {}
    for i in range(n_stage):
        params[f"stage{i}"] = {
            "w1": jax.random.normal(ks[2 * i], (D, D)) * 0.1,
            "w2": jax.random.normal(ks[2 * i + 1], (D, D)) * 0.1,
        }
    return params


def stage_fn(p, x):
    h = jnp.tanh(x @ p["w1"])
    return jnp.tanh(h @ p["w2"])


def loss_fn(p, x, y):
    return jnp.mean((stage_fn(p, x) - y) ** 2)


def two_stage_forward(rec, tvs):
    """Annotated model: two PP stages, second computes the loss."""
    with rec.annotate("pp"):
        h = rec.region(stage_fn, "stage0", name="stage0")(tvs["x"])
    with rec.annotate("pp"):
        loss = rec.region(loss_fn, "stage1", name="stage1")(h, tvs["y"])
    return loss


def oracle(params, x, y):
    def full(params):
        h = stage_fn(params["stage0"], x)
        return loss_fn(params["stage1"], h, y)
    l, g = jax.value_and_grad(full)(params)
    return float(l), g


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    params = make_params(key)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, D))
    y = jax.random.normal(jax.random.PRNGKey(2), (8, D))
    return params, x, y


INPUTS = {"x": ((8, D), "float32"), "y": ((8, D), "float32")}


def assert_grads_close(got, want, atol=1e-5):
    for bucket in want:
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_allclose(a, b, atol=atol,
                                                    rtol=1e-5),
            got[bucket], want[bucket])


class TestTraceAndCompile:
    def test_trace_builds_chunks(self, setup):
        params, x, y = setup
        prog = compile_training(two_stage_forward, params, INPUTS)
        chunks = prog.dag.chunks()
        assert len(chunks) == 4  # 2 fwd + 2 bwd
        dims = sorted((c.dims.get("pp"), c.dims["PASS"]) for c in chunks)
        assert dims == [(0, "B"), (0, "F"), (1, "B"), (1, "F")]

    def test_single_device_numerics(self, setup):
        params, x, y = setup
        prog = compile_training(two_stage_forward, params, INPUTS)
        res = Interpreter(prog).run({"x": x, "y": y})
        l, g = oracle(params, x, y)
        assert res.loss == pytest.approx(l, abs=1e-6)
        assert_grads_close(res.grads, g)


class TestPlace:
    def test_pp_two_devices(self, setup):
        params, x, y = setup
        sched = [Place(F(pp=0), devices=[0], stream="pp"),
                 Place(F(pp=1), devices=[1], stream="pp")]
        prog = compile_training(two_stage_forward, params, INPUTS,
                                strategy=raw_strategy(sched))
        # p2p inserted: activation fwd (0->1) and cotangent bwd (1->0)
        p2ps = [n for n in prog.dag.comms() if n.op == "p2p"]
        assert len(p2ps) == 2
        res = Interpreter(prog).run({"x": x, "y": y})
        l, g = oracle(params, x, y)
        assert res.loss == pytest.approx(l, abs=1e-6)
        assert_grads_close(res.grads, g)


class TestReplicate:
    def test_dp_numerics(self, setup):
        params, x, y = setup
        sched = [Replicate(F(), devices=[0, 1])]
        prog = compile_training(two_stage_forward, params, INPUTS,
                                strategy=raw_strategy(sched))
        ars = [n for n in prog.dag.comms() if n.op == "all_reduce"]
        assert len(ars) == 2  # one per bucket
        res = Interpreter(prog).run({"x": x, "y": y})
        l, g = oracle(params, x, y)
        assert res.loss == pytest.approx(l, abs=1e-6)
        assert_grads_close(res.grads, g)

    def test_zero3_allgathers(self, setup):
        params, x, y = setup
        sched = [Replicate(F(), devices=[0, 1], shard_params=True,
                           shard_grads=True)]
        prog = compile_training(two_stage_forward, params, INPUTS,
                                strategy=raw_strategy(sched))
        ags = [n for n in prog.dag.comms() if n.op == "all_gather"]
        assert len(ags) == 4  # one per chunk (2 fwd + 2 bwd), none elided
        rss = [n for n in prog.dag.comms() if n.op == "reduce_scatter"]
        assert len(rss) == 2
        res = Interpreter(prog).run({"x": x, "y": y})
        l, g = oracle(params, x, y)
        assert res.loss == pytest.approx(l, abs=1e-6)
        assert_grads_close(res.grads, g)

    def test_zero_memory_ladder(self):
        """ZeRO-1 -> ZeRO-2 -> ZeRO-3 should monotonically cut peak mem.
        Needs enough buckets that per-bucket temp buffers (full-grad
        window, 2 in-flight param gathers) are small relative to the total
        sharded state."""
        n = 8
        params = make_params(jax.random.PRNGKey(0), n_stage=n)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, D))
        y = jax.random.normal(jax.random.PRNGKey(2), (8, D))

        def fwd(rec, tvs):
            h = tvs["x"]
            for i in range(n - 1):
                with rec.annotate("pp"):
                    h = rec.region(stage_fn, f"stage{i}", name=f"s{i}")(h)
            with rec.annotate("pp"):
                loss = rec.region(loss_fn, f"stage{n-1}",
                                  name="head")(h, tvs["y"])
            return loss

        peaks = {}
        for name, kw in [
                ("zero1", {}),
                ("zero2", {"shard_grads": True}),
                ("zero3", {"shard_grads": True, "shard_params": True})]:
            sched = [Replicate(F(), devices=[0, 1], reduce_stream="dp",
                               gather_stream="ag", **kw)]
            prog = compile_training(fwd, params, INPUTS,
                                    strategy=raw_strategy(sched))
            res = Interpreter(prog).run({"x": x, "y": y})
            peaks[name] = res.max_peak()
        assert peaks["zero2"] < peaks["zero1"]
        assert peaks["zero3"] < peaks["zero2"]


class TestSplit:
    def test_microbatch_numerics(self, setup):
        params, x, y = setup
        sched = [Split(F(), dim="MB", num_microbatches=2)]
        prog = compile_training(two_stage_forward, params, INPUTS,
                                strategy=raw_strategy(sched))
        assert len(prog.dag.chunks()) == 8
        res = Interpreter(prog).run({"x": x, "y": y})
        l, g = oracle(params, x, y)
        assert res.loss == pytest.approx(l, abs=1e-6)
        assert_grads_close(res.grads, g)

    def test_split_then_dp(self, setup):
        params, x, y = setup
        sched = [Replicate(F(), devices=[0, 1]),
                 Split(F(), dim="MB", num_microbatches=2)]
        prog = compile_training(two_stage_forward, params, INPUTS,
                                strategy=raw_strategy(sched))
        # per-MB all-reduces merged into one accumulated AR per bucket
        ars = [n for n in prog.dag.comms() if n.op == "all_reduce"]
        assert len(ars) == 2
        assert all(n.meta.get("accumulated") for n in ars)
        res = Interpreter(prog).run({"x": x, "y": y})
        l, g = oracle(params, x, y)
        assert res.loss == pytest.approx(l, abs=1e-6)
        assert_grads_close(res.grads, g)


class TestOrderAndPipeline:
    def test_1f1b_like_order(self, setup):
        """PP-2 with 2 microbatches and an explicit Order: numerics must
        match, and the temporal edges must hold in execution order."""
        params, x, y = setup
        sched = [
            Place(F(pp=0), devices=[0], stream="pp"),
            Place(F(pp=1), devices=[1], stream="pp"),
            Split(F(), dim="MB", num_microbatches=2),
            Order([F(pp=0, MB=0, PASS="F"), F(pp=0, MB=1, PASS="F"),
                   F(pp=0, MB=0, PASS="B"), F(pp=0, MB=1, PASS="B")]),
        ]
        prog = compile_training(two_stage_forward, params, INPUTS,
                                strategy=raw_strategy(sched))
        res = Interpreter(prog).run({"x": x, "y": y})
        l, g = oracle(params, x, y)
        assert res.loss == pytest.approx(l, abs=1e-6)
        assert_grads_close(res.grads, g)

    def test_overlap_group_interleaves(self, setup):
        params, x, y = setup
        sched = [
            Split(F(), dim="MB", num_microbatches=2),
            Order([F(MB=0, PASS="F"),
                   [F(MB=1, PASS="F"), F(MB=0, PASS="B")],
                   F(MB=1, PASS="B")]),
        ]
        prog = compile_training(two_stage_forward, params, INPUTS,
                                strategy=raw_strategy(sched))
        res = Interpreter(prog).run({"x": x, "y": y})
        l, _ = oracle(params, x, y)
        assert res.loss == pytest.approx(l, abs=1e-6)
        # MB0-F first, MB1-B last (temporal edges honored)
        chunk_names = [prog.dag.nodes[k[0]].dims for k in res.exec_order
                       if prog.dag.nodes[k[0]].is_chunk]
        first, last = chunk_names[0], chunk_names[-1]
        assert first["MB"] == 0 and first["PASS"] == "F"
        assert last["MB"] == 1 and last["PASS"] == "B"


class TestShardEP:
    def test_moe_ep(self, setup):
        """Expert chunk sharded over 2 devices with a2a, DP elsewhere."""
        params, x, y = setup

        def moe_forward(rec, tvs):
            with rec.annotate("pp"):
                h = rec.region(stage_fn, "stage0", name="dense")(tvs["x"])
                with rec.annotate("ep"):
                    h = rec.region(stage_fn, "experts", name="experts")(h)
            with rec.annotate("pp"):
                loss = rec.region(loss_fn, "stage1", name="head")(h, tvs["y"])
            return loss

        p3 = dict(params)
        p3["experts"] = {
            "w1": jax.random.normal(jax.random.PRNGKey(7), (D, D)) * 0.1,
            "w2": jax.random.normal(jax.random.PRNGKey(8), (D, D)) * 0.1,
        }
        sched = [
            Replicate(F(ep="-"), devices=[0, 1], reduce_stream="dp"),
            Shard(F(ep="*"), devices=[0, 1], stream="ep"),
        ]
        prog = compile_training(moe_forward, p3, INPUTS,
                                strategy=raw_strategy(sched))
        a2as = [n for n in prog.dag.comms() if n.op == "all_to_all"]
        assert len(a2as) >= 4  # in/out x fwd/bwd
        res = Interpreter(prog).run({"x": x, "y": y})

        def full(p):
            h = stage_fn(p["stage0"], x)
            h = stage_fn(p["experts"], h)
            return loss_fn(p["stage1"], h, y)
        l, g = jax.value_and_grad(full)(p3)
        assert res.loss == pytest.approx(float(l), abs=1e-6)
        assert_grads_close(res.grads, g)
