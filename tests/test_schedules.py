"""PP schedule builders: canonical-table validation, end-to-end numerics
through the Piper compiler + interpreter for every builder, and the
p2p-order rejection rule."""
import jax
import pytest

from helpers import (assert_grads_close, inputs_spec, make_batch,
                     make_mlp_forward, make_mlp_params, mlp_oracle,
                     raw_strategy)
from repro.core import (F, Order, Place, Replicate, ScheduleRejected, Split,
                        compile_training)
from repro.core.schedules import (build_rank_sequences,
                                  canonical_1f1b,
                                  emit_directives,
                                  stages_of_rank)
from repro.runtime import Interpreter

jax.config.update("jax_platform_name", "cpu")


def flatten(seq):
    out = []
    for ops in seq:
        out.extend(ops if isinstance(ops, tuple) else (ops,))
    return out


class TestGenerators:
    @pytest.mark.parametrize("R,M", [(2, 4), (4, 8), (4, 4)])
    def test_1f1b_matches_canonical(self, R, M):
        seqs = build_rank_sequences("1f1b", R, M)
        for r in range(R):
            assert flatten(seqs[r]) == canonical_1f1b(r, R, M)

    @pytest.mark.parametrize("kind,R,M", [
        ("gpipe", 4, 8), ("1f1b", 4, 8),
        ("interleaved_1f1b", 4, 8), ("dualpipev", 4, 8)])
    def test_complete_and_dep_respecting(self, kind, R, M):
        seqs = build_rank_sequences(kind, R, M)
        S = {"gpipe": R, "1f1b": R}.get(kind, 2 * R)
        passes = 3 if kind == "dualpipev" else 2  # dualpipev splits Bi/Bw
        all_ops = [op for s in seqs for op in flatten(s)]
        assert len(all_ops) == passes * S * M
        assert len(set(all_ops)) == len(all_ops)
        # every rank only runs its own stages
        for r, seq in enumerate(seqs):
            mine = set(stages_of_rank(kind, r, R, S))
            assert {op.stage for op in flatten(seq)} <= mine

    def test_dualpipev_has_overlap_pairs(self):
        seqs = build_rank_sequences("dualpipev", 4, 8)
        pairs = [ops for s in seqs for ops in s if isinstance(ops, tuple)]
        assert len(pairs) >= 4  # steady state produces F+B pairs
        for (f, b) in pairs:
            assert f.pas == "F" and b.pas == "Bi"
            assert (f.stage < 4) != (b.stage < 4)  # opposite halves


N_MB = 4
BATCH = 16


class TestEndToEnd:
    @pytest.mark.parametrize("kind,R", [
        ("gpipe", 2), ("1f1b", 2), ("1f1b", 4),
        ("interleaved_1f1b", 2), ("dualpipev", 2)])
    def test_numerics(self, kind, R):
        S = {"gpipe": R, "1f1b": R}.get(kind, 2 * R)
        split = kind == "dualpipev"
        params = make_mlp_params(jax.random.PRNGKey(0), S)
        fwd = make_mlp_forward(S)
        seqs = build_rank_sequences(kind, R, N_MB, S)
        sched = emit_directives(kind, seqs,
                                device_groups=[[r] for r in range(R)],
                                n_stages=S)
        prog = compile_training(fwd, params, inputs_spec(BATCH),
                                strategy=raw_strategy(
                                    sched, split_backward=split))
        batch = make_batch(BATCH)
        res = Interpreter(prog).run(batch)
        l, g = mlp_oracle(params, batch["x"], batch["y"], S)
        assert res.loss == pytest.approx(l, abs=1e-6)
        assert_grads_close(res.grads, g)

    def test_1f1b_with_dp(self):
        """PP-2 x DP-2 on 4 devices."""
        R, S = 2, 2
        params = make_mlp_params(jax.random.PRNGKey(0), S)
        fwd = make_mlp_forward(S)
        seqs = build_rank_sequences("1f1b", R, N_MB, S)
        sched = emit_directives("1f1b", seqs,
                                device_groups=[[0, 2], [1, 3]], n_stages=S)
        # DP over the replica groups (insert before Split, per Listing 2)
        sched = sched[:S] + [
            Replicate(F(pp=0), devices=[0, 2], reduce_stream="dp"),
            Replicate(F(pp=1), devices=[1, 3], reduce_stream="dp"),
        ] + sched[S:]
        prog = compile_training(fwd, params, inputs_spec(BATCH),
                                strategy=raw_strategy(sched))
        assert len(prog.plan.devices) == 4
        batch = make_batch(BATCH)
        res = Interpreter(prog).run(batch)
        l, g = mlp_oracle(params, batch["x"], batch["y"], S)
        assert res.loss == pytest.approx(l, abs=1e-6)
        assert_grads_close(res.grads, g)

    def test_1f1b_activation_stash_bounded(self):
        """1F1B in-flight activations stay bounded by the stage depth
        (the reason 1F1B beats GPipe on memory)."""
        R = 4
        params = make_mlp_params(jax.random.PRNGKey(0), R)
        fwd = make_mlp_forward(R)
        peaks = {}
        for kind in ("gpipe", "1f1b"):
            seqs = build_rank_sequences(kind, R, 8, R)
            sched = emit_directives(kind, seqs,
                                    device_groups=[[r] for r in range(R)],
                                    n_stages=R)
            prog = compile_training(fwd, params, inputs_spec(32),
                                    strategy=raw_strategy(sched))
            res = Interpreter(prog).run(make_batch(32))
            peaks[kind] = res.ledgers[0].peak  # stage-0 device peak
        assert peaks["1f1b"] < peaks["gpipe"]


class TestRejection:
    def test_determinism_prevents_p2p_mismatch(self):
        """Reordering downstream consumption must NOT break the p2p rule:
        the deterministic centralized scheduler derives send and recv
        dispatch order from the same global priorities, so both sides
        flip together (paper §4.3.1 'the prioritization is deterministic,
        to ensure all ranks dispatch communications in the same order')."""
        S = 2
        params = make_mlp_params(jax.random.PRNGKey(0), S)
        fwd = make_mlp_forward(S)
        sched = [
            Place(F(pp=0), devices=[0], stream="pp"),
            Place(F(pp=1), devices=[1], stream="pp"),
            Split(F(), dim="MB", num_microbatches=2),
            Order([F(pp=0, MB=0, PASS="F"), F(pp=0, MB=1, PASS="F")]),
            # stage 1 consumes mb1 first — legal: recvs follow suit
            Order([F(pp=1, MB=1, PASS="F"), F(pp=1, MB=0, PASS="F")]),
        ]
        prog = compile_training(fwd, params, inputs_spec(BATCH),
                                strategy=raw_strategy(sched))
        res = Interpreter(prog).run(make_batch(BATCH))
        l, _ = mlp_oracle(params, make_batch(BATCH)["x"],
                          make_batch(BATCH)["y"], S)
        assert res.loss == pytest.approx(l, abs=1e-6)

    def test_mismatched_plan_rejected_by_validator(self):
        """A hand-built plan whose recv order disagrees with the send
        order must be rejected (paper §4.3.2)."""
        from repro.core import TrainingDAG, validate_comm_order
        from repro.core.plan import (ROLE_RECV, ROLE_SEND, DevicePlan,
                                     GlobalPlan, Task)
        dag = TrainingDAG()
        n0 = dag.new_node(kind="comm", op="p2p", name="p2p0",
                          devices=(0, 1), meta={"pairs": [(0, 1)]})
        n1 = dag.new_node(kind="comm", op="p2p", name="p2p1",
                          devices=(0, 1), meta={"pairs": [(0, 1)]})
        p0, p1 = DevicePlan(device=0), DevicePlan(device=1)
        p0.append(Task(n0.id, 0, ROLE_SEND, "pp#snd"))
        p0.append(Task(n1.id, 0, ROLE_SEND, "pp#snd"))
        p1.append(Task(n1.id, 1, ROLE_RECV, "pp#rcv"))  # flipped
        p1.append(Task(n0.id, 1, ROLE_RECV, "pp#rcv"))
        plan = GlobalPlan(device_plans={0: p0, 1: p1}, priorities={},
                          devices=[0, 1])
        with pytest.raises(ScheduleRejected):
            validate_comm_order(dag, plan)

    def test_mismatched_collective_order_rejected(self):
        """Two ranks dispatching a (group, stream) communicator's
        collectives in different orders must be rejected — on a real
        cluster the mismatched rendezvous deadlocks (paper §4.3.2)."""
        from repro.core import TrainingDAG, ValueSpec, validate_comm_order
        from repro.core.plan import (ROLE_COLL, DevicePlan, GlobalPlan,
                                     Task)
        dag = TrainingDAG()
        ag = dag.new_node(kind="comm", op="all_gather", name="ag",
                          devices=(0, 1), group=(0, 1), payload="param",
                          out_specs=[ValueSpec((8,))])
        ar = dag.new_node(kind="comm", op="all_reduce", name="ar",
                          devices=(0, 1), group=(0, 1), payload="grad",
                          out_specs=[ValueSpec((8,))])
        p0, p1 = DevicePlan(device=0), DevicePlan(device=1)
        p0.append(Task(ag.id, 0, ROLE_COLL, "zero"))
        p0.append(Task(ar.id, 0, ROLE_COLL, "zero"))
        p1.append(Task(ar.id, 1, ROLE_COLL, "zero"))  # flipped on rank 1
        p1.append(Task(ag.id, 1, ROLE_COLL, "zero"))
        plan = GlobalPlan(device_plans={0: p0, 1: p1}, priorities={},
                          devices=[0, 1])
        with pytest.raises(ScheduleRejected, match="dispatch order"):
            validate_comm_order(dag, plan)

    def test_same_group_different_streams_may_reorder(self):
        """Collectives on different streams use different communicators;
        cross-stream order is unconstrained (paper: one communicator per
        (group, stream))."""
        from repro.core import TrainingDAG, ValueSpec, validate_comm_order
        from repro.core.plan import (ROLE_COLL, DevicePlan, GlobalPlan,
                                     Task)
        dag = TrainingDAG()
        ag = dag.new_node(kind="comm", op="all_gather", name="ag",
                          devices=(0, 1), group=(0, 1), payload="param",
                          out_specs=[ValueSpec((8,))])
        ar = dag.new_node(kind="comm", op="all_reduce", name="ar",
                          devices=(0, 1), group=(0, 1), payload="grad",
                          out_specs=[ValueSpec((8,))])
        p0, p1 = DevicePlan(device=0), DevicePlan(device=1)
        p0.append(Task(ag.id, 0, ROLE_COLL, "gather"))
        p0.append(Task(ar.id, 0, ROLE_COLL, "reduce"))
        p1.append(Task(ar.id, 1, ROLE_COLL, "reduce"))
        p1.append(Task(ag.id, 1, ROLE_COLL, "gather"))
        plan = GlobalPlan(device_plans={0: p0, 1: p1}, priorities={},
                          devices=[0, 1])
        validate_comm_order(dag, plan)  # must not raise

    def test_p2p_missing_recv_rejected(self):
        """A send with no matching recv in the direction's sequence is a
        p2p order violation (the receiver would consume the wrong
        microbatch)."""
        from repro.core import TrainingDAG, validate_comm_order
        from repro.core.plan import (ROLE_RECV, ROLE_SEND, DevicePlan,
                                     GlobalPlan, Task)
        dag = TrainingDAG()
        n0 = dag.new_node(kind="comm", op="p2p", name="p2p0",
                          devices=(0, 1), meta={"pairs": [(0, 1)]})
        n1 = dag.new_node(kind="comm", op="p2p", name="p2p1",
                          devices=(0, 1), meta={"pairs": [(0, 1)]})
        p0, p1 = DevicePlan(device=0), DevicePlan(device=1)
        p0.append(Task(n0.id, 0, ROLE_SEND, "pp#snd"))
        p0.append(Task(n1.id, 0, ROLE_SEND, "pp#snd"))
        p1.append(Task(n0.id, 1, ROLE_RECV, "pp#rcv"))  # n1 recv missing
        plan = GlobalPlan(device_plans={0: p0, 1: p1}, priorities={},
                          devices=[0, 1])
        with pytest.raises(ScheduleRejected, match="p2p order"):
            validate_comm_order(dag, plan)

    def test_contradictory_order_rejected(self):
        """Order directives that contradict dataflow produce an IR cycle
        and are rejected at compile time."""
        S = 2
        params = make_mlp_params(jax.random.PRNGKey(0), S)
        fwd = make_mlp_forward(S)
        sched = [Order([F(pp=1, PASS="F"), F(pp=0, PASS="F")])]
        with pytest.raises((ValueError, ScheduleRejected)):
            compile_training(fwd, params, inputs_spec(BATCH),
                             strategy=raw_strategy(sched))


class TestZeroBubble:
    def test_zb1f1b_numerics(self):
        """ZeroBubble-style 1F1B (Bi/Bw split) matches the oracle."""
        R, S = 2, 2
        params = make_mlp_params(jax.random.PRNGKey(0), S)
        fwd = make_mlp_forward(S)
        seqs = build_rank_sequences("zb1f1b", R, N_MB, S)
        sched = emit_directives("zb1f1b", seqs,
                                device_groups=[[r] for r in range(R)],
                                n_stages=S)
        prog = compile_training(fwd, params, inputs_spec(BATCH),
                                strategy=raw_strategy(
                                    sched, split_backward=True))
        batch = make_batch(BATCH)
        res = Interpreter(prog).run(batch)
        l, g = mlp_oracle(params, batch["x"], batch["y"], S)
        assert res.loss == pytest.approx(l, abs=1e-6)
        assert_grads_close(res.grads, g)

    def test_zb1f1b_fills_bubbles(self):
        """Bw filler ops reduce drain-phase idle vs plain 1F1B in the
        simulator (the ZeroBubble claim, at Bi+Bw == B total cost)."""
        from repro.runtime.costmodel import CostModel
        from repro.runtime.simulator import TimelineSimulator
        R, M, S = 4, 8, 4
        params = make_mlp_params(jax.random.PRNGKey(0), S)
        fwd = make_mlp_forward(S)
        times = {}
        for kind in ("1f1b", "zb1f1b"):
            seqs = build_rank_sequences(kind, R, M, S)
            sched = emit_directives(kind, seqs,
                                    device_groups=[[r] for r in range(R)],
                                    n_stages=S)
            prog = compile_training(
                fwd, params, inputs_spec(32), strategy=raw_strategy(
                    sched, split_backward=(kind == "zb1f1b")))
            cost = CostModel(ici_bw=1e12, comm_latency=0.0)
            res = TimelineSimulator(
                prog, cost,
                chunk_seconds_override=lambda n: (
                    5e-3 if n.dims.get("PASS") in ("Bi", "Bw")
                    else 1e-2)).run()
            times[kind] = res.makespan
        assert times["zb1f1b"] < times["1f1b"], times
