"""The unified Executor API (runtime/executor.py): registry behavior,
the ``Executor`` protocol conformance of all three builtin backends,
the ``--backend`` CLI front door (argparse exit-2 contract), and the
PR-10 deprecation gate on the old ``parallel.sharding.Strategy``
spelling (internal to the spmd backend, renamed ``ShardingRules``)."""
import json
import os
import pathlib
import subprocess
import sys
import textwrap
import warnings

import pytest

_ROOT = pathlib.Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_builtin_backends_registered():
    from repro.runtime.executor import (BackendCapabilities,
                                        get_backend_spec, list_backends)

    assert list_backends() == ("reference", "spmd", "mpmd")
    ref = get_backend_spec("reference").capabilities
    spmd = get_backend_spec("spmd").capabilities
    mpmd = get_backend_spec("mpmd").capabilities
    assert isinstance(ref, BackendCapabilities)
    # the flags callers actually branch on
    assert not ref.real_xla and ref.memory_ledgers
    assert spmd.real_xla and spmd.measured_time and not spmd.per_rank_trace
    assert mpmd.real_xla and mpmd.per_rank_trace and mpmd.multi_controller


def test_unknown_backend_lists_registered_names():
    from repro.runtime.executor import (UnknownBackendError,
                                        executor_factory, get_backend,
                                        list_backends)

    for call in (lambda: get_backend("smpd"),
                 lambda: executor_factory("smpd")):
        with pytest.raises(UnknownBackendError) as ei:
            call()
        msg = str(ei.value)
        assert "smpd" in msg
        for name in list_backends():
            assert name in msg, (name, msg)


def test_backends_help_mentions_every_backend():
    from repro.runtime.executor import backends_help, list_backends

    text = backends_help()
    for name in list_backends():
        assert f"'{name}'" in text, (name, text)


def test_register_backend_third_party_roundtrip():
    """Non-builtin registration: needs explicit capabilities, stamps the
    class, resolves through the same front door."""
    from repro.runtime import executor as ex_mod
    from repro.runtime.executor import (BackendCapabilities, get_backend,
                                        register_backend)

    with pytest.raises(ValueError, match="capabilities"):
        register_backend("thirdparty")(type("X", (), {}))

    caps = BackendCapabilities(real_xla=False)
    try:
        @register_backend("thirdparty", capabilities=caps,
                          summary="test stub")
        class Stub:
            @classmethod
            def compile(cls, prog, params=None, *,
                        physical_devices=None, **opts):
                return cls()

        assert Stub.backend_name == "thirdparty"
        assert Stub.capabilities is caps
        assert get_backend("thirdparty") is Stub
    finally:
        ex_mod._REGISTRY.pop("thirdparty", None)


def test_executor_factory_shape():
    """``executor_factory`` produces the ``ElasticSupervisor``
    runner-factory contract: ``factory(prog, params, physical_devices)``
    with the backend resolved lazily (reference runs anywhere)."""
    import jax

    from helpers import (inputs_spec, make_batch, make_mlp_forward,
                         make_mlp_params)
    from repro.core import Mesh, Pipeline, Strategy, ZeRO, compile_training
    from repro.runtime.executor import Executor, executor_factory

    S, BATCH = 4, 8
    params = make_mlp_params(jax.random.PRNGKey(0), S)
    prog = compile_training(
        make_mlp_forward(S), params, inputs_spec(BATCH),
        strategy=Strategy(Mesh(pp=2, dp=2),
                          Pipeline("1f1b", n_mb=2) | ZeRO(stage=3)))
    factory = executor_factory("reference")
    assert factory.backend_name == "reference"
    runner = factory(prog, params, None)
    assert isinstance(runner, Executor)
    out = runner.run(make_batch(BATCH))
    assert out.loss == pytest.approx(out.loss)  # finite, no NaN
    # the elastic-resume contract: swap weights without rebuilding
    runner.params = params
    assert runner.params is params


# ---------------------------------------------------------------------------
# Executor protocol conformance (all three backends)
# ---------------------------------------------------------------------------

def test_protocol_surface_all_backends():
    """Import-level conformance: every registered class carries the
    protocol surface (compile classmethod, run, stamped identity)."""
    from repro.runtime.executor import (BackendCapabilities, get_backend,
                                        get_backend_spec, list_backends)

    for name in list_backends():
        cls = get_backend(name)
        assert cls.backend_name == name
        assert cls.capabilities is get_backend_spec(name).capabilities
        assert isinstance(cls.capabilities, BackendCapabilities)
        assert callable(getattr(cls, "compile"))
        assert callable(getattr(cls, "run"))


CONFORMANCE_CHILD = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import numpy as np
    from helpers import (make_mlp_params, make_mlp_forward,
                         inputs_spec, make_batch)
    from repro.core import (compile_training, Mesh, Pipeline, ZeRO,
                            Strategy)
    from repro.runtime.executor import (Executor, list_backends,
                                        make_executor)

    S, BATCH = 4, 8
    params = make_mlp_params(jax.random.PRNGKey(0), S)
    prog = compile_training(
        make_mlp_forward(S), params, inputs_spec(BATCH),
        strategy=Strategy(Mesh(pp=2, dp=2),
                          Pipeline("1f1b", n_mb=2) | ZeRO(stage=3)))
    batch = make_batch(BATCH)
    losses = {}
    for name in list_backends():
        ex = make_executor(name, prog, params=params)
        assert isinstance(ex, Executor), name
        assert ex.backend_name == name
        assert len(ex.physical_devices) == 4, (name, ex.physical_devices)
        out = ex.run(batch)
        assert sorted(out.grads), name
        losses[name] = float(out.loss)
        ex.params = params          # settable, per the protocol
    vals = sorted(losses.values())
    assert np.allclose(vals[0], vals[-1], rtol=1e-5), losses
    print("CONFORMANCE_OK", losses)
""")


@pytest.mark.slow
@pytest.mark.mpmd
def test_protocol_conformance_runs_all_backends():
    """Behavioral conformance: one ``make_executor`` front door builds
    all three backends on the same compiled plan; each satisfies the
    runtime-checkable protocol and agrees on the step loss."""
    env = {**os.environ, "JAX_PLATFORMS": "cpu",
           "PYTHONPATH": f"{_ROOT / 'src'}{os.pathsep}{_ROOT / 'tests'}"}
    r = subprocess.run(
        [sys.executable, "-c", CONFORMANCE_CHILD],
        capture_output=True, text=True, timeout=600, env=env)
    assert "CONFORMANCE_OK" in r.stdout, \
        (r.stdout[-2000:], r.stderr[-4000:])


# ---------------------------------------------------------------------------
# the --backend CLI front door
# ---------------------------------------------------------------------------

def test_cli_backend_without_strategy_is_argparse_error(capsys):
    """``--backend`` without ``--strategy`` must exit 2 through
    ``ArgumentParser.error`` (usage + message on stderr), not a manual
    print-and-return."""
    from repro.launch.train import main

    with pytest.raises(SystemExit) as ei:
        main(["--backend", "spmd"])
    assert ei.value.code == 2
    err = capsys.readouterr().err
    assert "usage:" in err
    assert "--backend needs a --strategy document" in err


def test_cli_unknown_backend_choice_lists_names(capsys):
    """An unregistered ``--backend`` value is rejected by argparse's
    choices (sourced from ``list_backends()``), naming the valid set."""
    from repro.launch.train import main
    from repro.runtime.executor import list_backends

    with pytest.raises(SystemExit) as ei:
        main(["--backend", "smpd"])
    assert ei.value.code == 2
    err = capsys.readouterr().err
    for name in list_backends():
        assert name in err, (name, err)


def test_cli_elastic_needs_strategy_and_backend(capsys):
    from repro.launch.train import main

    with pytest.raises(SystemExit) as ei:
        main(["--elastic"])
    assert ei.value.code == 2
    err = capsys.readouterr().err
    assert "--elastic needs --strategy and --backend" in err
    assert "mpmd" in err   # the one-of list comes from the registry


def test_no_string_backend_dispatch_outside_registry():
    """The api_redesign acceptance grep: no ``args.backend == "spmd"``
    style string dispatch survives outside runtime/executor.py —
    callers branch on capabilities or go through the registry."""
    offenders = []
    for p in (_ROOT / "src").rglob("*.py"):
        if p.name == "executor.py":
            continue
        text = p.read_text()
        for needle in ('backend == "spmd"', "backend == 'spmd'",
                       'backend == "mpmd"', "backend == 'mpmd'",
                       'backend == "reference"'):
            if needle in text:
                offenders.append((str(p), needle))
    assert not offenders, offenders


# ---------------------------------------------------------------------------
# the Strategy-worlds collapse: parallel.sharding.Strategy is deprecated
# ---------------------------------------------------------------------------

def test_sharding_strategy_deprecated_alias():
    """Both old spellings still resolve — to ``ShardingRules`` — but
    warn; under this repo's pytest filterwarnings config the warning is
    an error, so no in-repo code may use them."""
    import repro.parallel as par
    import repro.parallel.sharding as sharding

    for src in (sharding, par):
        with pytest.warns(DeprecationWarning,
                          match="parallel.sharding.Strategy is "
                                "deprecated"):
            cls = src.Strategy
        assert cls is sharding.ShardingRules


def test_sharding_unknown_attr_still_raises():
    import repro.parallel as par
    import repro.parallel.sharding as sharding

    for src in (sharding, par):
        with pytest.raises(AttributeError):
            src.Nonexistent


def test_sharding_rules_is_the_spmd_lowering():
    """``ShardingRules.from_core`` remains the one supported way in:
    the first-class ``core.strategy.Strategy`` lowers to the spmd
    backend's rules (``launch.steps.strategy_for``)."""
    from repro.core import Mesh
    from repro.launch.steps import strategy_for
    from repro.parallel.sharding import ShardingRules

    rules = strategy_for(Mesh(pp=2, dp=2), zero_stage=3)
    assert isinstance(rules, ShardingRules)
    assert rules.zero_stage == 3
