"""Substrate tests: data pipeline determinism/sharding/resume,
checkpoint save/restore/corruption/gc, FT supervisor restart semantics,
optimizer + schedules, and the end-to-end train driver."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_tree, save_tree
from repro.data import SyntheticTokenSource, TokenLoader
from repro.ft import FailureInjector, StragglerWatchdog, Supervisor
from repro.ft.supervisor import WorkerFailure
from repro.optim import adamw_init, adamw_update, cosine_schedule, wsd_schedule

jax.config.update("jax_platform_name", "cpu")


class TestData:
    def test_deterministic(self):
        s = SyntheticTokenSource(vocab=100, seed=3)
        a = s.block(5, 4, 16)
        b = s.block(5, 4, 16)
        assert np.array_equal(a, b)
        assert not np.array_equal(a, s.block(6, 4, 16))
        assert a.min() >= 0 and a.max() < 100

    def test_host_sharding_partitions(self):
        src = SyntheticTokenSource(vocab=100, seed=3)
        full = TokenLoader(src, batch=8, seq=16).next_batch()
        parts = []
        for h in range(4):
            l = TokenLoader(src, batch=8, seq=16, host_id=h, n_hosts=4)
            parts.append(l.next_batch()["tokens"])
        assert np.array_equal(np.concatenate(parts), full["tokens"])

    def test_resume_exact(self):
        src = SyntheticTokenSource(vocab=100, seed=3)
        l1 = TokenLoader(src, batch=4, seq=8)
        l1.next_batch(); l1.next_batch()
        saved = l1.state_dict()
        want = l1.fingerprint()
        l2 = TokenLoader(src, batch=4, seq=8)
        l2.load_state_dict(saved)
        assert l2.fingerprint() == want
        assert np.array_equal(l1.next_batch()["tokens"],
                              l2.next_batch()["tokens"])


class TestCheckpoint:
    def _tree(self, key=0):
        return {"a": jnp.arange(12.0).reshape(3, 4) + key,
                "b": {"c": jnp.ones((5,), jnp.int32) * key}}

    def test_roundtrip(self, tmp_path):
        t = self._tree(3)
        save_tree(t, tmp_path / "ck")
        got = restore_tree(t, tmp_path / "ck")
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(a, b), t, got)

    def test_corruption_detected(self, tmp_path):
        t = self._tree(1)
        save_tree(t, tmp_path / "ck")
        # flip a byte in one leaf
        f = next((tmp_path / "ck").glob("a.npy"))
        data = bytearray(f.read_bytes())
        data[-1] ^= 0xFF
        f.write_bytes(bytes(data))
        with pytest.raises(IOError, match="corruption"):
            restore_tree(t, tmp_path / "ck")

    def test_manager_keep_and_latest(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
        for s in (10, 20, 30):
            mgr.save(s, self._tree(s))
        assert mgr.latest_step() == 30
        dirs = sorted(p.name for p in tmp_path.glob("step_*"))
        assert dirs == ["step_00000020", "step_00000030"]
        tree, extra = mgr.restore(self._tree(0))
        assert extra["step"] == 30
        assert float(tree["a"][0, 0]) == 30.0

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2, async_save=True)
        mgr.save(1, self._tree(1))
        mgr.wait()
        assert mgr.latest_step() == 1

    def test_extension_dtype_roundtrip(self, tmp_path):
        # .npy loads ml_dtypes extension dtypes back as raw void
        # records; restore must reinterpret via the manifest dtype
        t = {"w": jnp.linspace(-2.0, 2.0, 8).astype(jnp.bfloat16),
             "b": jnp.ones((3,), jnp.float32)}
        save_tree(t, tmp_path / "ck")
        got = restore_tree(t, tmp_path / "ck")
        assert got["w"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(t["w"]).view(np.uint16),
                                      np.asarray(got["w"]).view(np.uint16))
        np.testing.assert_array_equal(t["b"], got["b"])


class TestSupervisor:
    def _setup(self, tmp_path, fail_at=()):
        loader = TokenLoader(SyntheticTokenSource(50, seed=1),
                             batch=2, seq=4)
        ckpt = CheckpointManager(tmp_path, keep=3, async_save=False)
        sup = Supervisor(ckpt, loader, checkpoint_every=5,
                         injector=FailureInjector(tuple(fail_at)))
        state = {"params": jnp.zeros((3,)),
                 "step": jnp.zeros((), jnp.int32)}

        def step_fn(state, batch):
            return ({"params": state["params"] + 1.0,
                     "step": state["step"] + 1},
                    {"loss": jnp.sum(state["params"])})
        return sup, state, step_fn

    def test_runs_and_checkpoints(self, tmp_path):
        sup, state, fn = self._setup(tmp_path)
        out = sup.run(state, fn, 12, log_every=0)
        assert int(out["step"]) == 12
        assert sup.ckpt.latest_step() == 12

    def test_failure_restart_resumes(self, tmp_path):
        sup, state, fn = self._setup(tmp_path, fail_at=(7,))
        out = sup.run(state, fn, 12, log_every=0)
        assert int(out["step"]) == 12
        assert sup.restarts == 1
        # params == step count proves no lost/duplicated updates after
        # rollback to step 5 and replay
        assert float(out["params"][0]) == 12.0

    def test_too_many_failures_surface(self, tmp_path):
        sup, state, fn = self._setup(tmp_path,
                                     fail_at=tuple(range(1, 20)))
        sup.max_restarts = 3
        sup.injector._fired = set()  # re-fire every time

        class AlwaysFail(FailureInjector):
            def check(self, step):
                raise WorkerFailure("flaky node")
        sup.injector = AlwaysFail()
        with pytest.raises(WorkerFailure):
            sup.run(state, fn, 12, log_every=0)

    def test_straggler_watchdog(self):
        wd = StragglerWatchdog(threshold=2.0)
        for _ in range(10):
            wd.observe(0, 0.1)
        assert wd.observe(11, 0.5) is True
        assert len(wd.events) == 1

    def test_checkpoint_persists_stream_position(self, tmp_path):
        # the restart contract: every checkpoint carries the loader
        # position, and it equals the checkpoint step
        sup, state, fn = self._setup(tmp_path)
        sup.run(state, fn, 12, log_every=0)
        for step in (5, 10, 12):
            _, extra = sup.ckpt.restore(
                {"params": jnp.zeros((3,)),
                 "step": jnp.zeros((), jnp.int32)}, step=step)
            assert int(extra["step"]) == step
            assert int(extra["data"]["step"]) == step

    def test_restore_rejects_stale_stream_position(self, tmp_path):
        from repro.ft import StreamPositionError, check_stream_position
        with pytest.raises(StreamPositionError, match="skip or replay"):
            check_stream_position({"step": 5, "data": {"step": 3}})
        with pytest.raises(StreamPositionError, match="no data-stream"):
            check_stream_position({"step": 5})
        assert check_stream_position({"step": 5,
                                      "data": {"step": 5}}) == 5
        # end to end: a checkpoint written with a desynced loader state
        # fails the restore instead of resuming on the wrong samples
        sup, state, fn = self._setup(tmp_path, fail_at=(3,))
        sup.ckpt.save(2, {"params": jnp.full((3,), 2.0),
                          "step": jnp.full((), 2, jnp.int32)},
                      extra={"data": {"step": 1, "epoch": 0, "seed": 1}})
        with pytest.raises(StreamPositionError):
            sup.run(state, fn, 12, log_every=0)

    def test_failure_before_first_checkpoint_rewinds_stream(
            self, tmp_path):
        # fail BEFORE the first checkpoint: the restart must rewind the
        # data stream to its pristine position along with the model
        # state (the old supervisor kept the advanced loader, silently
        # training a from-scratch run on the wrong sample order)
        sup, state, fn = self._setup(tmp_path, fail_at=(3,))
        out = sup.run(state, fn, 12, log_every=0)
        assert int(out["step"]) == 12
        assert float(out["params"][0]) == 12.0
        # 3 pre-failure batches were rewound: the loader's final
        # position reflects exactly the 12 kept steps
        assert int(sup.loader.state_dict()["step"]) == 12


class TestOptim:
    def test_adamw_decreases_quadratic(self):
        p = {"w": jnp.array([3.0, -2.0])}
        opt = adamw_init(p)
        for _ in range(200):
            g = {"w": 2 * p["w"]}
            p, opt, _ = adamw_update(p, g, opt, lr=5e-2, weight_decay=0.0)
        assert float(jnp.abs(p["w"]).max()) < 0.3

    def test_clipping(self):
        p = {"w": jnp.zeros((4,))}
        opt = adamw_init(p)
        g = {"w": jnp.full((4,), 1e6)}
        p2, opt, gnorm = adamw_update(p, g, opt, lr=1e-3, clip_norm=1.0)
        assert float(gnorm) > 1e5
        assert np.all(np.isfinite(np.asarray(p2["w"])))

    def test_schedules(self):
        wsd = wsd_schedule(1.0, 100, warmup_frac=0.1, decay_frac=0.2)
        assert float(wsd(5)) == pytest.approx(0.5)
        assert float(wsd(50)) == pytest.approx(1.0)
        assert float(wsd(100)) < 0.2
        cos = cosine_schedule(1.0, 100, warmup_frac=0.1)
        assert float(cos(10)) == pytest.approx(1.0)
        assert float(cos(100)) == pytest.approx(0.1, abs=0.02)


class TestTrainDriver:
    def test_end_to_end_with_failure(self, tmp_path):
        from repro.launch.train import main
        rc = main(["--arch", "qwen1.5-0.5b", "--steps", "30",
                   "--batch", "4", "--seq", "32", "--d-model", "64",
                   "--layers", "2", "--vocab", "128",
                   "--ckpt-dir", str(tmp_path),
                   "--ckpt-every", "10", "--fail-at", "15"])
        assert rc == 0
